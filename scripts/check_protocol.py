#!/usr/bin/env python3
"""WAL-protocol conformance CLI: the CI hard gate for the record protocol.

    python scripts/check_protocol.py               # check the protocol tree
    python scripts/check_protocol.py --self-test   # prove every rule fires
    python scripts/check_protocol.py --json        # machine-readable output
    python scripts/check_protocol.py path.py ...   # check explicit files

Default targets are the protocol's implementation files —
``src/repro/core/{metalog,range_shard,shard,store}.py`` and
``src/repro/elastic/remap.py`` — checked against
``repro.analysis.protocol.spec.WAL_SPEC`` with completeness on (every spec
kind must be appended somewhere).  Explicit paths are checked without the
completeness requirement.  Exit codes: 0 clean, 1 violations found,
2 self-test/usage failure.

``--self-test`` runs the seeded-violation fixtures so rules cannot silently
rot: every ``tests/fixtures/protocol_bad/*.py`` declares its planted rules
with ``# protocol-expect: <rule>`` lines (and opts into the completeness
check with ``# protocol-flags: require-complete``) and must produce exactly
that rule set; every ``tests/fixtures/protocol_good/*.py`` must check clean;
and every registered rule must be covered by at least one bad fixture.

``--json`` emits ``{"violations": [{"path", "line", "rule", "message"}],
"files": N}``; the default text format (``path:line: [rule] message``) is
matched by ``.github/problem-matchers/repro-analysis.json`` so CI annotates
the offending diff lines.
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis.protocol.static_check import (  # noqa: E402
    PROTOCOL_RULES,
    check_paths,
    default_targets,
)

_EXPECT_RE = re.compile(r"#\s*protocol-expect:\s*([a-z-]+)\s*$", re.MULTILINE)
_FLAGS_RE = re.compile(r"#\s*protocol-flags:\s*([a-z -]+?)\s*$", re.MULTILINE)


def _fixture_flags(text: str) -> set[str]:
    flags: set[str] = set()
    for m in _FLAGS_RE.findall(text):
        flags.update(m.split())
    return flags


def self_test() -> int:
    bad_dir = REPO_ROOT / "tests/fixtures/protocol_bad"
    good_dir = REPO_ROOT / "tests/fixtures/protocol_good"
    failures: list[str] = []
    covered: set[str] = set()

    bad = sorted(bad_dir.glob("*.py"))
    if not bad:
        failures.append(f"no bad fixtures found under {bad_dir}")
    for path in bad:
        text = path.read_text(encoding="utf-8")
        expected = set(_EXPECT_RE.findall(text))
        if not expected:
            failures.append(
                f"{path}: bad fixture declares no '# protocol-expect:' rules")
            continue
        complete = "require-complete" in _fixture_flags(text)
        actual = {v.rule for v in
                  check_paths([path], require_complete=complete)}
        if actual != expected:
            failures.append(
                f"{path}: expected rule set {sorted(expected)}, checker "
                f"produced {sorted(actual)}")
        covered |= expected & actual

    for path in sorted(good_dir.glob("*.py")):
        text = path.read_text(encoding="utf-8")
        complete = "require-complete" in _fixture_flags(text)
        for v in check_paths([path], require_complete=complete):
            failures.append(f"{path}: good fixture tripped {v}")

    missing = set(PROTOCOL_RULES) - covered
    if missing:
        failures.append(
            f"rules with no seeded bad-fixture coverage: {sorted(missing)} "
            f"(add a planted violation under {bad_dir})")

    if failures:
        print("protocol self-test FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 2
    print(f"protocol self-test ok: {len(bad)} bad fixtures, "
          f"{len(PROTOCOL_RULES)} rules covered")
    return 0


def main(argv: list[str]) -> int:
    if "--help" in argv or "-h" in argv:
        print(__doc__)
        return 0
    as_json = "--json" in argv
    argv = [a for a in argv if a != "--json"]
    if "--self-test" in argv:
        rest = [a for a in argv if a != "--self-test"]
        if rest:
            print(f"error: --self-test takes no paths, got {rest!r}",
                  file=sys.stderr)
            return 2
        return self_test()
    unknown = [a for a in argv if a.startswith("-")]
    if unknown:
        print(f"error: unknown flag(s) {unknown!r}; see --help",
              file=sys.stderr)
        return 2
    if argv:
        targets = [pathlib.Path(a) for a in argv]
        require_complete = False
    else:
        targets = default_targets()
        require_complete = True
    missing = [t for t in targets if not t.is_file()]
    if missing:
        print(f"error: no such file(s): {[str(m) for m in missing]}",
              file=sys.stderr)
        return 2
    violations = check_paths(targets, require_complete=require_complete)
    if as_json:
        print(json.dumps({
            "violations": [
                {"path": v.path, "line": v.lineno, "rule": v.rule,
                 "message": v.message}
                for v in violations
            ],
            "files": len(targets),
        }, indent=2))
    else:
        for v in violations:
            print(v)
    if violations:
        print(f"{len(violations)} protocol violation(s) in "
              f"{len(targets)} file(s)", file=sys.stderr)
        return 1
    if not as_json:
        print(f"protocol ok: {len(targets)} files conform to the WAL spec")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
