#!/usr/bin/env python3
"""Bench-regression gate: diff a ``--json`` bench run against the baseline.

    python scripts/check_bench.py CURRENT.json BENCH_BASELINE.json [--tol 0.35]

Compares every baseline row (by name, honoring duplicates in emission order)
against the current run:

* rows missing from the current run fail (a bench silently stopped running —
  exactly the hole the zero-match filter fix closes at the harness level);
* the ``derived`` field is parsed as ``key=value;key=value``: numeric values
  must agree within ``--tol`` relative tolerance, non-numeric values (claim
  strings like ``ok``/``lower``/``true``) must match exactly;
* **timing-dependent fields are skipped**: any key ending in ``_s`` (wall
  seconds) and the keys ``speedup``/``pace``/``us``, plus the whole
  ``us_per_call`` column — CI runners' wall-clock is noise, but the modeled
  metrics (amp, kops, probes, device/meta bytes, ``model_*_us`` overlap
  times) are deterministic byte-accounting and *are* gated;
* rows present only in the current run warn (new benches don't fail the gate;
  refresh the baseline to start gating them:
  ``PYTHONPATH=src python -m benchmarks.run --smoke --json BENCH_BASELINE.json``);
* per-engine rows are keyed on their full id including the engine-config tag
  after ``@`` (``repro.api.EngineConfig.tag()``, e.g. ``...@hash4+serial``) —
  an engine-config change renames the row and fails loudly as missing+new
  instead of silently gating different configurations against each other.

The default tolerance is intentionally generous (the ISSUE's "stop the perf
trajectory being empty" gate, not a bit-exactness oracle — tighten once the
noise floor is known); determinism itself is enforced separately by
``tests/test_determinism.py``.
"""
from __future__ import annotations

import json
import sys

SKIP_KEYS = {"speedup", "pace", "us"}


def parse_derived(derived: str) -> dict[str, str]:
    out: dict[str, str] = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def is_timing_key(key: str) -> bool:
    return key in SKIP_KEYS or key.endswith("_s")


def numeric(v: str) -> float | None:
    try:
        return float(v)
    except ValueError:
        return None


def index_rows(payload: dict) -> dict[tuple[str, int], dict]:
    """Rows keyed by (name, occurrence): some benches emit a name twice."""
    seen: dict[str, int] = {}
    out: dict[tuple[str, int], dict] = {}
    for row in payload["rows"]:
        n = seen.get(row["name"], 0)
        seen[row["name"]] = n + 1
        out[(row["name"], n)] = row
    return out


def is_informational(name: str) -> bool:
    """Rows whose presence/values are host-load-dependent, never gated: the
    benches' ``*:gate`` status rows (speedup applied vs skipped).  Gate ids
    put ``:gate`` after the engine-config tag (``<prefix>@<tag>:gate``), so
    the full-id suffix check covers tagged and untagged forms alike."""
    return name.endswith(":gate")


def compare(current: dict, baseline: dict, tol: float) -> tuple[list[str], list[str]]:
    problems: list[str] = []
    warnings: list[str] = []
    cur = {k: v for k, v in index_rows(current).items() if not is_informational(k[0])}
    base = {k: v for k, v in index_rows(baseline).items() if not is_informational(k[0])}
    for key, brow in base.items():
        name = f"{key[0]}#{key[1]}" if key[1] else key[0]
        crow = cur.get(key)
        if crow is None:
            problems.append(f"missing row: {name} (bench no longer emits it)")
            continue
        bvals, cvals = parse_derived(brow["derived"]), parse_derived(crow["derived"])
        # claim rows carry bare strings (e.g. 'ok', 'CLAIM-FAILED:...'), not k=v
        if not bvals and brow["derived"] != crow["derived"]:
            problems.append(f"{name}: derived {crow['derived']!r} != baseline {brow['derived']!r}")
            continue
        for k, bv in bvals.items():
            if is_timing_key(k):
                continue
            cv = cvals.get(k)
            if cv is None:
                problems.append(f"{name}: field {k} disappeared (baseline {bv})")
                continue
            bn, cn = numeric(bv), numeric(cv)
            if bn is None or cn is None:
                if bv != cv:
                    problems.append(f"{name}: {k}={cv!r} != baseline {bv!r}")
                continue
            rel = abs(cn - bn) / max(abs(cn), abs(bn), 1e-12)
            if rel > tol:
                problems.append(
                    f"{name}: {k}={cn:g} vs baseline {bn:g} "
                    f"(rel diff {rel:.2f} > tol {tol})"
                )
    for key in cur.keys() - base.keys():
        warnings.append(f"new row not in baseline (not gated): {key[0]}#{key[1]}")
    if current.get("failures"):
        problems.append(f"bench failures: {current['failures']}")
    return problems, warnings


def main(argv: list[str]) -> int:
    tol = 0.35
    args: list[str] = []
    it = iter(argv)
    for a in it:
        if a == "--tol":
            try:
                tol = float(next(it))
            except (StopIteration, ValueError):
                print("error: --tol needs a number", file=sys.stderr)
                return 2
        else:
            args.append(a)
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    loaded = []
    for role, path in (("current", args[0]), ("baseline", args[1])):
        try:
            with open(path) as f:
                loaded.append(json.load(f))
        except OSError as exc:
            print(f"error: cannot read {role} file {path}: {exc.strerror or exc}",
                  file=sys.stderr)
            if role == "baseline":
                print("hint: regenerate the baseline with\n"
                      "  PYTHONPATH=src python -m benchmarks.run --smoke "
                      "--json BENCH_BASELINE.json", file=sys.stderr)
            return 2
        except json.JSONDecodeError as exc:
            print(f"error: {role} file {path} is not valid JSON: {exc}",
                  file=sys.stderr)
            if role == "baseline":
                print("hint: regenerate the baseline with\n"
                      "  PYTHONPATH=src python -m benchmarks.run --smoke "
                      "--json BENCH_BASELINE.json", file=sys.stderr)
            return 2
    current, baseline = loaded
    problems, warnings = compare(current, baseline, tol)
    for w in warnings:
        print(f"WARN  {w}")
    for p in problems:
        print(f"FAIL  {p}")
    checked = len(baseline["rows"])
    if problems:
        print(f"bench gate: {len(problems)} problem(s) across {checked} baseline rows")
        return 1
    print(f"bench gate: OK ({checked} baseline rows, tol {tol}, {len(warnings)} warning(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
