#!/usr/bin/env python3
"""Contract-linter CLI: the CI hard gate for the engine's invariants.

    python scripts/lint_contracts.py               # lint the default targets
    python scripts/lint_contracts.py --self-test   # prove every rule fires
    python scripts/lint_contracts.py --json        # machine-readable output
    python scripts/lint_contracts.py path.py ...   # lint explicit files

Default targets are the modeled-path modules: ``src/repro/core/*.py`` plus
``src/repro/api.py``.  (``src/repro/analysis`` is *not* a target: the race
detector legitimately creates lock wrappers.)  Exit codes: 0 clean,
1 violations found, 2 self-test/usage failure.

``--self-test`` runs the seeded-violation fixture suite so rules cannot
silently rot: every ``tests/fixtures/lint_bad/*.py`` declares the rules it
plants with ``# lint-expect: <rule>`` lines and must produce exactly that rule
set; every ``tests/fixtures/lint_good/*.py`` must lint clean; and every
registered rule must be covered by at least one bad fixture.

``--json`` emits ``{"violations": [{"path", "line", "rule", "message"}],
"files": N}`` (the same shape as ``scripts/check_protocol.py --json``); the
default text format (``path:line: [rule] message``) is matched by
``.github/problem-matchers/repro-analysis.json`` so CI annotates the
offending diff lines.
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis.lint import RULES, lint_paths  # noqa: E402

_EXPECT_RE = re.compile(r"#\s*lint-expect:\s*([a-z-]+)\s*$", re.MULTILINE)


def default_targets() -> list[pathlib.Path]:
    targets = sorted((REPO_ROOT / "src/repro/core").glob("*.py"))
    targets.append(REPO_ROOT / "src/repro/api.py")
    return targets


def self_test() -> int:
    bad_dir = REPO_ROOT / "tests/fixtures/lint_bad"
    good_dir = REPO_ROOT / "tests/fixtures/lint_good"
    failures: list[str] = []
    covered: set[str] = set()

    bad = sorted(bad_dir.glob("*.py"))
    if not bad:
        failures.append(f"no bad fixtures found under {bad_dir}")
    for path in bad:
        expected = set(_EXPECT_RE.findall(path.read_text(encoding="utf-8")))
        if not expected:
            failures.append(f"{path}: bad fixture declares no '# lint-expect:' rules")
            continue
        actual = {v.rule for v in lint_paths([path])}
        if actual != expected:
            failures.append(
                f"{path}: expected rule set {sorted(expected)}, linter produced "
                f"{sorted(actual)}")
        covered |= expected & actual

    for path in sorted(good_dir.glob("*.py")):
        got = lint_paths([path])
        for v in got:
            failures.append(f"{path}: good fixture tripped {v}")

    missing = {r.name for r in RULES} - covered
    if missing:
        failures.append(
            f"rules with no seeded bad-fixture coverage: {sorted(missing)} "
            f"(add a planted violation under {bad_dir})")

    if failures:
        print("lint self-test FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 2
    print(f"lint self-test ok: {len(bad)} bad fixtures, "
          f"{len(RULES)} rules covered")
    return 0


def main(argv: list[str]) -> int:
    if "--help" in argv or "-h" in argv:
        print(__doc__)
        return 0
    as_json = "--json" in argv
    argv = [a for a in argv if a != "--json"]
    if "--self-test" in argv:
        rest = [a for a in argv if a != "--self-test"]
        if rest:
            print(f"error: --self-test takes no paths, got {rest!r}", file=sys.stderr)
            return 2
        return self_test()
    unknown = [a for a in argv if a.startswith("-")]
    if unknown:
        print(f"error: unknown flag(s) {unknown!r}; see --help", file=sys.stderr)
        return 2
    targets = [pathlib.Path(a) for a in argv] if argv else default_targets()
    missing = [t for t in targets if not t.is_file()]
    if missing:
        print(f"error: no such file(s): {[str(m) for m in missing]}", file=sys.stderr)
        return 2
    violations = lint_paths(targets)
    if as_json:
        print(json.dumps({
            "violations": [
                {"path": v.path, "line": v.lineno, "rule": v.rule,
                 "message": v.message}
                for v in violations
            ],
            "files": len(targets),
        }, indent=2))
    else:
        for v in violations:
            print(v)
    if violations:
        print(f"{len(violations)} contract violation(s) in {len(targets)} file(s)",
              file=sys.stderr)
        return 1
    if not as_json:
        print(f"contracts ok: {len(targets)} files clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
