"""Quickstart: the two halves of the repo in ~60 seconds on CPU.

1. The paper's KV store: hybrid placement vs baselines on a mixed workload.
2. The training framework: a reduced assigned-architecture, a few steps.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import repro.api as api
from repro.configs import ARCHS
from repro.core import StoreConfig
from repro.core.ycsb import Workload
from repro.data.pipeline import DataConfig, host_batch
from repro.models import get_model
from repro.optim import adamw
from repro.train.step import make_train_fn


def kv_store_demo() -> None:
    print("=== Parallax hybrid KV placement vs baselines (SD mix, scaled) ===")
    for mode in ("parallax", "rocksdb", "blobdb"):
        cfg = api.EngineConfig(store=StoreConfig(
            mode=mode, l0_capacity=1 << 14, growth_factor=4,
            cache_bytes=1 << 17, segment_bytes=1 << 17, chunk_bytes=1 << 13,
        ))
        with api.open(cfg) as db:
            api.execute(db, Workload("load_a", "SD", num_keys=4000, num_ops=0).load_ops())
            api.execute(db, Workload("run_a", "SD", num_keys=4000, num_ops=2000).run_ops())
            print(f"  {mode:9s} I/O amplification = {db.amplification():6.2f} "
                  f"(levels={[len(l) for l in db.store.levels]})")


def train_demo() -> None:
    print("=== Train a reduced qwen2.5 config for 20 steps ===")
    cfg = ARCHS["qwen2.5-3b"].reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    step_fn = jax.jit(make_train_fn(cfg, adamw.AdamWConfig(lr=3e-3, warmup_steps=5)))
    dcfg = DataConfig(seq_len=32, global_batch=4)
    for step in range(20):
        batch = {k: jnp.asarray(v) for k, v in host_batch(cfg, dcfg, step % 4).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 5 == 0 or step == 19:
            print(f"  step {step:3d} loss={float(metrics['loss']):.3f} "
                  f"lr={float(metrics['lr']):.2e}")


if __name__ == "__main__":
    kv_store_demo()
    train_demo()
    print("done.")
