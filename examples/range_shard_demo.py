"""Range vs hash sharding behind one engine API: scan locality, lazy
iterators, and skew-driven rebalancing.

    PYTHONPATH=src python examples/range_shard_demo.py
"""
import repro.api as api
from repro.core import StoreConfig
from repro.core.ycsb import Workload, make_key

STORE = StoreConfig(
    l0_capacity=1 << 13, growth_factor=4, cache_bytes=1 << 17,
    segment_bytes=1 << 17, chunk_bytes=1 << 13, bloom_bits_per_key=10,
)
KEYS = 4000


def main() -> None:
    load = Workload("load_e", "SD", num_keys=KEYS, num_ops=0)
    run_e = Workload("run_e", "SD", num_keys=KEYS, num_ops=1500)
    sample = [make_key(i) for i in range(KEYS)]

    print("=== hash sharding: every scan fans out to all shards ===")
    with api.open(api.EngineConfig(store=STORE, partitioning="hash:4",
                                   batch_size=64)) as hashed:
        api.execute(hashed, load.load_ops())
        api.execute(hashed, run_e.run_ops())
        f = hashed.stats()["frontend"]
        print(f"  scans={f['scans']} probes={f['scan_probes']} "
              f"probes/scan={f['scan_probes'] / max(1, f['scans']):.2f}")
        head = hashed.scan(b"", 100)

    print("=== range sharding: scans touch only overlapping shards ===")
    ranged_part = api.PartitioningConfig.range_for_keys(sample, 4)
    with api.open(api.EngineConfig(store=STORE, partitioning=ranged_part,
                                   batch_size=64)) as ranged:
        api.execute(ranged, load.load_ops())
        api.execute(ranged, run_e.run_ops())
        f = ranged.stats()["frontend"]
        print(f"  scans={f['scans']} probes={f['scan_probes']} "
              f"probes/scan={f['scan_probes'] / max(1, f['scans']):.2f}")
        assert ranged.scan(b"", 100) == head  # partitioning is invisible

        print("=== lazy iterator: stream rows without materializing scans ===")
        it = ranged.iterator(make_key(KEYS // 2))
        rows = 0
        while it.valid() and rows < 5:
            print(f"  {it.key()[:12].decode()}... {len(it.value())}B")
            it.next()
            rows += 1

    print("=== skew repair: a degenerate one-hot map splits under load ===")
    adaptive_cfg = api.EngineConfig(
        store=STORE,
        partitioning=api.PartitioningConfig(
            scheme="range", shards=4, rebalance_window=500, max_shards=16),
        batch_size=64,
    )
    with api.open(adaptive_cfg) as adaptive:
        store = adaptive.store
        one_hot = {store.shard_of(make_key(i)) for i in range(KEYS)}
        print(f"  before: all {KEYS} keys land on shard(s) {sorted(one_hot)}")
        api.execute(adaptive, load.load_ops())
        api.execute(adaptive, run_e.run_ops())
        topo = adaptive.stats()["topology"]
        per_shard = [
            len(s.live_keys_in(*store.bounds(i))) for i, s in enumerate(store.shards)
        ]
        print(f"  after:  splits={topo['splits']} merges={topo['merges']} "
              f"migrated={topo['migrated_keys']} keys/shard={per_shard}")

        print("=== crash mid-everything: prefix-consistent recovery per shard ===")
        adaptive.flush_all()
        cutoffs = adaptive.crash()
        adaptive.recover()
        head = [k[:10] for k, _ in adaptive.scan(b"", 3)]
        print(f"  recovered {len(cutoffs)} shards; scan head: {head}")


if __name__ == "__main__":
    main()
