"""Range vs hash sharding: scan locality and skew-driven rebalancing.

    PYTHONPATH=src python examples/range_shard_demo.py
"""
from repro.core import RangeShardedStore, ShardedStore, StoreConfig
from repro.core.ycsb import Workload, execute, make_key

CFG = StoreConfig(
    l0_capacity=1 << 13, growth_factor=4, cache_bytes=1 << 17,
    segment_bytes=1 << 17, chunk_bytes=1 << 13, bloom_bits_per_key=10,
)
KEYS = 4000


def main() -> None:
    load = Workload("load_e", "SD", num_keys=KEYS, num_ops=0)
    run_e = Workload("run_e", "SD", num_keys=KEYS, num_ops=1500)

    print("=== hash sharding: every scan fans out to all shards ===")
    hashed = ShardedStore(4, CFG)
    execute(hashed, load.load_ops(), batch_size=64)
    execute(hashed, run_e.run_ops(), batch_size=64)
    print(f"  scans={hashed.scans} probes={hashed.scan_probes} "
          f"probes/scan={hashed.scan_probes / max(1, hashed.scans):.2f}")

    print("=== range sharding: scans touch only overlapping shards ===")
    ranged = RangeShardedStore.for_keys([make_key(i) for i in range(KEYS)], 4, CFG)
    execute(ranged, load.load_ops(), batch_size=64)
    execute(ranged, run_e.run_ops(), batch_size=64)
    print(f"  scans={ranged.scans} probes={ranged.scan_probes} "
          f"probes/scan={ranged.scan_probes / max(1, ranged.scans):.2f}")
    assert ranged.scan(b"", 100) == hashed.scan(b"", 100)

    print("=== skew repair: a degenerate one-hot map splits under load ===")
    adaptive = RangeShardedStore(4, CFG, rebalance_window=500, max_shards=16)
    one_hot = {adaptive.shard_of(make_key(i)) for i in range(KEYS)}
    print(f"  before: all {KEYS} keys land on shard(s) {sorted(one_hot)}")
    execute(adaptive, load.load_ops(), batch_size=64)
    execute(adaptive, run_e.run_ops(), batch_size=64)
    per_shard = [
        len(s.live_keys_in(*adaptive.bounds(i))) for i, s in enumerate(adaptive.shards)
    ]
    print(f"  after:  splits={adaptive.splits} merges={adaptive.merges} "
          f"migrated={adaptive.migrated_keys} keys/shard={per_shard}")

    print("=== crash mid-everything: prefix-consistent recovery per shard ===")
    adaptive.flush_all()
    cutoffs = adaptive.crash()
    adaptive.recover()
    head = [k[:10] for k, _ in adaptive.scan(b"", 3)]
    print(f"  recovered {len(cutoffs)} shards; scan head: {head}")


if __name__ == "__main__":
    main()
