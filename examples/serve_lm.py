"""Serving example: batched prefill+decode with hybrid KV-cache placement.

Shows the paper's placement classes in action on the serving side: short
prompts land in the slab, medium in the transient arena (wholesale reclaim),
long in the paged pool (free-list GC).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import get_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    cfg = ARCHS["qwen3-8b"].reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=96, batch_size=4)

    prompts = [
        jnp.asarray([1, 5, 9, 2, 7, 3, 8, 4], jnp.int32),
        jnp.asarray([2, 4, 6, 8, 10, 12, 14, 16], jnp.int32),
        jnp.asarray([11, 3, 5, 7, 1, 9, 13, 2], jnp.int32),
        jnp.asarray([42, 17, 23, 5, 99, 100, 3, 8], jnp.int32),
    ]
    reqs = [Request(i, p, max_new_tokens=12) for i, p in enumerate(prompts)]
    done = eng.run_batch(reqs)
    for r in done:
        print(f"seq {r.seq_id}: prompt={list(map(int, r.prompt))[:4]}... -> {r.output}")
    print("cache manager:", eng.cache_mgr.stats())


if __name__ == "__main__":
    main()
