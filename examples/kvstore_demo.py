"""Parallax KV-store walkthrough on the unified engine API: hybrid placement,
write batches, iterators, GC, crash recovery.

    PYTHONPATH=src python examples/kvstore_demo.py
"""
import repro.api as api
from repro.core import StoreConfig
from repro.core.ycsb import Workload, payload


def main() -> None:
    cfg = api.EngineConfig(store=StoreConfig(
        mode="parallax", l0_capacity=1 << 14, growth_factor=4,
        cache_bytes=1 << 17, segment_bytes=1 << 17, chunk_bytes=1 << 13,
    ))
    with api.open(cfg) as db:
        print("=== load a medium-dominated workload ===")
        api.execute(db, Workload("load_a", "MD", num_keys=5000, num_ops=0).load_ops())
        s = db.store.checkpoint_stats()
        print(f"levels={s['levels']} medium_segments={s['medium_log_segments']} "
              f"large_segments={s['large_log_segments']} amp={s['amplification']:.2f}")

        print("=== a write batch across the three categories ===")
        with db.write_batch() as wb:
            wb.put(b"small-key-000000000000", payload(9))
            wb.put(b"medium-key-00000000000", payload(104))
            wb.put(b"large-key-000000000000", payload(1004))
        for k in (b"small-key-000000000000", b"medium-key-00000000000", b"large-key-000000000000"):
            v = db.get(k)
            print(f"  get {k.decode():24s} -> {len(v)}B")

        print("=== updates create garbage; GC reclaims large-log segments ===")
        for _ in range(3):
            with db.write_batch() as wb:
                for i in range(500):
                    wb.update(f"user{i:019d}".encode(), payload(1004))
        before = len(db.store.large_log.segments)
        reclaimed = db.gc_tick()
        stats = db.stats()["store"]
        print(f"  segments before={before} reclaimed={reclaimed} "
              f"gc_lookups={stats['gc_lookups']} relocations={stats['gc_relocations']}")

        print("=== crash / prefix-consistent recovery ===")
        db.put(b"durable-key-0000000000", payload(104))
        cutoff = db.crash()
        db.recover()
        it = db.iterator()
        head = []
        while it.valid() and len(head) < 3:
            head.append(it.key()[:12])
            it.next()
        print(f"  recovered to LSN {cutoff} (of {db.store.lsn}); scan head: {head}")
        print(f"final amplification: {db.amplification():.2f}")


if __name__ == "__main__":
    main()
