"""Parallax KV-store walkthrough: hybrid placement, GC, crash recovery.

    PYTHONPATH=src python examples/kvstore_demo.py
"""
from repro.core import ParallaxStore, StoreConfig
from repro.core.ycsb import Workload, execute, payload


def main() -> None:
    st = ParallaxStore(StoreConfig(
        mode="parallax", l0_capacity=1 << 14, growth_factor=4,
        cache_bytes=1 << 17, segment_bytes=1 << 17, chunk_bytes=1 << 13,
    ))

    print("=== load a medium-dominated workload ===")
    execute(st, Workload("load_a", "MD", num_keys=5000, num_ops=0).load_ops())
    s = st.checkpoint_stats()
    print(f"levels={s['levels']} medium_segments={s['medium_log_segments']} "
          f"large_segments={s['large_log_segments']} amp={s['amplification']:.2f}")

    print("=== point ops across the three categories ===")
    st.put(b"small-key-000000000000", payload(9))
    st.put(b"medium-key-00000000000", payload(104))
    st.put(b"large-key-000000000000", payload(1004))
    for k in (b"small-key-000000000000", b"medium-key-00000000000", b"large-key-000000000000"):
        v = st.get(k)
        print(f"  get {k.decode():24s} -> {len(v)}B")

    print("=== updates create garbage; GC reclaims large-log segments ===")
    for _ in range(3):
        for i in range(500):
            st.update(f"user{i:019d}".encode(), payload(1004))
    before = len(st.large_log.segments)
    reclaimed = st.gc_tick()
    print(f"  segments before={before} reclaimed={reclaimed} "
          f"gc_lookups={st.stats.gc_lookups} relocations={st.stats.gc_relocations}")

    print("=== crash / prefix-consistent recovery ===")
    st.put(b"durable-key-0000000000", payload(104))
    cutoff = st.crash()
    st.recover()
    print(f"  recovered to LSN {cutoff} (of {st.lsn}); "
          f"scan head: {[k[:12] for k, _ in st.scan(b'', 3)]}")
    print(f"final amplification: {st.amplification():.2f}")


if __name__ == "__main__":
    main()
