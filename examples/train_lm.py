"""End-to-end training driver: data pipeline -> sharded train step ->
LSM incremental checkpointing -> crash recovery -> straggler accounting.

Presets:
    smoke (default): ~8M-param qwen2.5-family model, 120 steps, ~2 min CPU.
    100m:            ~100M-param config, few hundred steps (hours on CPU;
                     the real target is the TPU mesh via repro.launch).

    PYTHONPATH=src python examples/train_lm.py --steps 120
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, host_batch
from repro.elastic.remap import StragglerPolicy
from repro.models import get_model
from repro.optim import adamw
from repro.train.step import make_train_fn


def make_config(preset: str):
    base = ARCHS["qwen2.5-3b"]
    if preset == "smoke":
        return dataclasses.replace(
            base.reduced(), name="qwen2.5-smoke", num_layers=4, d_model=128,
            num_heads=4, num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=2048,
        )
    # ~100M: 12L x 512d x 2048ff, 32k vocab
    return dataclasses.replace(
        base, name="qwen2.5-100m", num_layers=12, d_model=512, num_heads=8,
        num_kv_heads=2, head_dim=64, d_ff=2048, vocab_size=32768,
        param_dtype="float32", compute_dtype="float32", remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["smoke", "100m"], default="smoke")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-lm")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = make_config(args.preset)
    model = get_model(cfg)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_fn(cfg, ocfg), donate_argnums=(0, 1))
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch)
    mgr = CheckpointManager(args.ckpt_dir, consolidate_every=4)
    straggler = StragglerPolicy()

    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    nparams = sum(int(jnp.size(x)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={nparams/1e6:.1f}M steps={args.steps}")

    start = 0
    if args.resume:
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            {"params": params, "opt": opt})
        restored, start = mgr.restore(like)
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from checkpoint at step {start}")

    t_start = time.time()
    for step in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in host_batch(cfg, dcfg, step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        straggler.observe(jax.process_index(), time.time() - t0)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"{(time.time()-t0)*1e3:.0f}ms")
        if step and step % args.ckpt_every == 0:
            stats = mgr.save(step, {"params": params, "opt": opt})
            print(f"  checkpointed @{step}: {stats} "
                  f"write_amp={mgr.stats()['write_amplification']:.2f}")
    tok_s = (args.steps - start) * args.batch * args.seq / (time.time() - t_start)
    print(f"done: {tok_s:.0f} tokens/s; stragglers flagged: {straggler.stragglers()}")


if __name__ == "__main__":
    main()
