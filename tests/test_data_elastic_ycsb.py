"""Data pipeline determinism, elastic rescale planner, YCSB generator."""
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.ycsb import MIXES, Workload, ZipfGenerator
from repro.data.pipeline import DataConfig, host_batch
from repro.elastic.remap import RescaleState, Topology, plan_rescale


# ------------------------------------------------------------------ pipeline
def test_pipeline_deterministic_and_host_disjoint():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    d = DataConfig(seq_len=32, global_batch=8, seed=1)
    a1 = host_batch(cfg, d, step=5, host_id=0, num_hosts=4)
    a2 = host_batch(cfg, d, step=5, host_id=0, num_hosts=4)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])  # restart-stable
    b = host_batch(cfg, d, step=5, host_id=1, num_hosts=4)
    assert not np.array_equal(a1["tokens"], b["tokens"])       # hosts differ
    c = host_batch(cfg, d, step=6, host_id=0, num_hosts=4)
    assert not np.array_equal(a1["tokens"], c["tokens"])       # steps differ
    assert a1["tokens"].shape == (2, 32)
    assert np.array_equal(a1["tokens"][:, 1:], a1["labels"][:, :-1])


def test_pipeline_modality_stubs():
    vlm = ARCHS["internvl2-26b"].reduced()
    b = host_batch(vlm, DataConfig(16, 4), step=0)
    assert b["patch_embeds"].shape == (4, vlm.num_patches, vlm.d_model)
    aud = ARCHS["whisper-medium"].reduced()
    b = host_batch(aud, DataConfig(16, 4), step=0)
    assert b["frame_embeds"].shape == (4, aud.encoder_frames, aud.d_model)


# ------------------------------------------------------------------- elastic
def test_hash_grow_moves_minimal_fraction():
    plan = plan_rescale(Topology("hash", 4), 8)
    assert plan.new_shards == 8 and len(plan.legs) == 4
    # consistent-hashing-style property of mod routing: each new slot j
    # pulls only from j mod N, moving (M-N)/M of the keys
    assert {(l.src, l.dst) for l in plan.legs} == {(0, 4), (1, 5), (2, 6), (3, 7)}
    assert plan.moved_fraction == pytest.approx(0.5)


def test_hash_shrink_is_divisor_only():
    plan = plan_rescale(Topology("hash", 8), 2)
    assert {(l.src, l.dst) for l in plan.legs} == {
        (2, 0), (3, 1), (4, 0), (5, 1), (6, 0), (7, 1)}
    assert plan.moved_fraction == pytest.approx(0.75)
    with pytest.raises(ValueError, match="multiple or divisor"):
        plan_rescale(Topology("hash", 4), 6)


def test_range_grow_cuts_heaviest_ranges():
    topo = Topology("range", 2, (b"", b"m"))
    ks = [b"a%03d" % i for i in range(40)] + [b"z0", b"z1"]
    plan = plan_rescale(topo, 4, key_sample=ks)
    assert plan.new_shards == 4 and len(plan.legs) == 2
    assert len(plan.boundaries) == 4 and plan.boundaries[0] == b""
    # both cuts land in the heavy a-range; keys outside cut spans never move
    assert all(b"" < b < b"m" for b in plan.boundaries[1:3])
    assert 0 < plan.moved_fraction < 1


def test_range_shrink_merges_lightest_nonadjacent_pairs():
    topo = Topology("range", 4, (b"", b"b", b"c", b"d"))
    ks = [b"a%02d" % i for i in range(30)] + [b"b0", b"c0", b"d0"]
    plan = plan_rescale(topo, 2, key_sample=ks)
    assert len(plan.legs) == 2 and len(plan.boundaries) == 2
    assert all(l.kind == "merge" for l in plan.legs)
    with pytest.raises(ValueError, match="stepwise"):
        plan_rescale(topo, 1, key_sample=ks)


def test_noop_and_state_progress():
    plan = plan_rescale(Topology("hash", 4), 4)
    assert plan.legs == () and plan.moved_fraction == 0.0
    st = RescaleState(plan_rescale(Topology("hash", 2), 4), budget=4096)
    assert st.legs_total == 2 and not st.done
    st.legs_done = 2
    assert st.done
    p = st.progress()
    assert p["from_shards"] == 2 and p["to_shards"] == 4
    assert p["budget"] == 4096 and p["legs_done"] == 2


# ---------------------------------------------------------------------- ycsb
def test_ycsb_load_covers_keyspace():
    w = Workload("load_a", "SD", num_keys=500, num_ops=0, seed=3)
    ops = list(w.load_ops())
    assert len(ops) == 500
    assert len({o.key for o in ops}) == 500
    sizes = {o.value_size for o in ops}
    assert sizes <= {9, 104, 1004}


def test_ycsb_mix_fractions():
    w = Workload("load_a", "MD", num_keys=4000, num_ops=0, seed=4)
    ops = list(w.load_ops())
    med = sum(1 for o in ops if o.value_size == 104) / len(ops)
    assert 0.5 < med < 0.7  # MD: 60% medium


def test_ycsb_run_a_op_mix():
    w = Workload("run_a", "S", num_keys=1000, num_ops=4000, seed=5)
    ops = list(w.run_ops())
    upd = sum(1 for o in ops if o.kind == "update") / len(ops)
    rd = sum(1 for o in ops if o.kind == "read") / len(ops)
    assert 0.45 < upd < 0.55 and 0.45 < rd < 0.55


def test_ycsb_deterministic():
    w1 = list(Workload("run_b", "LD", 100, 200, seed=9).run_ops())
    w2 = list(Workload("run_b", "LD", 100, 200, seed=9).run_ops())
    assert [(o.kind, o.key) for o in w1] == [(o.kind, o.key) for o in w2]


def test_zipf_is_skewed():
    z = ZipfGenerator(1000, seed=0)
    samples = z.sample(20000)
    _, counts = np.unique(samples, return_counts=True)
    top = np.sort(counts)[::-1]
    assert top[:10].sum() > 0.2 * len(samples)  # hot keys dominate


def test_all_mixes_defined():
    assert set(MIXES) == {"S", "M", "L", "SD", "MD", "LD"}
    for s, m, l in MIXES.values():
        assert s + m + l == 100
