"""Data pipeline determinism, elastic/straggler policies, YCSB generator."""
import numpy as np

from repro.configs import ARCHS
from repro.core.ycsb import MIXES, Workload, ZipfGenerator
from repro.data.pipeline import DataConfig, host_batch
from repro.elastic.remap import StragglerPolicy, shrink_mesh


# ------------------------------------------------------------------ pipeline
def test_pipeline_deterministic_and_host_disjoint():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    d = DataConfig(seq_len=32, global_batch=8, seed=1)
    a1 = host_batch(cfg, d, step=5, host_id=0, num_hosts=4)
    a2 = host_batch(cfg, d, step=5, host_id=0, num_hosts=4)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])  # restart-stable
    b = host_batch(cfg, d, step=5, host_id=1, num_hosts=4)
    assert not np.array_equal(a1["tokens"], b["tokens"])       # hosts differ
    c = host_batch(cfg, d, step=6, host_id=0, num_hosts=4)
    assert not np.array_equal(a1["tokens"], c["tokens"])       # steps differ
    assert a1["tokens"].shape == (2, 32)
    assert np.array_equal(a1["tokens"][:, 1:], a1["labels"][:, :-1])


def test_pipeline_modality_stubs():
    vlm = ARCHS["internvl2-26b"].reduced()
    b = host_batch(vlm, DataConfig(16, 4), step=0)
    assert b["patch_embeds"].shape == (4, vlm.num_patches, vlm.d_model)
    aud = ARCHS["whisper-medium"].reduced()
    b = host_batch(aud, DataConfig(16, 4), step=0)
    assert b["frame_embeds"].shape == (4, aud.encoder_frames, aud.d_model)


# ------------------------------------------------------------------- elastic
def test_shrink_mesh_prefers_model_axis():
    m = shrink_mesh(1, prefer_model=16)
    assert m.shape["model"] == 1 and m.shape["data"] == 1


def test_straggler_policy_flags_and_rebalances():
    pol = StragglerPolicy(threshold=1.5, min_samples=3)
    for step in range(5):
        for h in range(4):
            pol.observe(h, 1.0 if h != 2 else 3.0)
    assert pol.stragglers() == [2]
    alloc = pol.rebalance(256, [0, 1, 2, 3])
    assert sum(alloc.values()) == 256
    assert alloc[2] < alloc[0]  # straggler gets less work
    assert min(alloc.values()) >= 1


def test_straggler_policy_quiet_when_uniform():
    pol = StragglerPolicy()
    for step in range(5):
        for h in range(4):
            pol.observe(h, 1.0 + 0.01 * h)
    assert pol.stragglers() == []
    alloc = pol.rebalance(64, [0, 1, 2, 3])
    assert all(v == 16 for v in alloc.values())


# ---------------------------------------------------------------------- ycsb
def test_ycsb_load_covers_keyspace():
    w = Workload("load_a", "SD", num_keys=500, num_ops=0, seed=3)
    ops = list(w.load_ops())
    assert len(ops) == 500
    assert len({o.key for o in ops}) == 500
    sizes = {o.value_size for o in ops}
    assert sizes <= {9, 104, 1004}


def test_ycsb_mix_fractions():
    w = Workload("load_a", "MD", num_keys=4000, num_ops=0, seed=4)
    ops = list(w.load_ops())
    med = sum(1 for o in ops if o.value_size == 104) / len(ops)
    assert 0.5 < med < 0.7  # MD: 60% medium


def test_ycsb_run_a_op_mix():
    w = Workload("run_a", "S", num_keys=1000, num_ops=4000, seed=5)
    ops = list(w.run_ops())
    upd = sum(1 for o in ops if o.kind == "update") / len(ops)
    rd = sum(1 for o in ops if o.kind == "read") / len(ops)
    assert 0.45 < upd < 0.55 and 0.45 < rd < 0.55


def test_ycsb_deterministic():
    w1 = list(Workload("run_b", "LD", 100, 200, seed=9).run_ops())
    w2 = list(Workload("run_b", "LD", 100, 200, seed=9).run_ops())
    assert [(o.kind, o.key) for o in w1] == [(o.kind, o.key) for o in w2]


def test_zipf_is_skewed():
    z = ZipfGenerator(1000, seed=0)
    samples = z.sample(20000)
    _, counts = np.unique(samples, return_counts=True)
    top = np.sort(counts)[::-1]
    assert top[:10].sum() > 0.2 * len(samples)  # hot keys dominate


def test_all_mixes_defined():
    assert set(MIXES) == {"S", "M", "L", "SD", "MD", "LD"}
    for s, m, l in MIXES.values():
        assert s + m + l == 100
