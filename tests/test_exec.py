"""Async executor differential oracle: async == serial, byte for byte.

The :class:`~repro.core.exec.ShardExecutor` scheduling discipline (per-shard
FIFO queues, migration-pair queue merging, policy ticks at sequence points)
claims that async execution is *byte-identical* to the serial batched path —
same get results, same scans, same live key sets, same per-shard
``DeviceStats`` totals, same metadata-WAL record stream — for every worker
count, with pipelining on or off, with background migration and GC running,
and across a crash/recover mid-migration.  This module is that claim's
enforcement.  Overlap-policy model unit tests ride along.
"""
import dataclasses
import threading

import pytest

from repro.core import (
    ParallaxStore,
    RangeShardedStore,
    ShardedStore,
    ShardExecutor,
    StoreConfig,
    overlap_time,
)
from repro.core.ycsb import Workload, execute, execute_async, make_key, payload

BATCH = 32


def small_config(**kw) -> StoreConfig:
    defaults = dict(l0_capacity=1 << 12, cache_bytes=1 << 15,
                    segment_bytes=1 << 14, chunk_bytes=1 << 11,
                    bloom_bits_per_key=10)
    defaults.update(kw)
    return StoreConfig(**defaults)


def device_stats_per_store(store) -> list[dict]:
    return [dataclasses.asdict(s.device.stats) for s in store._all_stores()]


def assert_identical(serial, async_, num_keys: int) -> None:
    """Full-state agreement: results, stats, and per-shard device traffic."""
    # device + stats first: the probes below mutate both stores identically
    assert device_stats_per_store(serial) == device_stats_per_store(async_)
    assert dataclasses.asdict(serial.aggregate_stats()) == dataclasses.asdict(async_.aggregate_stats())
    assert (serial.gets, serial.get_probes) == (async_.gets, async_.get_probes)
    assert (serial.scans, serial.scan_probes) == (async_.scans, async_.scan_probes)
    probe = [make_key(i) for i in range(num_keys + 50)]
    assert async_.get_many(probe) == serial.get_many(probe)
    full_s = serial.scan(b"", 2 * num_keys + 100)
    full_a = async_.scan(b"", 2 * num_keys + 100)
    assert full_a == full_s
    keys_only = [k for k, _ in full_s]
    assert keys_only == sorted(set(keys_only))


def load_ops(nk, seed):
    return Workload("load_a", "SD", num_keys=nk, num_ops=0, seed=seed).load_ops()


def run_ops(nk, nops, seed, kind="run_a"):
    return Workload(kind, "SD", num_keys=nk, num_ops=nops, seed=seed).run_ops()


# --------------------------------------------------------------- hash store
@pytest.mark.parametrize("workers,pipeline", [(1, False), (2, True), (4, True), (4, False)])
def test_async_hash_matches_serial(workers, pipeline):
    nk = 400
    serial = ShardedStore(4, small_config())
    async_ = ShardedStore(4, small_config())
    execute(serial, load_ops(nk, 11), batch_size=BATCH)
    execute(serial, run_ops(nk, 300, 11), batch_size=BATCH)
    execute_async(async_, load_ops(nk, 11), batch_size=BATCH,
                  workers=workers, pipeline=pipeline)
    execute_async(async_, run_ops(nk, 300, 11), batch_size=BATCH,
                  workers=workers, pipeline=pipeline)
    assert_identical(serial, async_, nk)


def test_async_hash_background_gc_and_deletes():
    """gc_every fires per-shard background GC tasks on the async path; the
    per-shard projection (and therefore GC traffic) must match serial."""
    nk = 400
    serial = ShardedStore(3, small_config())
    async_ = ShardedStore(3, small_config())
    doomed = [make_key(i) for i in range(50, 350, 3)]
    back = [(make_key(i), payload(1004)) for i in range(60, 300, 5)]  # large values -> log GC work
    for store, driver in ((serial, execute), (async_, None)):
        if driver:
            execute(store, load_ops(nk, 13), batch_size=BATCH, gc_every=64)
            store.delete_many(doomed)
            store.put_many(back)
            execute(store, run_ops(nk, 200, 13, "run_b"), batch_size=BATCH, gc_every=64)
        else:
            execute_async(store, load_ops(nk, 13), batch_size=BATCH, workers=4, gc_every=64)
            store.delete_many(doomed)
            store.put_many(back)
            execute_async(store, run_ops(nk, 200, 13, "run_b"), batch_size=BATCH,
                          workers=4, gc_every=64)
    gc_traffic = sum(d["gc_read"] + d["gc_written"] for d in device_stats_per_store(serial))
    assert gc_traffic > 0  # the oracle only counts if GC really ran
    assert_identical(serial, async_, nk)


def test_async_scan_heavy_matches_serial():
    nk = 400
    serial = ShardedStore(4, small_config())
    async_ = ShardedStore(4, small_config())
    execute(serial, load_ops(nk, 17), batch_size=BATCH)
    execute(serial, run_ops(nk, 200, 17, "run_e"), batch_size=BATCH)
    execute_async(async_, load_ops(nk, 17), batch_size=BATCH, workers=4)
    execute_async(async_, run_ops(nk, 200, 17, "run_e"), batch_size=BATCH, workers=4)
    assert_identical(serial, async_, nk + 200)


# -------------------------------------------------------------- range store
def range_pair(nk, **kw):
    keys = [make_key(i) for i in range(nk)]
    params = dict(rebalance_window=100, split_factor=1.05, merge_factor=0.9,
                  migration_batch_keys=16)
    params.update(kw)
    return (RangeShardedStore.for_keys(keys, 3, small_config(), **params),
            RangeShardedStore.for_keys(keys, 3, small_config(), **params))


@pytest.mark.parametrize("workers", [1, 2, 4])
def test_async_range_background_migration_matches_serial(workers):
    """The headline oracle: live skew rebalancer + throttled migration ticks
    driven as background sequence points — topology evolution, WAL record
    stream, double-routed fallbacks and per-shard traffic all byte-identical
    to serial."""
    nk = 500
    serial, async_ = range_pair(nk)
    execute(serial, load_ops(nk, 19), batch_size=BATCH, migrate_budget=8)
    execute(serial, run_ops(nk, 400, 19), batch_size=BATCH, migrate_budget=8)
    execute_async(async_, load_ops(nk, 19), batch_size=BATCH, workers=workers,
                  migrate_budget=8)
    execute_async(async_, run_ops(nk, 400, 19), batch_size=BATCH, workers=workers,
                  migrate_budget=8)
    assert serial.splits + serial.merges > 0  # the policy really fired
    assert serial.boundaries == async_.boundaries
    assert serial._shard_ids == async_._shard_ids
    assert serial.metalog.records == async_.metalog.records
    assert serial.get_fallbacks == async_.get_fallbacks
    assert serial.migrated_keys == async_.migrated_keys
    assert_identical(serial, async_, nk)


def test_async_range_crash_recover_mid_migration():
    """Crash with a migration in flight on both paths, recover, keep running:
    the async engine's sequence points make crash/recover safe and the
    recovered histories stay identical."""
    nk = 500
    serial, async_ = range_pair(nk, auto_rebalance=False, migration_batch_keys=1)
    execute(serial, load_ops(nk, 23), batch_size=BATCH)
    execute_async(async_, load_ops(nk, 23), batch_size=BATCH, workers=4)
    for st in (serial, async_):
        st.flush_all()
        hot = max(range(st.num_shards),
                  key=lambda i: len(st.shards[i].live_keys_in(*st.bounds(i))))
        assert st._split(hot, background=True)
        st.migration_tick()  # move one batch, leave the rest pending
        assert st.migration is not None
    # traffic over the half-migrated topology, then a crash mid-flight (the
    # 1-key ticks cannot drain the ~80-key migration within 30 ops)
    execute(serial, run_ops(nk, 30, 23), batch_size=BATCH, migrate_budget=1)
    execute_async(async_, run_ops(nk, 30, 23), batch_size=BATCH, workers=4,
                  migrate_budget=1)
    assert serial.migration is not None and async_.migration is not None
    for st in (serial, async_):
        st.crash()
        st.recover()
    assert serial.migration is not None and async_.migration is not None
    assert serial.metalog.records == async_.metalog.records
    # resume: drive the migration to completion under more traffic
    execute(serial, run_ops(nk, 150, 29), batch_size=BATCH, migrate_budget=64)
    execute_async(async_, run_ops(nk, 150, 29), batch_size=BATCH, workers=4,
                  migrate_budget=64)
    serial.drain_migration()
    with ShardExecutor(async_, workers=4) as ex:
        ex.exclusive(async_.drain_migration)
    assert serial.migration is None and async_.migration is None
    assert serial.boundaries == async_.boundaries
    assert_identical(serial, async_, nk)


@pytest.mark.parametrize("workers", [1, 4])
def test_engine_async_range_matches_legacy_serial(workers):
    """PR 5 acceptance: the repro.api engine's async path — persistent
    executor, api.execute driver — is byte-identical to the legacy serial
    range store on the same stream, including the WAL record stream, with
    the skew rebalancer and throttled migration live."""
    import repro.api as api

    nk = 500
    keys = [make_key(i) for i in range(nk)]
    params = dict(rebalance_window=100, split_factor=1.05, merge_factor=0.9,
                  migration_batch_keys=16)
    serial = RangeShardedStore.for_keys(keys, 3, small_config(), **params)
    execute(serial, load_ops(nk, 19), batch_size=BATCH, migrate_budget=8)
    execute(serial, run_ops(nk, 400, 19), batch_size=BATCH, migrate_budget=8)
    cfg = api.EngineConfig(
        store=small_config(),
        partitioning=api.PartitioningConfig.range_for_keys(keys, 3, **params),
        execution=api.ExecutionConfig(mode="async", workers=workers),
    )
    with api.open(cfg) as eng:
        api.execute(eng, load_ops(nk, 19), batch_size=BATCH, migrate_budget=8)
        api.execute(eng, run_ops(nk, 400, 19), batch_size=BATCH, migrate_budget=8)
        async_ = eng.store
        assert serial.splits + serial.merges > 0
        assert serial.boundaries == async_.boundaries
        assert serial.metalog.records == async_.metalog.records
        assert serial.get_fallbacks == async_.get_fallbacks
        assert_identical(serial, async_, nk)
        # and the uniform read surface agrees with the raw front-end
        assert list(eng.iterator(make_key(nk // 2))) == serial.scan(make_key(nk // 2), 2 * nk)


def test_async_range_paced_matches_unpaced():
    """Pacing only sleeps — it must not change a single byte of state."""
    nk = 300
    serial, async_ = range_pair(nk)
    execute(serial, load_ops(nk, 31), batch_size=BATCH, migrate_budget=8)
    execute_async(async_, load_ops(nk, 31), batch_size=BATCH, workers=4,
                  migrate_budget=8, pace=0.5)
    assert serial.metalog.records == async_.metalog.records
    assert_identical(serial, async_, nk)


# ----------------------------------------------------------- executor edges
def test_executor_get_many_returns_values():
    store = ShardedStore(3, small_config())
    store.put_many([(make_key(i), payload(104)) for i in range(100)])
    with ShardExecutor(store, workers=2) as ex:
        handle = ex.get_many([make_key(i) for i in range(110)])
        got = handle.result()
    expect = [payload(104)] * 100 + [None] * 10
    assert got == expect


def test_executor_propagates_task_errors():
    store = ShardedStore(2, small_config())
    store.put_many([(make_key(i), b"v" * 40) for i in range(50)])
    boom = RuntimeError("injected")

    def exploding_get(key):
        raise boom

    store.shards[0].get = exploding_get
    ex = ShardExecutor(store, workers=2)
    try:
        ex.get_many([make_key(i) for i in range(50)])
        with pytest.raises(RuntimeError) as err:
            ex.drain()
        assert err.value.__cause__ is boom
    finally:
        ex.close(wait=False)


def test_executor_shard_independence_assertion():
    """A task that sneaks onto the wrong queue (violating one-task-per-store)
    trips the non-blocking lock assertion instead of corrupting state."""
    store = ShardedStore(2, small_config())
    ex = ShardExecutor(store, workers=2)
    try:
        shard = store.shards[0]
        # simulate a task still owning the store while a mis-queued task for
        # the same store starts draining
        assert ex._lock_of(shard).acquire(blocking=False)
        ex._enqueue(1, [shard], lambda: None, None)  # wrong queue, same store
        with pytest.raises(RuntimeError) as err:
            ex.drain()
        assert "shard-independence" in str(err.value.__cause__)
    finally:
        ex._lock_of(shard).release()
        ex.close(wait=False)


def test_get_many_locks_pair_only_on_merged_queue():
    """Regression: while a migration is in flight, get_many tasks for shards
    *unrelated* to the migration must not lock the src/dst pair — doing so
    races the merged pair queue's own tasks and trips the independence
    assertion spuriously.  A tight thread-switch interval makes the race
    (which otherwise hides behind GIL preemption timing) deterministic."""
    import sys

    cfgk = small_config()
    keys = [make_key(i) for i in range(600)]
    store = RangeShardedStore.for_keys(keys, 6, cfgk, auto_rebalance=False,
                                       migration_batch_keys=1)
    store.put_many([(k, payload(104)) for k in keys])
    store.flush_all()
    assert store._split(2, background=True)
    assert store.migration is not None
    old_interval = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)
    try:
        with ShardExecutor(store, workers=4) as ex:
            for _ in range(200):
                ex.get_many(keys)
            ex.drain()
    finally:
        sys.setswitchinterval(old_interval)
    # results still correct under the pounding
    assert store.get_many(keys) == [payload(104)] * len(keys)


def test_metalog_append_is_single_writer():
    store = RangeShardedStore(2, small_config())
    log = store.metalog
    entered = threading.Event()
    proceed = threading.Event()
    orig_flush = log._log.flush

    def stalling_flush():
        entered.set()
        assert proceed.wait(timeout=5)
        orig_flush()

    log._log.flush = stalling_flush
    t = threading.Thread(target=log.append, args=({"kind": "checkpoint", "cursor": b"x"},))
    t.start()
    assert entered.wait(timeout=5)
    log._log.flush = orig_flush
    try:
        with pytest.raises(RuntimeError, match="concurrent MetadataLog.append"):
            log.append({"kind": "finish"})
    finally:
        proceed.set()
        t.join(timeout=5)


# --------------------------------------------------------- overlap policies
def test_overlap_policy_algebra():
    times = [4.0, 3.0, 2.0, 2.0, 1.0]
    assert overlap_time(times, "serial") == pytest.approx(12.0)
    assert overlap_time(times, "ideal") == pytest.approx(4.0)
    # channels:1 degenerates to serial; k >= N degenerates to ideal
    assert overlap_time(times, "channels:1") == pytest.approx(12.0)
    assert overlap_time(times, "channels:5") == pytest.approx(4.0)
    assert overlap_time(times, "channels:99") == pytest.approx(4.0)
    # LPT on 2 channels: 4+2 | 3+2+1 -> makespan 6
    assert overlap_time(times, "channels:2") == pytest.approx(6.0)
    # makespan is monotone: more channels never slower, bounded by serial/ideal
    prev = float("inf")
    for k in range(1, 7):
        t = overlap_time(times, f"channels:{k}")
        assert overlap_time(times, "ideal") <= t <= overlap_time(times, "serial")
        assert t <= prev
        prev = t
    assert overlap_time([], "serial") == 0.0
    assert overlap_time([0.0, 0.0], "ideal") == 0.0
    with pytest.raises(ValueError):
        overlap_time(times, "channels:0")
    with pytest.raises(ValueError):
        overlap_time(times, "warp")


def test_front_end_device_time_uses_policy():
    store = ShardedStore(4, small_config())
    store.put_many([(make_key(i), payload(104)) for i in range(400)])
    per_shard = store.device_times()
    assert store.device_time() == pytest.approx(max(per_shard))           # default: ideal
    assert store.device_time("serial") == pytest.approx(sum(per_shard))
    assert store.device_time("channels:2") <= store.device_time("serial")
    assert store.device_time("channels:2") >= store.device_time("ideal")
