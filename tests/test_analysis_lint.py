"""Tests for the contract linter (``repro.analysis.lint``).

Covers the annotation parser, every rule (good + bad inline sources), the
seeded fixtures under ``tests/fixtures/lint_bad`` / ``lint_good``, the
real-tree-is-clean invariant, and the CLI exit codes of
``scripts/lint_contracts.py``.
"""
from __future__ import annotations

import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.contracts import FUNCTION_MARKERS, ModuleContracts
from repro.analysis.lint import RULES, lint_paths, lint_source

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures"
LINT_SCRIPT = REPO / "scripts" / "lint_contracts.py"


def rules_of(source: str) -> set[str]:
    src = textwrap.dedent(source)
    return {v.rule for v in lint_source("<test>", src)}


# ---------------------------------------------------------------- contracts --


def test_function_markers_parsed():
    mod = ModuleContracts(
        "<t>",
        textwrap.dedent(
            """
            class C:
                # contract: coordinator-only, record-then-apply
                def split(self):
                    pass
            """
        ),
    )
    (fn,) = mod.functions
    assert mod.markers_of(fn) == {"coordinator-only", "record-then-apply"}
    assert not mod.problems


def test_unknown_marker_is_a_problem():
    mod = ModuleContracts("<t>", "# contract: coordinator-onyl\n")
    assert mod.problems and "coordinator-onyl" in mod.problems[0].message


def test_exempt_requires_reason():
    mod = ModuleContracts("<t>", "# contract: exempt()\nx = 1\n")
    assert mod.problems
    mod = ModuleContracts("<t>", "# contract: exempt(thread-local here)\nx = 1\n")
    assert not mod.problems
    assert mod.exempted(1) and mod.exempted(2) and not mod.exempted(3)


def test_marker_vocabulary_is_closed():
    assert FUNCTION_MARKERS == {
        "coordinator-only",
        "record-then-apply",
        "flush-before-record",
        "rename-before-truncate",
        "single-threaded",
    }


# -------------------------------------------------------------- rules (bad) --


def test_no_nondeterminism_flags_hash_time_random():
    assert "no-nondeterminism" in rules_of(
        """
        def slot(key, n):
            return hash(key) % n
        """
    )
    assert "no-nondeterminism" in rules_of(
        """
        import time

        def stamp():
            return time.time()
        """
    )
    assert "no-nondeterminism" in rules_of("import random\n")
    assert "no-nondeterminism" in rules_of("from random import random\n")


def test_no_nondeterminism_allows_crc_and_sleep():
    assert not rules_of(
        """
        import time
        import zlib

        def slot(key, n):
            return zlib.crc32(key) % n

        def pace():
            time.sleep(0.001)  # pacing, not modeling
        """
    )


def test_coordinator_only_locks():
    bad = """
        import threading

        def anywhere(self):
            self._mu = threading.Lock()
        """
    assert "coordinator-only-locks" in rules_of(bad)
    good = """
        import threading

        # contract: coordinator-only
        def __init__(self):
            self._mu = threading.Lock()
        """
    assert "coordinator-only-locks" not in rules_of(good)


def test_stats_lock_rule():
    bad = """
        class F:
            def get(self, key):
                self.gets += 1
        """
    assert "stats-lock" in rules_of(bad)
    good = """
        class F:
            def get(self, key):
                with self._stats_lock:
                    self.gets += 1
        """
    assert "stats-lock" not in rules_of(good)
    # nested objects (store.stats.gets) are the store's own counters, not the
    # front-end aggregate — out of scope for this rule
    nested = """
        class F:
            def get(self, key):
                self.stats.gets += 1
        """
    assert "stats-lock" not in rules_of(nested)


def test_record_then_apply_rule():
    bad = """
        class T:
            # contract: record-then-apply
            def split(self, at):
                self.boundaries.insert(1, at)
                self.metalog.append({})
        """
    assert "record-then-apply" in rules_of(bad)
    missing = """
        class T:
            # contract: record-then-apply
            def split(self, at):
                self.boundaries.insert(1, at)
        """
    assert "record-then-apply" in rules_of(missing)
    good = """
        class T:
            # contract: record-then-apply
            def split(self, at):
                self.metalog.append({})
                self.boundaries.insert(1, at)
        """
    assert "record-then-apply" not in rules_of(good)


def test_flush_before_record_rule():
    bad = """
        class M:
            # contract: flush-before-record
            def tick(self, dst):
                self.metalog.append({})
                dst.flush_all()
        """
    assert "flush-before-record" in rules_of(bad)
    good = """
        class M:
            # contract: flush-before-record
            def tick(self, dst):
                dst.flush_all()
                self.metalog.append({})
        """
    assert "flush-before-record" not in rules_of(good)


def test_rename_before_truncate_rule():
    bad = """
        class C:
            # contract: rename-before-truncate
            def snapshot(self):
                self.metalog.truncate(3)
                self.metalog.append({})
        """
    assert "rename-before-truncate" in rules_of(bad)
    no_replacement = """
        class C:
            # contract: rename-before-truncate
            def snapshot(self):
                self.metalog.truncate(3)
        """
    assert "rename-before-truncate" in rules_of(no_replacement)
    never_truncates = """
        class C:
            # contract: rename-before-truncate
            def snapshot(self):
                self.metalog.append({})
        """
    assert "rename-before-truncate" in rules_of(never_truncates)
    good = """
        class C:
            # contract: rename-before-truncate
            def snapshot(self):
                self.metalog.append({})
                self.metalog.truncate(3)
        """
    assert "rename-before-truncate" not in rules_of(good)
    # the file edition: atomic publication (os.replace / atomic_write_bytes)
    # counts as the replacement write
    good_file = """
        import os

        class C:
            # contract: rename-before-truncate
            def consolidate(self, tmp, path, fh):
                os.replace(tmp, path)
                fh.truncate(0)
        """
    assert "rename-before-truncate" not in rules_of(good_file)


def test_lock_free_hot_path_rule():
    bad = """
        class S:
            # contract: single-threaded
            def get(self, key):
                with self._stats_lock:
                    pass
        """
    assert "lock-free-hot-path" in rules_of(bad)
    good = """
        class S:
            # contract: single-threaded
            def get(self, key):
                return self.index.get(key)
        """
    assert not rules_of(good)


def test_exempt_suppresses_rule_but_not_hygiene():
    exempted = """
        class F:
            def get(self, key):
                # contract: exempt(provably main-thread in this fixture)
                self.gets += 1
        """
    assert "stats-lock" not in rules_of(exempted)
    empty_reason = """
        class F:
            def get(self, key):
                # contract: exempt()
                self.reads += 1
        """
    assert "contract-annotation" in rules_of(empty_reason)


# ---------------------------------------------------------------- fixtures --


def _expected_rules(path: pathlib.Path) -> set[str]:
    out = set()
    for line in path.read_text().splitlines():
        if "# lint-expect:" in line:
            out.add(line.split("# lint-expect:", 1)[1].strip())
    return out


@pytest.mark.parametrize(
    "path",
    sorted((FIXTURES / "lint_bad").glob("*.py")),
    ids=lambda p: p.stem,
)
def test_bad_fixture_flags_exactly_its_planted_rules(path):
    expected = _expected_rules(path)
    assert expected, f"{path} must declare its planted rules via # lint-expect:"
    got = {v.rule for v in lint_paths([path])}
    assert got == expected


def test_good_fixtures_are_clean():
    paths = sorted((FIXTURES / "lint_good").glob("*.py"))
    assert paths
    assert lint_paths(paths) == []


def test_every_rule_has_a_bad_fixture():
    covered = set()
    for path in (FIXTURES / "lint_bad").glob("*.py"):
        covered |= _expected_rules(path)
    assert covered == {rule.name for rule in RULES}


def test_real_tree_is_clean():
    targets = sorted((REPO / "src" / "repro" / "core").glob("*.py"))
    targets.append(REPO / "src" / "repro" / "api.py")
    assert lint_paths(targets) == []


# --------------------------------------------------------------------- CLI --


def _run_cli(*args: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, str(LINT_SCRIPT), *args],
        cwd=REPO,
        capture_output=True,
        text=True,
    )


def test_cli_clean_tree_exits_zero():
    proc = _run_cli()
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_self_test_exits_zero():
    proc = _run_cli("--self-test")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_reports_violations_with_exit_one(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(k, n):\n    return hash(k) % n\n")
    proc = _run_cli(str(bad))
    assert proc.returncode == 1
    assert "no-nondeterminism" in proc.stdout


def test_cli_missing_file_exits_two(tmp_path):
    proc = _run_cli(str(tmp_path / "nope.py"))
    assert proc.returncode == 2


def test_cli_unknown_flag_exits_two():
    proc = _run_cli("--bogus")
    assert proc.returncode == 2
