"""Legacy shims: every deprecated symbol warns exactly once and still works.

The unified engine API (repro.api) replaced the module-level drivers; the old
imports live on for one release as thin shims that emit a single
``DeprecationWarning`` per process and then delegate unchanged.  CI runs this
module standalone under ``-W error::DeprecationWarning`` (see
.github/workflows/ci.yml) so an *unexpected* deprecation anywhere on the
import path — or a shim that warns on every call instead of once — fails the
job; inside the tests, ``warnings.catch_warnings`` scopes recording filters
so the expected warnings are observed rather than raised.
"""
import warnings

import pytest

import repro.api as api
from repro.core import ParallaxStore, ShardedStore, StoreConfig
from repro.core import ycsb
from repro.core.range_shard import RangeShardedStore
from repro.core.ycsb import Workload, make_key


def small_config(**kw) -> StoreConfig:
    defaults = dict(l0_capacity=1 << 12, cache_bytes=1 << 15,
                    segment_bytes=1 << 14, chunk_bytes=1 << 11)
    defaults.update(kw)
    return StoreConfig(**defaults)


def load(nk=150, seed=3):
    return Workload("load_a", "SD", num_keys=nk, num_ops=0, seed=seed).load_ops()


# (shim, make_store, call) — every deprecated legacy symbol, exercised
DEPRECATED = [
    ("repro.core.ycsb.execute",
     lambda: ParallaxStore(small_config()),
     lambda store: ycsb.execute(store, load())),
    ("repro.core.ycsb.execute_async",
     lambda: ShardedStore(2, small_config()),
     lambda store: ycsb.execute_async(store, load(), batch_size=32, workers=2)),
]


@pytest.mark.parametrize("symbol,make_store,call", DEPRECATED,
                         ids=[d[0] for d in DEPRECATED])
def test_shim_warns_exactly_once_and_still_functions(symbol, make_store, call):
    api.reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as first:
        warnings.simplefilter("always")
        counts = call(make_store())
    deps = [w for w in first if issubclass(w.category, DeprecationWarning)
            and symbol in str(w.message)]
    assert len(deps) == 1, [str(w.message) for w in first]
    assert "repro.api" in str(deps[0].message)  # the message names the replacement
    assert counts == {"insert": 150, "update": 0, "read": 0, "scan": 0}

    # second call: the registry remembers — silent, still functional
    with warnings.catch_warnings(record=True) as second:
        warnings.simplefilter("always")
        counts = call(make_store())
    assert not [w for w in second if issubclass(w.category, DeprecationWarning)
                and symbol in str(w.message)]
    assert counts["insert"] == 150


def test_shims_delegate_byte_identically():
    """The shim path and the engine path drive identical state: the legacy
    call pattern still *works*, not just warns."""
    api.reset_deprecation_warnings()
    legacy = ShardedStore(3, small_config(bloom_bits_per_key=10))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ycsb.execute(legacy, load(300), batch_size=32)
    with api.open(api.EngineConfig(store=small_config(bloom_bits_per_key=10),
                                   partitioning="hash:3")) as db:
        api.execute(db, load(300), batch_size=32)
        probe = [make_key(i) for i in range(320)]
        assert [db.get(k) for k in probe] == [legacy.get(k) for k in probe]
        assert db.stats()["device"]["bytes_written"] == \
            sum(s.device.stats.bytes_written for s in legacy.shards)


def _range_store(n=2, **kw) -> RangeShardedStore:
    st = RangeShardedStore(n, small_config(), auto_rebalance=False, **kw)
    for i in range(200):
        st.put(b"k%05d" % i, b"v" * 40)
    return st


def test_split_shim_warns_once_and_delegates():
    api.reset_deprecation_warnings()
    st = _range_store()
    with warnings.catch_warnings(record=True) as first:
        warnings.simplefilter("always")
        assert st.split(0)
    deps = [w for w in first if issubclass(w.category, DeprecationWarning)
            and "RangeShardedStore.split" in str(w.message)]
    assert len(deps) == 1 and "repro.api" in str(deps[0].message)
    assert st.num_shards == 3  # the shim still mutates topology

    with warnings.catch_warnings(record=True) as second:
        warnings.simplefilter("always")
        assert st.split(1)
    assert not [w for w in second if issubclass(w.category, DeprecationWarning)
                and "RangeShardedStore.split" in str(w.message)]
    assert st.num_shards == 4


def test_merge_shim_warns_once_and_delegates():
    api.reset_deprecation_warnings()
    st = _range_store(4)
    with warnings.catch_warnings(record=True) as first:
        warnings.simplefilter("always")
        st.merge(0)
    deps = [w for w in first if issubclass(w.category, DeprecationWarning)
            and "RangeShardedStore.merge" in str(w.message)]
    assert len(deps) == 1 and "repro.api" in str(deps[0].message)
    assert st.num_shards == 3

    with warnings.catch_warnings(record=True) as second:
        warnings.simplefilter("always")
        st.merge(0)
    assert not [w for w in second if issubclass(w.category, DeprecationWarning)
                and "RangeShardedStore.merge" in str(w.message)]
    assert st.num_shards == 2


def test_auto_rebalance_and_rescale_never_warn():
    """The internal policy path (_split/_merge) and the new rescale surface
    must not trip the public-shim deprecations."""
    api.reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        with api.open(partitioning=api.PartitioningConfig.parse(
                "range:2", min_split_keys=16, rebalance_window=32),
                store=small_config()) as db:
            for lo in range(0, 400, 50):  # batched: policy runs at boundaries
                wb = db.write_batch()
                for i in range(lo, lo + 50):
                    wb.put(b"r%05d" % i, b"v" * 40)
                db.write(wb)
            assert db.store.splits > 0  # the policy did rebalance
            db.store.drain_migration()
            db.rescale(db.store.num_shards * 2)
            while db.topology()["rescale"] is not None:
                db.migration_tick()
    assert not [w for w in caught if issubclass(w.category, DeprecationWarning)]


def test_engine_api_itself_never_warns():
    """Driving through repro.api must not trip the deprecation shims."""
    api.reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        with api.open(partitioning="hash:2", execution="async",
                      store=small_config()) as db:
            api.execute(db, load())
            db.put(make_key(999), b"v" * 30)
            assert db.get(make_key(999)) == b"v" * 30
    assert not [w for w in caught if issubclass(w.category, DeprecationWarning)]
