"""Cross-process determinism: modeled stats must not depend on PYTHONHASHSEED.

PR 1 moved every read-path hash (cache-block choice, bloom probes, shard
routing) from the randomized builtin ``hash()`` to ``zlib.crc32`` so traffic
and stats are bit-identical across processes; PR 2 pinned the generator/op
stream.  This module is the regression net against a reintroduced ``hash()``
(or any other process-randomized state): the full :class:`DeviceStats` of a
hash- and a range-sharded run — driven through the *async* executor, with a
live migration — plus ZipfGenerator samples and route assignments must be
byte-identical between two subprocesses launched with different
``PYTHONHASHSEED`` values.  CI additionally pins ``PYTHONHASHSEED=0``
globally (``.github/workflows/ci.yml``), but the suite must not need it.
"""
import os
import pathlib
import subprocess
import sys

_SCRIPT = r"""
import dataclasses, json
from repro.core import RangeShardedStore, ShardedStore, StoreConfig
from repro.core.shard import route
from repro.core.ycsb import Workload, ZipfGenerator, execute_async, make_key

cfg = lambda: StoreConfig(l0_capacity=1 << 12, cache_bytes=1 << 15,
                          segment_bytes=1 << 14, chunk_bytes=1 << 11,
                          bloom_bits_per_key=10)
nk = 300
load = Workload("load_a", "SD", num_keys=nk, num_ops=0, seed=41)
run = Workload("run_a", "SD", num_keys=nk, num_ops=200, seed=41)

hashed = ShardedStore(3, cfg())
execute_async(hashed, load.load_ops(), batch_size=32, workers=2)
execute_async(hashed, run.run_ops(), batch_size=32, workers=2)

ranged = RangeShardedStore.for_keys([make_key(i) for i in range(nk)], 3, cfg(),
                                    rebalance_window=80, split_factor=1.05,
                                    merge_factor=0.9, migration_batch_keys=8)
execute_async(ranged, load.load_ops(), batch_size=32, workers=2, migrate_budget=4)
execute_async(ranged, run.run_ops(), batch_size=32, workers=2, migrate_budget=4)

out = {
    "zipf": ZipfGenerator(2000, seed=9).sample(500).tolist(),
    "routes": [route(make_key(i), 5) for i in range(400)],
    "hash_dev": [dataclasses.asdict(s.device.stats) for s in hashed._all_stores()],
    "hash_agg": dataclasses.asdict(hashed.aggregate_stats()),
    "range_dev": [dataclasses.asdict(s.device.stats) for s in ranged._all_stores()],
    "range_meta": dataclasses.asdict(ranged.meta_device.stats),
    "range_topology": [b.hex() for b in ranged.boundaries],
    "range_counters": [ranged.splits, ranged.merges, ranged.migrated_keys,
                       ranged.get_fallbacks, ranged.metalog.n_records],
}
print(json.dumps(out, sort_keys=True))
"""


def test_device_stats_identical_across_processes():
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    outputs = []
    for seed in ("1", "31337"):
        proc = subprocess.run(
            [sys.executable, "-c", _SCRIPT],
            capture_output=True, text=True, check=True,
            env={**os.environ, "PYTHONPATH": src, "PYTHONHASHSEED": seed},
        )
        outputs.append(proc.stdout)
    assert outputs[0] == outputs[1]
    assert '"range_counters"' in outputs[0]  # the payload really materialized
