"""Tests for the static WAL-protocol checker
(``repro.analysis.protocol.static_check``).

Covers the spec itself (automaton sanity), every rule against inline planted
sources, the dataflow subtleties the real tree depends on (variable-resolved
records, conditional payload keys, flush tracking across loops), the seeded
fixtures under ``tests/fixtures/protocol_bad`` / ``protocol_good``, the
real-tree-is-clean invariant with completeness on, the append-site inventory,
and the CLI exit codes / ``--json`` output of ``scripts/check_protocol.py``.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys
import textwrap

from repro.analysis.protocol.spec import (
    IDLE,
    LEG,
    RESCALE,
    START,
    WAL_SPEC,
)
from repro.analysis.protocol.static_check import (
    PROTOCOL_RULES,
    append_site_inventory,
    check_paths,
    check_source,
    default_targets,
)

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures"
CHECK_SCRIPT = REPO / "scripts" / "check_protocol.py"


def rules_of(source: str) -> set[str]:
    violations, _sites = check_source(textwrap.dedent(source), "<test>")
    return {v.rule for v in violations}


# --------------------------------------------------------------------- spec --


def test_spec_declares_every_wal_kind():
    assert WAL_SPEC.kind_names == {
        "init", "snapshot", "cutoff", "gc_reclaim", "split_start",
        "merge_start", "rescale_start", "checkpoint", "finish",
        "rescale_finish",
    }


def test_spec_automaton_steps():
    assert WAL_SPEC.step(frozenset({START}), "init") == frozenset({IDLE})
    assert WAL_SPEC.step(frozenset({IDLE}), "split_start") == frozenset({LEG})
    assert WAL_SPEC.step(frozenset({LEG}), "finish") == frozenset({IDLE})
    assert WAL_SPEC.step(frozenset({START, IDLE}), "rescale_start") == \
        frozenset({RESCALE})
    assert WAL_SPEC.step(frozenset({RESCALE}), "rescale_finish") == \
        frozenset({IDLE})
    # infeasible: checkpoint from a closed stream
    assert WAL_SPEC.step(frozenset({IDLE}), "checkpoint") == frozenset()


def test_spec_stream_start_and_crash_coverage():
    assert WAL_SPEC.stream_start_kinds() == {
        "init", "snapshot", "rescale_start"}
    # init is genesis: exempt from the crash sweep (it precedes all data work)
    assert WAL_SPEC.crash_coverage_kinds() == WAL_SPEC.kind_names - {"init"}


# ---------------------------------------------------------- rules, inline ----


def test_rule_order_checkpoint_after_close():
    assert rules_of("""
        class C:
            def f(self, dst):
                dst.flush_all()
                self.metalog.append({"kind": "rescale_finish"})
                self.metalog.append({"kind": "checkpoint", "cursor": b"k"})
        """) == {"order"}


def test_rule_fence_flush_reordered():
    assert rules_of("""
        class C:
            def f(self, dst, batch):
                for k in batch:
                    dst._write(k, b"v", tombstone=False, internal=True)
                self.metalog.append({"kind": "checkpoint", "cursor": b"k"})
                dst.flush_all()
        """) == {"fence-flush"}


def test_rule_fence_flush_satisfied_is_clean():
    assert rules_of("""
        class C:
            def f(self, dst, batch):
                for k in batch:
                    dst._write(k, b"v", tombstone=False, internal=True)
                dst.flush_all()
                self.metalog.append({"kind": "checkpoint", "cursor": b"k"})
        """) == set()


def test_rule_fence_flush_rewrite_after_flush_dirties():
    # flush then write again: the CLEAN fact must be killed
    assert rules_of("""
        class C:
            def f(self, dst):
                dst.flush_all()
                dst.put(b"k", b"v")
                self.metalog.append({"kind": "checkpoint", "cursor": b"k"})
        """) == {"fence-flush"}


def test_rule_fence_apply_before_record():
    assert rules_of("""
        class C:
            def f(self, at):
                self.boundaries.insert(1, at)
                self.metalog.append({"kind": "split_start", "src": 0,
                                     "dst": 1, "at": at, "hi": None,
                                     "epoch": 0})
        """) == {"fence-apply"}


def test_rule_fence_truncate_unrooted():
    assert rules_of("""
        class C:
            def f(self):
                self.metalog.truncate(0)
        """) == {"fence-truncate"}


def test_rule_undeclared_kind():
    assert rules_of("""
        class C:
            def f(self):
                self.metalog.append({"kind": "compact_start"})
        """) == {"undeclared-kind"}


def test_rule_payload_keys():
    assert rules_of("""
        class C:
            def f(self, dst):
                dst.flush_all()
                self.metalog.append({"kind": "checkpoint", "cur": b"k"})
        """) == {"payload-keys"}


def test_rule_unresolved_record():
    assert rules_of("""
        class C:
            def f(self):
                self.metalog.append(self._make_record())
        """) == {"unresolved-kind"}


# ------------------------------------------------- dataflow subtleties -------


def test_variable_record_with_conditional_key_resolves():
    violations, sites = check_source(textwrap.dedent("""
        class C:
            def f(self, dst, m):
                dst.flush_all()
                rec = {"kind": "checkpoint", "cursor": b"k"}
                if self._rescale is not None:
                    rec["leg"] = m.dst_id
                self.metalog.append(rec)
        """), "<test>")
    assert not violations
    assert [s.kind for s in sites] == ["checkpoint"]


def test_variable_rebind_checkpoint_then_finish():
    # the real _advance_leg shape: rec reassigned between two appends
    assert rules_of("""
        class C:
            def f(self, dst, done):
                dst.flush_all()
                rec = {"kind": "checkpoint", "cursor": b"k"}
                self.metalog.append(rec)
                if done:
                    rec = {"kind": "finish"}
                    self.metalog.append(rec)
        """) == set()


def test_flush_only_loop_satisfies_fence():
    # the snapshot_metadata shape: flush the whole fleet in a loop
    assert rules_of("""
        class C:
            def f(self, cuts):
                for store in self._all_stores():
                    store.flush_all()
                self.metalog.append({"kind": "snapshot", "boundaries": [],
                                     "shards": [], "next_shard_id": 1,
                                     "migration": None, "cutoffs": cuts})
                self.metalog.truncate(0)
        """) == set()


def test_order_resync_after_violation():
    # one ordering bug must not cascade: the stream resynchronizes
    assert rules_of("""
        class C:
            def f(self, dst):
                dst.flush_all()
                self.metalog.append({"kind": "rescale_finish"})
                self.metalog.append({"kind": "checkpoint", "cursor": b"k"})
                self.metalog.append({"kind": "finish"})
        """) == {"order"}


def test_branch_divergent_order_both_paths_checked():
    # split_start is only legal from IDLE; after a rescale_start it is not
    assert rules_of("""
        class C:
            def f(self, which):
                if which:
                    self.metalog.append({"kind": "rescale_start",
                                         "scheme": "hash", "from": 1,
                                         "to": 2, "legs": []})
                    self.metalog.append({"kind": "split_start", "src": 0,
                                         "dst": 1, "at": b"m", "hi": None,
                                         "epoch": 0})
        """) == {"order"}


# --------------------------------------------------------- real tree ---------


def test_real_tree_is_clean_and_complete():
    violations = check_paths(require_complete=True)
    assert violations == [], [str(v) for v in violations]


def test_append_site_inventory_covers_every_kind():
    sites = append_site_inventory()
    assert {s.kind for s in sites} == set(WAL_SPEC.kind_names)
    # every site resolved to a real file/line in the protocol tree
    target_names = {p.name for p in default_targets()}
    for s in sites:
        assert pathlib.Path(s.path).name in target_names
        assert s.lineno > 0 and s.func


# ---------------------------------------------------------- fixtures ---------


def test_bad_fixtures_flag_exactly_their_planted_rules():
    bad = sorted((FIXTURES / "protocol_bad").glob("*.py"))
    assert len(bad) >= len(PROTOCOL_RULES) - 1  # one fixture may cover two
    covered: set[str] = set()
    for path in bad:
        text = path.read_text(encoding="utf-8")
        expected = {
            line.split("protocol-expect:")[1].strip()
            for line in text.splitlines() if "protocol-expect:" in line
        }
        assert expected, f"{path.name} declares no planted rules"
        complete = "require-complete" in text
        actual = {v.rule for v in check_paths([path],
                                              require_complete=complete)}
        assert actual == expected, (
            f"{path.name}: expected {sorted(expected)}, got {sorted(actual)}")
        covered |= actual
    assert covered == set(PROTOCOL_RULES)


def test_good_fixture_is_clean_even_with_completeness():
    good = sorted((FIXTURES / "protocol_good").glob("*.py"))
    assert good
    for path in good:
        violations = check_paths([path], require_complete=True)
        assert violations == [], [str(v) for v in violations]


# --------------------------------------------------------------- CLI ---------


def run_cli(*args: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, str(CHECK_SCRIPT), *args],
        capture_output=True, text=True, cwd=REPO)


def test_cli_default_targets_clean_exit_0():
    proc = run_cli()
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "protocol ok" in proc.stdout


def test_cli_self_test_exit_0():
    proc = run_cli("--self-test")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "protocol self-test ok" in proc.stdout


def test_cli_bad_fixture_exit_1():
    proc = run_cli(str(FIXTURES / "protocol_bad" / "fence_flush_reordered.py"))
    assert proc.returncode == 1
    assert "[fence-flush]" in proc.stdout


def test_cli_json_output_parses():
    proc = run_cli("--json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["violations"] == []
    assert payload["files"] == len(default_targets())


def test_cli_json_violations_have_matcher_fields():
    proc = run_cli(
        "--json", str(FIXTURES / "protocol_bad" / "undeclared_kind.py"))
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    (v,) = payload["violations"]
    assert v["rule"] == "undeclared-kind"
    assert v["path"].endswith("undeclared_kind.py") and v["line"] > 0


def test_cli_usage_errors_exit_2():
    assert run_cli("--bogus-flag").returncode == 2
    assert run_cli("--self-test", "extra.py").returncode == 2
    assert run_cli("no/such/file.py").returncode == 2
