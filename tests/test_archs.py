"""Per-architecture smoke tests: reduced config, one forward/train step on CPU.

Asserts output shapes, finite losses, no NaNs, and that a train step actually
changes parameters.  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, applicable, runnable_cells
from repro.models import get_model
from repro.optim import adamw
from repro.train.step import make_train_fn

ARCH_NAMES = sorted(ARCHS)


def make_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (b, s + 1), dtype=np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(rng.standard_normal((b, cfg.num_patches, cfg.d_model), np.float32) * 0.02)
    if cfg.family == "encdec":
        batch["frame_embeds"] = jnp.asarray(rng.standard_normal((b, cfg.encoder_frames, cfg.d_model), np.float32) * 0.02)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finiteness(name):
    cfg = ARCHS[name].reduced()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = m.forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert np.isfinite(float(m.loss_fn(cfg, params, batch)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(name):
    """decode_step after prefill == forward over the extended sequence.

    MoE capacity dropping is order-dependent (a token dropped in the full
    forward is never dropped in single-token decode), so consistency is only
    exact without drops — use an ample capacity factor here.
    """
    import dataclasses

    cfg = dataclasses.replace(ARCHS[name].reduced(), capacity_factor=100.0)
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 1, 16
    batch = make_batch(cfg, b, s, seed=2)
    lg, cache = m.prefill(cfg, params, batch, max_len=s + 4)
    full, _ = m.forward(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1:]), atol=2e-3, rtol=2e-3)
    nxt = jnp.zeros((b, 1), jnp.int32) + 7
    lg2, cache = m.decode_step(cfg, params, cache, nxt)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    full2, _ = m.forward(cfg, params, batch2)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full2[:, -1:]), atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_updates_params(name):
    cfg = ARCHS[name].reduced()
    step = make_train_fn(cfg, adamw.AdamWConfig(lr=1e-3, warmup_steps=0))
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(3))
    opt = adamw.init(params)
    batch = make_batch(cfg, 2, 8, seed=4)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    # at least one leaf moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


def test_decode_multiple_steps_greedy():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(5))
    batch = make_batch(cfg, 2, 8, seed=6)
    _, cache = m.prefill(cfg, params, batch, max_len=16)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(6):
        logits, cache = m.decode_step(cfg, params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        assert not np.any(np.isnan(np.asarray(logits)))
    assert int(cache["pos"]) == 8 + 6


def test_cell_registry_counts():
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    runnable = runnable_cells()
    # long_500k only for ssm/hybrid (2 archs)
    assert len(runnable) == 32
    assert applicable("mamba2-780m", "long_500k")
    assert applicable("zamba2-2.7b", "long_500k")
    assert not applicable("yi-34b", "long_500k")


def test_param_counts_match_names():
    expect = {
        "mamba2-780m": 0.78, "yi-34b": 34.4, "qwen2.5-3b": 3.1,
        "phi3-medium-14b": 14.7, "qwen3-8b": 8.2, "whisper-medium": 1.0,
        "deepseek-moe-16b": 16.9, "qwen3-moe-30b-a3b": 30.5, "zamba2-2.7b": 2.4,
        "internvl2-26b": 19.9,  # backbone only (ViT frontend stubbed per spec)
    }
    for name, target in expect.items():
        n = ARCHS[name].param_count() / 1e9
        assert abs(n - target) / target < 0.1, (name, n)


def test_moe_active_params():
    cfg = ARCHS["qwen3-moe-30b-a3b"]
    active = cfg.active_param_count() / 1e9
    assert 2.5 < active < 4.5  # "A3B" = ~3B active
