"""ShardedStore: routing determinism, batched-vs-sequential equivalence."""
import os
import pathlib
import subprocess
import sys
import zlib

import pytest

from repro.core import ParallaxStore, ShardedStore, StoreConfig
from repro.core.shard import _ROUTE_SEED, route
from repro.core.ycsb import Workload, execute, make_key


def small_config(**kw) -> StoreConfig:
    defaults = dict(l0_capacity=1 << 12, cache_bytes=1 << 15,
                    segment_bytes=1 << 14, chunk_bytes=1 << 11)
    defaults.update(kw)
    return StoreConfig(**defaults)


def test_routing_is_deterministic_and_covers_all_shards():
    keys = [make_key(i) for i in range(2000)]
    for n in (1, 2, 4, 8):
        assignment = [route(k, n) for k in keys]
        # stable: recomputing gives the same shard, and it matches the
        # documented crc32 formula (independent of PYTHONHASHSEED)
        assert assignment == [zlib.crc32(k, _ROUTE_SEED) % n for k in keys]
        assert set(assignment) == set(range(n))  # every shard owns keys
        st = ShardedStore(n, small_config())
        assert [st.shard_of(k) for k in keys[:100]] == assignment[:100]


def test_shards_partition_the_keyspace():
    st = ShardedStore(4, small_config())
    for i in range(500):
        st.put(make_key(i), b"v" * 60)
    per_shard_keys = [
        {k for k, _ in s.scan(b"", 1000)} for s in st.shards
    ]
    union = set().union(*per_shard_keys)
    assert len(union) == 500
    assert sum(len(ks) for ks in per_shard_keys) == 500  # disjoint


@pytest.mark.parametrize("num_shards", [1, 3, 8])
def test_batched_matches_sequential_single_store(num_shards):
    """Batched ops on N bloom-filtered shards == sequential ops on one bare
    filterless store."""
    sharded = ShardedStore(num_shards, small_config(bloom_bits_per_key=10))
    bare = ParallaxStore(small_config())
    w = Workload("load_a", "SD", num_keys=1500, num_ops=0, seed=3)
    execute(sharded, w.load_ops(), batch_size=32)
    execute(bare, w.load_ops())
    r = Workload("run_a", "SD", num_keys=1500, num_ops=800, seed=3)
    execute(sharded, r.run_ops(), batch_size=32)
    execute(bare, r.run_ops())
    keys = [make_key(i) for i in range(1600)]
    assert sharded.get_many(keys) == [bare.get(k) for k in keys]
    assert sharded.scan(b"", 2000) == bare.scan(b"", 2000)
    # scans starting mid-keyspace also merge identically
    assert sharded.scan(make_key(700), 40) == bare.scan(make_key(700), 40)


def test_sharded_n1_is_identical_to_bare_store():
    """Acceptance: ShardedStore(n=1) == bare ParallaxStore on get and scan."""
    cfg = small_config(bloom_bits_per_key=10)
    front = ShardedStore(1, cfg)
    bare = ParallaxStore(small_config())
    w = Workload("load_a", "MD", num_keys=1200, num_ops=0, seed=5)
    execute(front, w.load_ops(), batch_size=64)
    execute(bare, w.load_ops())
    keys = [make_key(i) for i in range(1300)]
    assert front.get_many(keys) == [bare.get(k) for k in keys]
    assert front.scan(b"", 1500) == bare.scan(b"", 1500)
    # stats route through the single shard unchanged
    assert front.aggregate_stats().inserts == bare.stats.inserts


def test_put_many_last_write_wins_within_batch():
    st = ShardedStore(4, small_config())
    k = make_key(42)
    st.put_many([(k, b"first"), (make_key(1), b"x"), (k, b"last")])
    assert st.get(k) == b"last"
    st.update_many([(k, b"updated"), (k, b"updated-2")])
    assert st.get(k) == b"updated-2"
    st.delete_many([k])
    assert st.get(k) is None


def test_get_many_preserves_input_order():
    st = ShardedStore(4, small_config())
    items = [(make_key(i), f"v{i}".encode()) for i in range(200)]
    st.put_many(items)
    keys = [k for k, _ in items][::-1] + [make_key(999)]
    got = st.get_many(keys)
    assert got[:-1] == [v for _, v in items][::-1]
    assert got[-1] is None


def test_crash_recover_delegates_to_every_shard():
    st = ShardedStore(3, small_config())
    items = [(make_key(i), b"v" * 104) for i in range(900)]
    st.put_many(items)
    st.flush_all()
    cutoffs = st.crash()
    st.recover()
    # one cutoff per shard: LSN spaces are independent, and the flush made
    # every shard's full history durable
    assert len(cutoffs) == st.num_shards
    assert cutoffs == [s.lsn for s in st.shards]
    # flushed before crash: every write survives on every shard
    assert st.get_many([k for k, _ in items]) == [v for _, v in items]


_STREAM_SCRIPT = r"""
from repro.core.shard import route
from repro.core.ycsb import Workload, ZipfGenerator

z = ZipfGenerator(5000, seed=9)
print(z.sample(2000).tolist())
ops = list(Workload("run_e", "SD", num_keys=1000, num_ops=400, seed=5).run_ops())
print([(op.kind, op.key.decode(), op.value_size) for op in ops])
print([route(op.key, 4) for op in ops])
"""


def test_op_stream_and_routing_deterministic_across_processes():
    """ZipfGenerator samples, the generated op stream, and the shard
    assignment must be bit-identical across processes regardless of
    PYTHONHASHSEED (mirrors PR 1's crc32 determinism test: benchmarks and
    the differential oracle rely on replaying the exact same stream)."""
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    outputs = []
    for seed in ("1", "31337"):
        proc = subprocess.run(
            [sys.executable, "-c", _STREAM_SCRIPT],
            capture_output=True, text=True, check=True,
            env={**os.environ, "PYTHONPATH": src, "PYTHONHASHSEED": seed},
        )
        outputs.append(proc.stdout)
    assert outputs[0] == outputs[1]


_HOT_STREAM_SCRIPT = r"""
from repro.core.ycsb import Workload

ops = list(Workload("run_a", "SD", num_keys=1000, num_ops=400, seed=5,
                    hot_update_frac=0.6, hot_update_keys=16).run_ops())
print([(op.kind, op.key.decode(), op.value_size) for op in ops])
"""


def test_hot_update_stream_deterministic_across_processes():
    """The hot-update-skewed op stream (the lifetime workload knob) must be
    bit-identical across processes regardless of PYTHONHASHSEED, like the
    base stream above — bench_lifetime and the lifetime differential tests
    replay it."""
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    outputs = []
    for seed in ("1", "31337"):
        proc = subprocess.run(
            [sys.executable, "-c", _HOT_STREAM_SCRIPT],
            capture_output=True, text=True, check=True,
            env={**os.environ, "PYTHONPATH": src, "PYTHONHASHSEED": seed},
        )
        outputs.append(proc.stdout)
    assert outputs[0] == outputs[1]


def test_aggregate_stats_sums_shards():
    st = ShardedStore(4, small_config())
    st.put_many([(make_key(i), b"v" * 60) for i in range(300)])
    st.get_many([make_key(i) for i in range(300)])
    agg = st.aggregate_stats()
    assert agg.inserts == 300
    assert agg.gets == 300
    assert agg.found == 300
    assert agg.app_bytes == sum(s.stats.app_bytes for s in st.shards)
    dev = st.device_stats()
    assert dev.total == sum(s.device.stats.total for s in st.shards)
