"""Planted violations: lock traffic on a single-threaded modeled hot path.

``single-threaded`` functions are the byte-accounted hot paths; a lock there
is either dead weight or evidence the path is no longer single-threaded.
"""
# lint-expect: lock-free-hot-path


class Store:
    # contract: single-threaded
    def get(self, key):
        with self._stats_lock:
            self.reads = self.reads + 1
        self._mu.acquire()
        try:
            return self.index.get(key)
        finally:
            self._mu.release()
