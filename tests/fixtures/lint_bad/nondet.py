"""Planted violations: nondeterminism in a modeled path.

Builtin ``hash()`` is PYTHONHASHSEED-randomized, ``time.time()`` is
wall-clock, and stdlib ``random`` is process-seeded — all three would make
modeled byte counts differ across processes.
"""
# lint-expect: no-nondeterminism
import time

import random


def cache_slot(key: bytes, nslots: int) -> int:
    return hash(key) % nslots


def stamp() -> float:
    return time.time()


def jitter() -> float:
    return random.random()
