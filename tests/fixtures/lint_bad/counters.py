"""Planted violations: front-end counters mutated without the stats lock.

Shared counters (``gets``, ``scan_probes``, ...) may only move under
``with ..._stats_lock:`` or inside a ``coordinator-only`` function.
"""
# lint-expect: stats-lock


class FrontEnd:
    def __init__(self):
        # even initialization counts unless the function is coordinator-only
        self.gets = 0

    def get(self, key):
        self.gets += 1
        self.get_probes += 1
        return None
