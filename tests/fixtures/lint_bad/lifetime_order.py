"""Planted violations: lifetime GC/cutover paths with inverted ordering.

The GC reclaim fence must make relocated values durable *before* the WAL
record that covers the reclaim (flush-before-record: a crash after the
record would otherwise point at volatile relocations), and an adaptive
cutoff cutover must journal the new thresholds *before* installing them
(record-then-apply: applying first leaves unrecorded placement policy a
recovery cannot reproduce).  These mirror
``RangeShardedStore._journal_gc_reclaim`` / ``_apply_cutoffs``.
"""
# lint-expect: flush-before-record
# lint-expect: record-then-apply


class LifetimeFrontend:
    # contract: flush-before-record
    def journal_gc_reclaim(self, store, log_name, segment_id):
        self.metalog.append(
            {"kind": "gc_reclaim", "log": log_name, "segment": segment_id}
        )  # record first: a crash here covers still-volatile relocations
        store.flush_all()

    # contract: record-then-apply
    def apply_cutoffs(self, sid, t_sm, t_ml):
        self.shards[sid] = (t_sm, t_ml)  # applied before the record: wrong
        self.metalog.append({"kind": "cutoff", "shard": sid})

    # contract: record-then-apply
    def autonomous_cutover(self, migration):
        self._migration = migration  # no record at all: silently applied
