"""Planted violation: history truncated before its replacement is durable.

A snapshot may only drop the records it summarizes *after* the snapshot
itself has been durably published (``metalog.append`` / ``os.replace``).
Truncating first leaves a crash window with no copy of the state at all.
"""
# lint-expect: rename-before-truncate


class Coordinator:
    # contract: rename-before-truncate
    def snapshot_metadata(self):
        self.metalog.truncate(3)  # truncate first: wrong
        self.metalog.append({"kind": "snapshot"})
