"""Planted violations: the rescale coordinator breaking the WAL discipline.

A rescale flips the routing topology (``shards``/``_draining``) and arms the
per-leg migration registry (``_migrations``/``_rescale``); all of that must
happen *after* the ``rescale_start`` record, or a crash leaves live traffic
routed through topology no recovery can rebuild.  Likewise the per-leg
``finish`` record drops the leg from recovery's view, so the source shard's
deletes must be flushed durable *before* the record is appended.
"""
# lint-expect: record-then-apply
# lint-expect: flush-before-record


class Coordinator:
    # contract: record-then-apply
    def rescale(self, plan):
        self._rescale = plan  # armed before the rescale_start record: wrong
        self._draining[plan.src] = self.shards[plan.src]  # routing flip, unrecorded
        self._migrations[plan.dst] = plan.leg  # leg visible with no durable evidence
        self.metalog.append({"kind": "rescale_start", "legs": plan.legs})

    # contract: flush-before-record
    def finish_leg(self, src, leg):
        # the record drops the leg from recovery's view while src deletes
        # it covers may still be volatile: wrong order
        self.metalog.append({"kind": "finish", "leg": leg.index})
        src.flush_all()
