"""Planted violations: topology applied before the WAL record.

Record-then-apply means a crash before the record leaves *no* applied state;
mutating first opens a window where the in-memory topology has no durable
evidence.
"""
# lint-expect: record-then-apply


class Topology:
    # contract: record-then-apply
    def split(self, at):
        self.boundaries.insert(1, at)  # applied before the record: wrong
        self.metalog.append({"kind": "split_start", "at": at})

    # contract: record-then-apply
    def forgot_the_record(self, migration):
        self._migration = migration
