"""Planted violation: durable record written before the data it covers.

A checkpoint/redo record committed ahead of the destination flush would,
after a crash, point at data that never became durable (the PR 1
dangling-pointer class of bug).
"""
# lint-expect: flush-before-record


class Migration:
    # contract: flush-before-record
    def tick(self, dst):
        self.metalog.append({"kind": "checkpoint"})  # record first: wrong
        dst.flush_all()
