"""Planted violations: lock creation outside a coordinator-only function.

Two worker threads racing to create "the" lock would each get their own —
and the exclusivity assertion the lock implements would never fire.
"""
# lint-expect: coordinator-only-locks
import threading

_GLOBAL_LOCK = threading.Lock()  # module level is never coordinator-only


class Worker:
    def ensure_lock(self):
        # an unannotated method may run on any thread
        self._lock = threading.RLock()
        return self._lock
