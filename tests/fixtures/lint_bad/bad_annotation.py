"""Planted violations: annotation hygiene.

A typo'd marker would silently disable a rule; an ``exempt`` without a
justification is an unaccountable suppression.  Both are violations.
"""
# lint-expect: contract-annotation


# contract: coordinator-onyl
def typo_disables_nothing():
    pass


def unjustified_suppression(self):
    # contract: exempt()
    self.reads += 1
