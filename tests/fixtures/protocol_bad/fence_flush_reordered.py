"""Planted violation: the acceptance-criteria reorder — a migration batch
is written into the destination but the ``checkpoint`` record commits
*before* ``dst.flush_all()``.  A crash between the append and the flush
loses data the durable record already claims ownership of.
"""
# protocol-expect: fence-flush


class Coordinator:
    def migrate_batch(self, dst, batch):
        for key, row in batch:
            dst._write(key, row, tombstone=False, internal=True)
        self.metalog.append({"kind": "checkpoint", "cursor": b"k"})
        dst.flush_all()  # too late: the record is already durable
