"""Planted violation: WAL truncation with no preceding snapshot append on
any path — the rename-before-truncate discipline requires the replacement
root record to be durable before the prefix it replaces is dropped.
"""
# protocol-expect: fence-truncate


class Coordinator:
    def compact_wal(self):
        self.metalog.truncate(0)
