"""Planted violation: an append whose record the dataflow pass cannot
resolve to a literal dict (built by a helper call) — the checker refuses
to pass code it cannot prove conformant.
"""
# protocol-expect: unresolved-kind


class Coordinator:
    def opaque_append(self):
        self.metalog.append(self._make_record())
