"""Planted violation: an append of a record kind the spec never declared —
the exact "new kind wired in while every checker stays silent" failure the
protocol package exists to close.
"""
# protocol-expect: undeclared-kind


class Coordinator:
    def start_compaction(self):
        self.metalog.append({"kind": "compact_start", "level": 1})
