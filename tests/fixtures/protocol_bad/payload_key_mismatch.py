"""Planted violation: a ``checkpoint`` record misspelling its required
``cursor`` key — recovery replay would silently see no cursor and restart
the leg from the beginning.
"""
# protocol-expect: payload-keys


class Coordinator:
    def checkpoint(self, dst):
        dst.flush_all()
        self.metalog.append({"kind": "checkpoint", "cur": b"k"})
