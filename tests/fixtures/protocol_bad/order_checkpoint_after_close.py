"""Planted violation: a checkpoint appended after the rescale is closed.

``rescale_finish`` moves the automaton RESCALE -> IDLE; a subsequent
``checkpoint`` has no feasible from-state left (it needs LEG or RESCALE),
so the ordering pass reports the stream as infeasible at the second append.
"""
# protocol-expect: order


class Coordinator:
    def close_then_checkpoint(self, dst):
        dst.flush_all()
        self.metalog.append({"kind": "rescale_finish"})
        self.metalog.append({"kind": "checkpoint", "cursor": b"k"})
