"""Planted violation: record-then-apply inverted — the split's boundary
flip mutates ``self.boundaries`` *before* the ``split_start`` record is
durable, so a crash between them leaves routed keys with no WAL evidence.
"""
# protocol-expect: fence-apply


class Coordinator:
    def split(self, at, dst_id):
        self.boundaries.insert(1, at)  # applied before the record: wrong
        self.metalog.append({
            "kind": "split_start", "src": 0, "dst": dst_id,
            "at": at, "hi": None, "epoch": 0,
        })
