"""Disciplined twin of the bad fixtures — every rule satisfied.

Covers: crc32 instead of hash(), coordinator-only lock creation,
stats-lock-guarded counters, record-then-apply ordering, flush-before-record
ordering, a lock-free single-threaded hot path, and a justified ``exempt``.
"""
import threading
import zlib


def cache_slot(key: bytes, nslots: int) -> int:
    return zlib.crc32(key) % nslots


class FrontEnd:
    # contract: coordinator-only
    def __init__(self):
        self._stats_lock = threading.Lock()
        self.gets = 0
        self.get_probes = 0

    def get(self, key):
        with self._stats_lock:
            self.gets += 1
            self.get_probes += 1
        return None

    def get_cached(self, key):
        # contract: exempt(counter is thread-local by construction here)
        self.gets += 1
        return None

    # contract: record-then-apply
    def split(self, at):
        self.metalog.append({"kind": "split_start", "at": at})
        self.boundaries.insert(1, at)

    # contract: flush-before-record
    def migration_tick(self, dst):
        dst.flush_all()
        self.metalog.append({"kind": "checkpoint"})


class Store:
    # contract: single-threaded
    def get(self, key):
        self.reads = self.reads + 1
        return self.index.get(key)
