"""Conforming fixture: one function exercising every spec kind in a legal
order with every fence honored — genesis, a cutoff cutover, a split leg
(flush then checkpoint then finish), a merge leg, a GC reclaim behind its
flush fence, a hash rescale bracketed by rescale_start/rescale_finish, and
a snapshot rooting a truncation.  Must check clean even with the
completeness requirement on.
"""
# protocol-flags: require-complete


class Coordinator:
    def lifecycle(self, dst):
        self.metalog.append({"kind": "init", "boundaries": [], "shards": []})
        self.metalog.append(
            {"kind": "cutoff", "shard": 0, "t_sm": 1, "t_ml": 2})
        self.metalog.append({
            "kind": "split_start", "src": 0, "dst": 1,
            "at": b"m", "hi": None, "epoch": 0,
        })
        dst.flush_all()
        self.metalog.append({"kind": "checkpoint", "cursor": b"k"})
        self.metalog.append({"kind": "finish"})
        self.metalog.append({
            "kind": "merge_start", "src": 1, "dst": 0,
            "lo": b"a", "hi": b"z", "epoch": 1,
        })
        self.metalog.append({"kind": "finish"})
        self.metalog.append(
            {"kind": "gc_reclaim", "shard": 0, "log": "large", "segment": 0})
        self.metalog.append({
            "kind": "rescale_start", "scheme": "hash",
            "from": 1, "to": 2, "legs": [],
        })
        self.metalog.append({"kind": "rescale_finish"})
        self.metalog.append({
            "kind": "snapshot", "boundaries": [], "shards": [],
            "next_shard_id": 2, "migration": None, "cutoffs": {},
        })
        self.metalog.truncate(0)
