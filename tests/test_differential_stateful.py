"""Hypothesis stateful differential oracle (optional-deps policy: skips
without hypothesis; the deterministic streams in ``test_differential.py``
and the crash-site enumeration in ``test_crashpoints.py`` always run).

Random op interleavings — puts, updates, deletes, background splits/merges,
migration ticks, whole-fleet crash/recover, and injected crashes at
shard-metadata WAL record sites (``crash_after``) — drive a bare
ParallaxStore, a hash-ShardedStore and a RangeShardedStore alongside a plain
dict model; every get, scan and the full key set must agree at every step,
including while an incremental migration is in flight (double-routed reads)
and after it is interrupted by a crash and resumed.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import settings, strategies as st  # noqa: E402
from hypothesis.stateful import RuleBasedStateMachine, initialize, invariant, rule  # noqa: E402

from repro.core.metalog import CrashPoint  # noqa: E402
from repro.core.ycsb import make_key, payload  # noqa: E402

from test_differential import make_fleet  # noqa: E402

_KEYS = st.integers(min_value=0, max_value=80)
_SIZES = st.sampled_from([9, 104, 1004])


class DifferentialMachine(RuleBasedStateMachine):
    """Random op/migration/crash interleavings: three stores + a dict model
    must agree at every step."""

    @initialize()
    def setup(self):
        # small migration batches keep migrations in flight across many steps;
        # the fourth (lifetime-enabled) range store must stay byte-identical
        # through adaptive cutoff ticks and class-migrating GC
        self.fleet = make_fleet(90, num_shards=2, rebalance_window=60,
                                min_split_keys=4, migration_batch_keys=3,
                                lifetime_range=True)
        self.model: dict[bytes, bytes] = {}
        self.n = 0

    def _everywhere(self, fn):
        for store in self.fleet.values():
            fn(store)

    def _rng(self):
        return self.fleet["range"]

    def _hottest(self):
        rng = self._rng()
        return max(
            range(rng.num_shards),
            key=lambda i: len(rng.shards[i].live_keys_in(*rng.bounds(i))),
        )

    @rule(i=_KEYS, size=_SIZES)
    def put(self, i, size):
        self.n += 1
        k, v = make_key(i), (b"%6d|" % self.n) + payload(size)
        self._everywhere(lambda s: s.put(k, v))
        self.model[k] = v

    @rule(i=_KEYS, size=_SIZES)
    def update(self, i, size):
        self.n += 1
        k, v = make_key(i), (b"%6d~" % self.n) + payload(size)
        self._everywhere(lambda s: s.update(k, v))
        self.model[k] = v

    @rule(i=_KEYS)
    def delete(self, i):
        k = make_key(i)
        self._everywhere(lambda s: s.delete(k))
        self.model.pop(k, None)

    @rule(i=_KEYS)
    def get_agrees(self, i):
        k = make_key(i)
        expect = self.model.get(k)
        for name, store in self.fleet.items():
            assert store.get(k) == expect, name

    @rule(i=_KEYS, count=st.integers(min_value=1, max_value=30))
    def scan_agrees(self, i, count):
        start = make_key(i)
        expect = sorted((k, v) for k, v in self.model.items() if k >= start)[:count]
        for name, store in self.fleet.items():
            assert store.scan(start, count) == expect, name

    @rule()
    def rebalance(self):
        self._rng().rebalance_tick(force=True)

    # ------------------------------------------------- lifetime interleavings
    @rule()
    def lifetime_gc_tick(self):
        """Force GC on the lifetime store: per-class sweeps relocate and
        class-migrate values, drain parked cutoff proposals through the WAL
        (record-then-apply) and fence reclaims — all invisible to results."""
        self.fleet["range_lt"].gc_tick(force=True)

    @rule(offset=st.integers(min_value=0, max_value=3))
    def lifetime_crash_at_record(self, offset):
        """Arm an injected crash a few WAL records ahead on the *lifetime*
        store's metalog and drive GC into it: a crash at a cutoff record
        drops the cutover (never applied), a crash at a gc_reclaim fence
        leaves both copies of every relocated value — recovery must keep
        exactly one winner either way."""
        lt = self.fleet["range_lt"]
        lt.flush_all()
        lt.metalog.crash_after(lt.metalog.total_appended + offset)
        try:
            for _ in range(2):
                lt.gc_tick(force=True)
        except CrashPoint:
            lt.crash()
            lt.recover()
        finally:
            lt.metalog.disarm()

    # ------------------------------------------------ migration interleavings
    @rule()
    def split_hottest(self):
        rng = self._rng()
        if rng.migration is None and rng.num_shards < 6:
            rng.split(self._hottest(), background=True)

    @rule()
    def merge_coldest(self):
        rng = self._rng()
        if rng.migration is None and rng.num_shards > 1:
            cold = min(
                range(rng.num_shards - 1),
                key=lambda i: len(rng.shards[i].live_keys_in(*rng.bounds(i)))
                + len(rng.shards[i + 1].live_keys_in(*rng.bounds(i + 1))),
            )
            rng.merge(cold, background=True)

    @rule(budget=st.integers(min_value=1, max_value=8))
    def migration_tick(self, budget):
        self._rng().migration_tick(budget)

    # ---------------------------------------------------- crash interleavings
    @rule()
    def crash_recover(self):
        # equalize durability first (the dict model has no crash semantics):
        # the crash then loses only in-flight migration work, which recovery
        # must roll forward without losing or duplicating a key
        self._everywhere(lambda s: s.flush_all())
        for s in self.fleet.values():
            s.crash()
            s.recover()

    @rule(offset=st.integers(min_value=0, max_value=4))
    def crash_after(self, offset):
        """Arm an injected crash a few WAL records ahead, drive migration
        work into it, then crash+recover the range store: the interrupted
        protocol step must leave a recoverable, oracle-identical state."""
        rng = self._rng()
        self._everywhere(lambda s: s.flush_all())
        rng.metalog.crash_after(rng.metalog.n_records + offset)
        try:
            if rng.migration is None and rng.num_shards < 6:
                rng.split(self._hottest(), background=True)
            for _ in range(offset + 2):
                rng.migration_tick()
        except CrashPoint:
            rng.crash()
            rng.recover()
        finally:
            rng.metalog.disarm()

    @invariant()
    def key_sets_agree(self):
        if not hasattr(self, "fleet"):
            return  # invariant fires before @initialize on some versions
        expect = sorted(self.model)
        for name, store in self.fleet.items():
            got = [k for k, _ in store.scan(b"", 500)]
            assert got == expect, name


DifferentialMachine.TestCase.settings = settings(
    max_examples=15, stateful_step_count=25, deadline=None
)
TestDifferentialStateful = DifferentialMachine.TestCase
