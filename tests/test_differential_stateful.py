"""Hypothesis stateful differential oracle (optional-deps policy: skips
without hypothesis; the deterministic streams in ``test_differential.py``
always run).

Random op interleavings — puts, updates, deletes, forced rebalances — drive a
bare ParallaxStore, a hash-ShardedStore and a RangeShardedStore alongside a
plain dict model; every get, scan and the full key set must agree at every
step.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import settings, strategies as st  # noqa: E402
from hypothesis.stateful import RuleBasedStateMachine, initialize, invariant, rule  # noqa: E402

from repro.core.ycsb import make_key, payload  # noqa: E402

from test_differential import make_fleet  # noqa: E402

_KEYS = st.integers(min_value=0, max_value=80)
_SIZES = st.sampled_from([9, 104, 1004])


class DifferentialMachine(RuleBasedStateMachine):
    """Random op interleavings: three stores + a dict model must agree."""

    @initialize()
    def setup(self):
        self.fleet = make_fleet(90, num_shards=2, rebalance_window=60)
        self.model: dict[bytes, bytes] = {}
        self.n = 0

    def _everywhere(self, fn):
        for store in self.fleet.values():
            fn(store)

    @rule(i=_KEYS, size=_SIZES)
    def put(self, i, size):
        self.n += 1
        k, v = make_key(i), (b"%6d|" % self.n) + payload(size)
        self._everywhere(lambda s: s.put(k, v))
        self.model[k] = v

    @rule(i=_KEYS, size=_SIZES)
    def update(self, i, size):
        self.n += 1
        k, v = make_key(i), (b"%6d~" % self.n) + payload(size)
        self._everywhere(lambda s: s.update(k, v))
        self.model[k] = v

    @rule(i=_KEYS)
    def delete(self, i):
        k = make_key(i)
        self._everywhere(lambda s: s.delete(k))
        self.model.pop(k, None)

    @rule(i=_KEYS)
    def get_agrees(self, i):
        k = make_key(i)
        expect = self.model.get(k)
        for name, store in self.fleet.items():
            assert store.get(k) == expect, name

    @rule(i=_KEYS, count=st.integers(min_value=1, max_value=30))
    def scan_agrees(self, i, count):
        start = make_key(i)
        expect = sorted((k, v) for k, v in self.model.items() if k >= start)[:count]
        for name, store in self.fleet.items():
            assert store.scan(start, count) == expect, name

    @rule()
    def rebalance(self):
        self.fleet["range"].rebalance_tick(force=True)

    @invariant()
    def key_sets_agree(self):
        if not hasattr(self, "fleet"):
            return  # invariant fires before @initialize on some versions
        expect = sorted(self.model)
        for name, store in self.fleet.items():
            got = [k for k, _ in store.scan(b"", 500)]
            assert got == expect, name


DifferentialMachine.TestCase.settings = settings(
    max_examples=15, stateful_step_count=25, deadline=None
)
TestDifferentialStateful = DifferentialMachine.TestCase
