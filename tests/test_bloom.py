"""Per-level bloom filters: no false negatives, probe savings, state parity.

Property-style tests run over many seeded-random key sets without requiring
``hypothesis`` (tier-1 optional-deps policy: the suite must pass with only
the baked-in toolchain).
"""
import random

from repro.core import ParallaxStore, StoreConfig
from repro.core.lsm import BloomFilter
from repro.core.ycsb import make_key


def small_store(**kw) -> ParallaxStore:
    defaults = dict(mode="parallax", l0_capacity=1 << 12, cache_bytes=1 << 15,
                    segment_bytes=1 << 14, chunk_bytes=1 << 11)
    defaults.update(kw)
    return ParallaxStore(StoreConfig(**defaults))


def test_bloom_never_false_negative_property():
    """For arbitrary key sets, every added key answers 'maybe present'."""
    for seed in range(8):
        rng = random.Random(seed)
        n = rng.randrange(1, 400)
        keys = {rng.randbytes(rng.randrange(1, 48)) for _ in range(n)}
        bf = BloomFilter(len(keys), bits_per_key=rng.choice([4, 10, 16]))
        for k in keys:
            bf.add(k)
        assert all(k in bf for k in keys)  # no false negatives, ever


def test_bloom_false_positive_rate_is_bounded():
    keys = [make_key(i) for i in range(2000)]
    bf = BloomFilter(len(keys), bits_per_key=10)
    for k in keys:
        bf.add(k)
    absent = [make_key(i) for i in range(10_000, 14_000)]
    fp = sum(1 for k in absent if k in bf)
    assert fp / len(absent) < 0.05  # ~1% expected at 10 bits/key


def test_level_blooms_never_lose_a_key():
    """Store-level property: with blooms on, every written key stays readable
    across compactions (a false negative would surface as a lost key)."""
    st = small_store(bloom_bits_per_key=10)
    oracle = {}
    rng = random.Random(1)
    for i in range(4000):
        k = f"key{rng.randrange(1500):05d}".encode()
        v = bytes([i % 256]) * rng.choice([9, 104, 1004])
        st.put(k, v)
        oracle[k] = v
    assert len(st.levels) >= 2
    assert any(lvl.bloom is not None for lvl in st.levels)
    for k, v in oracle.items():
        assert st.get(k) == v


def test_bloom_skips_levels_and_saves_probes():
    """Missing-key gets skip every level; probe count drops vs blooms off."""
    stats = {}
    for bits in (0, 10):
        st = small_store(bloom_bits_per_key=bits)
        for i in range(3000):
            st.put(make_key(i), b"v" * 104)
        st.stats.index_probes = 0
        st.stats.bloom_skips = 0
        for i in range(500):
            st.get(make_key(i * 7))            # present
            st.get(make_key(50_000 + i))       # absent
        stats[bits] = (st.stats.index_probes, st.stats.bloom_skips)
    probes_off, skips_off = stats[0]
    probes_on, skips_on = stats[10]
    assert skips_off == 0
    assert skips_on > 0
    assert probes_on < probes_off
    # every avoided probe is accounted as a skip (multi-level tree)
    assert probes_on + skips_on == probes_off


def test_bloom_on_off_visible_state_identical():
    stores = []
    for bits in (0, 10):
        st = small_store(bloom_bits_per_key=bits)
        rng = random.Random(9)
        for _ in range(2500):
            k = f"key{rng.randrange(800):04d}".encode()
            if rng.random() < 0.1:
                st.delete(k)
            else:
                st.put(k, bytes([rng.randrange(256)]) * rng.choice([9, 104, 1004]))
        stores.append(st)
    off, on = stores
    assert off.scan(b"", 2000) == on.scan(b"", 2000)
    for i in range(800):
        k = f"key{i:04d}".encode()
        assert off.get(k) == on.get(k)


def test_bloom_disabled_leaves_levels_filterless():
    st = small_store(bloom_bits_per_key=0)
    for i in range(3000):
        st.put(make_key(i), b"v" * 104)
    assert all(lvl.bloom is None for lvl in st.levels)
    assert st.stats.bloom_skips == 0
