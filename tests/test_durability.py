"""Redo/durability ordering and cross-process determinism.

Covers the two seed bugs fixed in this PR:

* the redo record is only written after the transient (medium) log is flushed,
  and ``crash()`` drops any unflushed medium-log tail — so durable levels can
  never hold pointers into lost log bytes;
* the read path hashes with ``zlib.crc32`` instead of the per-process
  randomized ``hash()``, so amplification/stats are identical across runs.
"""
import os
import pathlib
import subprocess
import sys

from repro.core import ParallaxStore, StoreConfig
from repro.core.logs import LogEntry
from repro.core.lsm import CAT_MEDIUM


def small_store(**kw) -> ParallaxStore:
    defaults = dict(mode="parallax", l0_capacity=1 << 11, cache_bytes=1 << 14,
                    segment_bytes=1 << 14, chunk_bytes=1 << 10)
    defaults.update(kw)
    return ParallaxStore(StoreConfig(**defaults))


def _medium_payload(k: bytes) -> bytes:
    return (k * 20)[:104]


def test_crash_recover_across_compaction_with_medium_spill():
    """Crash right after compactions that spilled mediums to the transient log:
    recovery must still serve every durable key, including log-placed mediums."""
    st = small_store()
    history = []  # (lsn, key, value)
    for i in range(1500):
        k = f"key{i % 500:05d}".encode()
        v = _medium_payload(k) + str(i).encode()
        st.put(k, v)
        history.append((st.lsn, k, v))
    # the scenario under test: transient segments exist and are attached to
    # non-last levels (mediums spilled by compaction, not merged in place yet)
    assert st.medium_log.segments, "workload must spill mediums to the transient log"
    assert any(lvl.transient_segments for lvl in st.levels)
    cutoff = st.crash()
    st.recover()
    expect = {}
    for lsn, k, v in history:
        if lsn <= cutoff:
            expect[k] = v
    for i in range(500):
        k = f"key{i:05d}".encode()
        assert st.get(k) == expect.get(k), (k, cutoff)


def test_medium_log_flushed_before_every_redo_record():
    """No compaction may leave unflushed transient-log bytes behind its redo
    record (checked at every redo write via monkeypatching)."""
    st = small_store()
    orig = st._write_redo_record
    seen = []

    def checked():
        orig()
        seen.append(st.medium_log._unflushed)

    st._write_redo_record = checked
    for i in range(1500):
        st.put(f"key{i:05d}".encode(), _medium_payload(b"x"))
    assert seen, "expected compactions"
    assert all(u == 0 for u in seen)


def test_crash_drops_unflushed_medium_tail():
    st = small_store()
    for i in range(300):
        st.put(f"key{i:05d}".encode(), _medium_payload(b"y"))
    # simulate an append that never reached a group-commit boundary
    ptr = st.medium_log.append(LogEntry(st.lsn + 1, b"tail-key", b"m" * 104, CAT_MEDIUM))
    assert st.medium_log._unflushed > 0
    st.crash()
    seg = st.medium_log.segments.get(ptr.segment_id)
    assert seg is None or seg.entries[ptr.slot] is None
    assert st.medium_log._unflushed == 0
    st.recover()  # still consistent: recovery never touches the dropped tail
    assert st.get(b"tail-key") is None


def test_gc_relocations_durable_before_segment_reclaim():
    """Crash right after GC: relocated values must be durable, or shadowed
    level entries would resurface pointing into the reclaimed segment (the
    seed's kvstore_demo crashed exactly here with a KeyError on scan)."""
    st = small_store(l0_capacity=1 << 14, segment_bytes=1 << 16, chunk_bytes=1 << 12)
    for _ in range(3):
        for i in range(200):
            st.update(f"user{i:05d}".encode(), b"L" * 1004)
    assert st.gc_tick(force=True) > 0
    st.crash()
    st.recover()
    # no read may dereference a reclaimed segment
    assert len(st.scan(b"", 1000)) > 0
    for i in range(200):
        v = st.get(f"user{i:05d}".encode())
        assert v is None or v == b"L" * 1004


_DETERMINISM_SCRIPT = r"""
import random
from repro.core import ParallaxStore, StoreConfig
from repro.core.ycsb import Workload, execute

st = ParallaxStore(StoreConfig(l0_capacity=1 << 12, cache_bytes=1 << 15,
                               segment_bytes=1 << 14, chunk_bytes=1 << 11))
execute(st, Workload("load_a", "SD", num_keys=1500, num_ops=0, seed=13).load_ops())
execute(st, Workload("run_a", "SD", num_keys=1500, num_ops=600, seed=13).run_ops())
st.gc_tick(force=True)
print(st.amplification(), st.stats.index_probes, st.stats.bloom_skips,
      st.device.stats.bytes_read, st.device.stats.bytes_written,
      st.device.cache.hits, st.device.cache.misses)
"""


def test_amplification_deterministic_across_hash_seeds():
    """The same workload must produce bit-identical device traffic regardless
    of PYTHONHASHSEED (the seed used hash(key) to pick cache blocks)."""
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    outputs = []
    for seed in ("0", "424242"):
        proc = subprocess.run(
            [sys.executable, "-c", _DETERMINISM_SCRIPT],
            capture_output=True, text=True, check=True,
            env={**os.environ, "PYTHONPATH": src, "PYTHONHASHSEED": seed},
        )
        outputs.append(proc.stdout)
    assert outputs[0] == outputs[1], outputs
