"""Redo/durability ordering and cross-process determinism.

Covers the two seed bugs fixed in PR 1:

* the redo record is only written after the transient (medium) log is flushed,
  and ``crash()`` drops any unflushed medium-log tail — so durable levels can
  never hold pointers into lost log bytes;
* the read path hashes with ``zlib.crc32`` instead of the per-process
  randomized ``hash()``, so amplification/stats are identical across runs.

PR 2 extends the same ordering discipline to range-shard rebalancing: a split
copies the moved range, flushes the new shard, flips the boundary, and only
then tombstones the old range — so a crash in any migration window loses no
key and duplicates no key on either side of the moved boundary.
"""
import os
import pathlib
import subprocess
import sys

import pytest

from repro.core import ParallaxStore, RangeShardedStore, StoreConfig
from repro.core.logs import LogEntry
from repro.core.lsm import CAT_MEDIUM
from repro.core.ycsb import make_key


def small_store(**kw) -> ParallaxStore:
    defaults = dict(mode="parallax", l0_capacity=1 << 11, cache_bytes=1 << 14,
                    segment_bytes=1 << 14, chunk_bytes=1 << 10)
    defaults.update(kw)
    return ParallaxStore(StoreConfig(**defaults))


def _medium_payload(k: bytes) -> bytes:
    return (k * 20)[:104]


def test_crash_recover_across_compaction_with_medium_spill():
    """Crash right after compactions that spilled mediums to the transient log:
    recovery must still serve every durable key, including log-placed mediums."""
    st = small_store()
    history = []  # (lsn, key, value)
    for i in range(1500):
        k = f"key{i % 500:05d}".encode()
        v = _medium_payload(k) + str(i).encode()
        st.put(k, v)
        history.append((st.lsn, k, v))
    # the scenario under test: transient segments exist and are attached to
    # non-last levels (mediums spilled by compaction, not merged in place yet)
    assert st.medium_log.segments, "workload must spill mediums to the transient log"
    assert any(lvl.transient_segments for lvl in st.levels)
    cutoff = st.crash()
    st.recover()
    expect = {}
    for lsn, k, v in history:
        if lsn <= cutoff:
            expect[k] = v
    for i in range(500):
        k = f"key{i:05d}".encode()
        assert st.get(k) == expect.get(k), (k, cutoff)


def test_medium_log_flushed_before_every_redo_record():
    """No compaction may leave unflushed transient-log bytes behind its redo
    record (checked at every redo write via monkeypatching)."""
    st = small_store()
    orig = st._write_redo_record
    seen = []

    def checked():
        orig()
        seen.append(st.medium_log._unflushed)

    st._write_redo_record = checked
    for i in range(1500):
        st.put(f"key{i:05d}".encode(), _medium_payload(b"x"))
    assert seen, "expected compactions"
    assert all(u == 0 for u in seen)


def test_crash_drops_unflushed_medium_tail():
    st = small_store()
    for i in range(300):
        st.put(f"key{i:05d}".encode(), _medium_payload(b"y"))
    # simulate an append that never reached a group-commit boundary
    ptr = st.medium_log.append(LogEntry(st.lsn + 1, b"tail-key", b"m" * 104, CAT_MEDIUM))
    assert st.medium_log._unflushed > 0
    st.crash()
    seg = st.medium_log.segments.get(ptr.segment_id)
    assert seg is None or seg.entries[ptr.slot] is None
    assert st.medium_log._unflushed == 0
    st.recover()  # still consistent: recovery never touches the dropped tail
    assert st.get(b"tail-key") is None


def test_gc_relocations_durable_before_segment_reclaim():
    """Crash right after GC: relocated values must be durable, or shadowed
    level entries would resurface pointing into the reclaimed segment (the
    seed's kvstore_demo crashed exactly here with a KeyError on scan)."""
    st = small_store(l0_capacity=1 << 14, segment_bytes=1 << 16, chunk_bytes=1 << 12)
    for _ in range(3):
        for i in range(200):
            st.update(f"user{i:05d}".encode(), b"L" * 1004)
    assert st.gc_tick(force=True) > 0
    st.crash()
    st.recover()
    # no read may dereference a reclaimed segment
    assert len(st.scan(b"", 1000)) > 0
    for i in range(200):
        v = st.get(f"user{i:05d}".encode())
        assert v is None or v == b"L" * 1004


# --------------------------------------------------- rebalancing crash windows

class _CrashNow(Exception):
    pass


def _loaded_range_store(n_keys=600) -> RangeShardedStore:
    cfg = StoreConfig(l0_capacity=1 << 12, cache_bytes=1 << 15,
                      segment_bytes=1 << 14, chunk_bytes=1 << 11)
    st = RangeShardedStore.for_keys(
        [make_key(i) for i in range(n_keys)], 2, cfg, auto_rebalance=False,
    )
    st.put_many([(make_key(i), b"m" * 104) for i in range(n_keys)])
    st.flush_all()  # a clean durable base: the crash loses only migration work
    return st


def _assert_no_lost_or_dup(st: RangeShardedStore, n_keys: int) -> None:
    """Every key readable with its value; the global scan holds each exactly once."""
    for i in range(n_keys):
        assert st.get(make_key(i)) == b"m" * 104, i
    keys = [k for k, _ in st.scan(b"", 2 * n_keys)]
    assert keys == [make_key(i) for i in range(n_keys)]  # sorted, no dups


def test_crash_before_split_start_record_aborts_the_split():
    """Window A: crash before the ``split_start`` record lands — the split
    never was: the old shard still owns and serves the whole range, and the
    orphan destination shard is dropped by recovery replay."""
    from repro.core.metalog import CrashPoint

    st = _loaded_range_store()
    st.metalog.crash_after(st.metalog.n_records)  # the very next record dies
    with pytest.raises(CrashPoint):
        st._split(0)
    st.metalog.disarm()
    assert st.num_shards == 2  # metadata never flipped
    st.crash()
    st.recover()
    assert st.num_shards == 2 and len(st._all_stores()) == 2  # orphan dropped
    _assert_no_lost_or_dup(st, 600)
    # the map is still splittable afterwards
    assert st._split(0)
    _assert_no_lost_or_dup(st, 600)


def test_crash_after_boundary_flip_before_ranged_delete():
    """Window B: the boundary flipped (``split_start`` durable) and the first
    batch was copied+flushed, but its checkpoint record — and therefore the
    old shard's ranged delete — never happened.  Recovery resumes the
    migration at the start cursor; stale copies in the old shard must be
    unreachable (the new owner answers first, and below the cursor the old
    shard is never consulted)."""
    from repro.core.metalog import CrashPoint

    st = _loaded_range_store()
    st.metalog.crash_after(st.metalog.n_records + 1)  # split_start lands,
    with pytest.raises(CrashPoint):                   # 1st checkpoint dies
        st._split(0)
    st.metalog.disarm()
    assert st.num_shards == 3  # boundary flipped before the crash
    st.crash()
    st.recover()
    assert st.migration is not None  # the interrupted migration is live again
    assert st.migration.cursor == st.migration.lo  # no checkpoint was durable
    _assert_no_lost_or_dup(st, 600)
    # the stale copies really are still in the old shard (the ranged delete
    # never ran), proving double-routing is what protects reads
    lo, hi = st.bounds(0)
    assert st.shards[0].live_keys_in(hi, None), "expected stale migrated copies"
    # and the migration rolls forward to completion
    st.drain_migration()
    assert st.migration is None
    _assert_no_lost_or_dup(st, 600)


def test_crash_mid_ranged_delete_drops_unflushed_tombstones():
    """Window C: the crash hits while the old shard is tombstoning the moved
    range — unflushed tombstones are lost, resurrecting stale copies, which
    must stay invisible on both sides of the boundary."""
    st = _loaded_range_store()
    assert st._split(0)  # full split: copy + flip + ranged delete (unflushed)
    st.crash()          # some tombstones above the boundary may be lost
    st.recover()
    _assert_no_lost_or_dup(st, 600)
    # and the topology keeps rebalancing cleanly afterwards
    st._merge(0)
    _assert_no_lost_or_dup(st, 600)


def test_merge_after_crashed_split_cannot_resurrect_deleted_keys():
    """A merge that re-extends a shard's range over stale copies left by a
    crashed split must not resurrect keys deleted in the absorbed shard."""
    st = _loaded_range_store()
    src = st.shards[0]
    src.delete_range = lambda *a, **kw: (_ for _ in ()).throw(_CrashNow())
    with pytest.raises(_CrashNow):
        st._split(0)  # window B: boundary flipped, stale copies remain in src
    del src.delete_range
    st.crash()
    st.recover()
    # delete a migrated key: the tombstone lands in the new owner (shard 1)
    victim = st.boundaries[1]
    assert st.shard_of(victim) == 1
    st.delete(victim)
    assert st.get(victim) is None
    # absorbing shard 1 back must not expose shard 0's stale copy of victim
    st._merge(0)
    assert st.get(victim) is None, "crashed-split stale copy resurrected"
    keys = [k for k, _ in st.scan(b"", 1200)]
    assert victim not in keys
    assert keys == sorted(set(keys))


def test_migration_is_internal_work_not_application_traffic():
    """Split/merge migration charges the device but never application stats
    (same accounting discipline as GC relocations), so amplification
    comparisons between hash and range sharding stay honest."""
    st = _loaded_range_store()
    agg0 = st.aggregate_stats()
    dev0 = st.device_stats()
    assert st._split(0)
    st._merge(0)
    agg = st.aggregate_stats()
    assert agg.app_bytes == agg0.app_bytes
    assert agg.scans == agg0.scans
    assert agg.inserts == agg0.inserts and agg.deletes == agg0.deletes
    assert st.device_stats().total > dev0.total  # the device did pay


_DETERMINISM_SCRIPT = r"""
import random
from repro.core import ParallaxStore, StoreConfig
from repro.core.ycsb import Workload, execute

st = ParallaxStore(StoreConfig(l0_capacity=1 << 12, cache_bytes=1 << 15,
                               segment_bytes=1 << 14, chunk_bytes=1 << 11))
execute(st, Workload("load_a", "SD", num_keys=1500, num_ops=0, seed=13).load_ops())
execute(st, Workload("run_a", "SD", num_keys=1500, num_ops=600, seed=13).run_ops())
st.gc_tick(force=True)
print(st.amplification(), st.stats.index_probes, st.stats.bloom_skips,
      st.device.stats.bytes_read, st.device.stats.bytes_written,
      st.device.cache.hits, st.device.cache.misses)
"""


def test_amplification_deterministic_across_hash_seeds():
    """The same workload must produce bit-identical device traffic regardless
    of PYTHONHASHSEED (the seed used hash(key) to pick cache blocks)."""
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    outputs = []
    for seed in ("0", "424242"):
        proc = subprocess.run(
            [sys.executable, "-c", _DETERMINISM_SCRIPT],
            capture_output=True, text=True, check=True,
            env={**os.environ, "PYTHONPATH": src, "PYTHONHASHSEED": seed},
        )
        outputs.append(proc.stdout)
    assert outputs[0] == outputs[1], outputs
