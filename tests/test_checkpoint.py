"""LSM checkpointer: roundtrip, crash tolerance, GC, placement economics."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, _assemble
from repro.checkpoint.store import LogStructuredCheckpointer


def make_state(rng, step=0):
    return {
        "embed": rng.standard_normal((2000, 64)).astype(np.float32),     # ~512KB: large
        "ffn_w": rng.standard_normal((64, 256)).astype(np.float32),      # 64KB: large
        "medium": rng.standard_normal((80,)).astype(np.float32),         # 320B: medium
        "gain": rng.standard_normal((8,)).astype(np.float32),            # 32B: medium/small
        "scalar": np.float32(step),                                      # 4B: small -> inline
    }


def test_roundtrip(tmp_path):
    ck = LogStructuredCheckpointer(str(tmp_path), consolidate_every=100)
    rng = np.random.default_rng(0)
    state = make_state(rng)
    ck.save(0, state)
    out, step = ck.restore()
    assert step == 0
    for k, v in state.items():
        np.testing.assert_array_equal(out[k], np.asarray(v))


def test_incremental_and_consolidation(tmp_path):
    ck = LogStructuredCheckpointer(str(tmp_path), consolidate_every=4)
    rng = np.random.default_rng(1)
    state = make_state(rng)
    for step in range(10):
        state["ffn_w"] = state["ffn_w"] * 0.9
        state["scalar"] = np.float32(step)
        ck.save(step, state, changed={"ffn_w", "scalar"})
    out, step = ck.restore()
    assert step == 9
    np.testing.assert_allclose(out["ffn_w"], state["ffn_w"], rtol=1e-6)
    np.testing.assert_array_equal(out["embed"], state["embed"])
    # transient segments were reclaimed wholesale at consolidation
    tsegs = [f for f in os.listdir(tmp_path) if f.startswith("tseg-")]
    assert len(tsegs) <= 2


def test_torn_manifest_tail(tmp_path):
    ck = LogStructuredCheckpointer(str(tmp_path), consolidate_every=100)
    rng = np.random.default_rng(2)
    state = make_state(rng)
    ck.save(0, state)
    ck.save(1, state)
    with open(os.path.join(str(tmp_path), "MANIFEST"), "a") as f:
        f.write('{"key": "embed", "lsn": 999, "step"')  # torn write
    out, step = ck.restore()
    assert step == 1
    np.testing.assert_array_equal(out["embed"], state["embed"])


def test_torn_payload_falls_back_to_previous_step(tmp_path):
    """A payload segment truncated mid-write (the pre-atomic-rename failure
    mode) must not poison restore: it falls back to the previous step whose
    payloads all read back intact."""
    # gc_threshold > 1 disables GC so step 0's segment survives as the fallback
    ck = LogStructuredCheckpointer(str(tmp_path), consolidate_every=100, gc_threshold=1.1)
    rng = np.random.default_rng(5)
    state = make_state(rng)
    ck.save(0, state)
    prev_embed = state["embed"].copy()
    state["embed"] = state["embed"] + 1.0
    ck.save(1, state, changed={"embed"})  # embed lands alone in seg-1.log
    seg = os.path.join(str(tmp_path), "seg-1.log")
    with open(seg, "r+b") as f:
        f.truncate(os.path.getsize(seg) // 2)  # torn in-place write
    out, step = ck.restore()
    assert step == 0
    np.testing.assert_array_equal(out["embed"], prev_embed)
    np.testing.assert_array_equal(out["ffn_w"], state["ffn_w"])


def test_manager_2d_sharded_roundtrip(tmp_path):
    """Regression: keys are the canonical slice spec alone.  Two shards of a
    2-D array (distinct regions, distinct replica ids) must round-trip to the
    exact original — the old replica-prefixed key collapsed tuple-indexed
    shards onto one entry and the assembler zero-filled the gap silently."""

    class FakeShard:
        def __init__(self, data, index, replica_id=0):
            self.data = data
            self.index = index
            self.replica_id = replica_id

    class FakeSharded:
        def __init__(self, arr, shards):
            self.shape = arr.shape
            self.dtype = arr.dtype
            self.addressable_shards = shards

    full = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    shards = [
        FakeShard(full[0:32, :], (slice(0, 32), slice(0, 64)), replica_id=0),
        FakeShard(full[32:64, :], (slice(32, 64), slice(0, 64)), replica_id=1),
    ]
    mgr = CheckpointManager(str(tmp_path), consolidate_every=100)
    mgr.save(0, {"w": FakeSharded(full, shards)})
    out, step = mgr.restore({"w": jax.ShapeDtypeStruct(full.shape, full.dtype)})
    assert step == 0
    np.testing.assert_array_equal(out["w"], full)


def test_assemble_refuses_partial_coverage():
    """Regression: a missing shard part must raise, never restore zeros."""
    half = np.ones((4, 8), np.float32)
    with pytest.raises(RuntimeError, match="uncovered"):
        _assemble({"0-4_0-8": half}, (8, 8), np.float32)
    # the same parts with full coverage assemble fine
    got = _assemble({"0-4_0-8": half, "4-8_0-8": 2 * half}, (8, 8), np.float32)
    np.testing.assert_array_equal(got, np.vstack([half, 2 * half]))


def test_gc_reclaims_large_segments(tmp_path):
    ck = LogStructuredCheckpointer(str(tmp_path), consolidate_every=1000, gc_threshold=0.1)
    rng = np.random.default_rng(3)
    state = make_state(rng)
    for step in range(6):
        state["embed"] = state["embed"] + 1.0  # rewrite the large tensor
        ck.save(step, state)
    segs = [f for f in os.listdir(tmp_path) if f.startswith("seg-")]
    # GC keeps the live generation only, not 6 copies
    live_bytes = state["embed"].nbytes + state["ffn_w"].nbytes
    on_disk = sum(os.path.getsize(os.path.join(tmp_path, s)) for s in segs)
    assert on_disk < 3 * live_bytes
    out, _ = ck.restore()
    np.testing.assert_array_equal(out["embed"], state["embed"])


def test_hybrid_beats_inline_write_amp(tmp_path):
    """The paper's economics transplanted: hybrid placement writes less than
    consolidate-every-step inline checkpoints for update-heavy traces."""
    amps = {}
    for mode in ("hybrid", "inline"):
        d = tmp_path / mode
        ck = LogStructuredCheckpointer(str(d), mode=mode, consolidate_every=8)
        rng = np.random.default_rng(4)
        state = make_state(rng)
        for step in range(16):
            state["medium"] = state["medium"] + 0.1
            state["scalar"] = np.float32(step)
            ck.save(step, state, changed={"medium", "scalar"})
        amps[mode] = ck.device.stats.bytes_written
    assert amps["hybrid"] <= amps["inline"]


def test_manager_with_jax_pytree(tmp_path):
    mgr = CheckpointManager(str(tmp_path), consolidate_every=4)
    params = {
        "layer": {"w": jnp.arange(128, dtype=jnp.float32).reshape(8, 16), "b": jnp.ones((16,))},
        "step_count": jnp.zeros((), jnp.int32),
    }
    mgr.save(3, params)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    restored, step = mgr.restore(like)
    assert step == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    stats = mgr.stats()
    assert stats["write_amplification"] >= 1.0
