"""Tests for the runtime WAL-protocol monitor
(``repro.analysis.protocol.monitor``).

Covers the stream validator on hand-built good/bad streams, live engine
runs across all six partitioning x execution combos (zero false positives
is the acceptance bar), crash/recover mid-migration and mid-rescale with
replay validation, the planted flush-reorder bug (the acceptance-criteria
ordering bug, caught here at runtime and by the static pass via its
fixture), observational transparency (monitor on vs off byte-identical),
and the zero-overhead-off contract (debug off never imports the package —
subprocess-pinned).

A hypothesis property test drives random op/maintenance interleavings
against a live monitored engine when hypothesis is installed
(optional-deps policy: importorskip).
"""
from __future__ import annotations

import pathlib
import subprocess
import sys

import pytest

import repro.api as api
from repro.analysis.protocol.monitor import (
    ProtocolMonitor,
    ProtocolViolation,
    attach_store,
    store_is_clean,
)
from repro.core import RangeShardedStore, StoreConfig

REPO = pathlib.Path(__file__).resolve().parent.parent

COMBOS = [("none", "serial"), ("none", "async"),
          ("hash:2", "serial"), ("hash:2", "async"),
          ("range:2", "serial"), ("range:2", "async")]


def small_config(**kw) -> StoreConfig:
    defaults = dict(l0_capacity=1 << 12, cache_bytes=1 << 15,
                    segment_bytes=1 << 14, chunk_bytes=1 << 11)
    defaults.update(kw)
    return StoreConfig(**defaults)


def open_engine(partitioning="range:2", execution="serial", **kw) -> api.Engine:
    return api.open(api.EngineConfig(store=small_config(),
                                     partitioning=partitioning,
                                     execution=execution, **kw))


# ------------------------------------------------------- stream validation --


def test_valid_lifecycle_stream_accepted():
    mon = ProtocolMonitor()
    n = mon.validate_stream([
        {"kind": "init", "boundaries": [b""], "shards": [0]},
        {"kind": "cutoff", "shard": 0, "t_sm": 1, "t_ml": 2},
        {"kind": "split_start", "src": 0, "dst": 1, "at": b"m",
         "hi": None, "epoch": 0},
        {"kind": "checkpoint", "cursor": b"m"},
        {"kind": "finish"},
        {"kind": "gc_reclaim", "shard": 0, "log": "large", "segment": 0},
        {"kind": "rescale_start", "scheme": "hash", "from": 1, "to": 2,
         "legs": [[0, 1, 7]]},
        {"kind": "checkpoint", "cursor": b"", "leg": 0},
        {"kind": "finish", "leg": 0},
        {"kind": "rescale_finish"},
        {"kind": "snapshot", "boundaries": [b""], "shards": [0],
         "next_shard_id": 2, "migration": None, "cutoffs": {}},
    ])
    assert n == 11 and mon.records_checked == 11


def test_snapshot_can_root_a_truncated_stream():
    ProtocolMonitor().validate_stream([
        {"kind": "snapshot", "boundaries": [b""], "shards": [0],
         "next_shard_id": 1, "migration": None, "cutoffs": {}},
        {"kind": "cutoff", "shard": 0, "t_sm": 1, "t_ml": 2},
    ])


def test_rescale_start_can_open_a_stream():
    # the hash front-end's lazily created metalog: first record is the rescale
    ProtocolMonitor().validate_stream([
        {"kind": "rescale_start", "scheme": "hash", "from": 2, "to": 4,
         "legs": [[0, 2, 5], [1, 3, 5]]},
        {"kind": "checkpoint", "cursor": b"", "leg": 0},
        {"kind": "finish", "leg": 0},
        {"kind": "finish", "leg": 1},
        {"kind": "rescale_finish"},
    ])


def _violates(records) -> str:
    with pytest.raises(ProtocolViolation) as exc:
        ProtocolMonitor().validate_stream(records)
    return str(exc.value)


def test_rejects_unknown_kind():
    msg = _violates([{"kind": "init", "boundaries": [], "shards": []},
                     {"kind": "compact_start"}])
    assert "not declared" in msg


def test_rejects_non_start_kind_opening_stream():
    msg = _violates([{"kind": "cutoff", "shard": 0, "t_sm": 1, "t_ml": 2}])
    assert "cannot open a WAL stream" in msg


def test_rejects_mid_stream_init():
    msg = _violates([{"kind": "init", "boundaries": [], "shards": []},
                     {"kind": "init", "boundaries": [], "shards": []}])
    assert "genesis" in msg


def test_rejects_payload_mismatch():
    msg = _violates([{"kind": "init", "boundaries": [], "shards": []},
                     {"kind": "checkpoint", "cur": b"k"}])
    assert "payload mismatch" in msg


def test_rejects_checkpoint_with_no_leg_in_flight():
    msg = _violates([{"kind": "init", "boundaries": [], "shards": []},
                     {"kind": "checkpoint", "cursor": b"k"}])
    assert "no migration leg in flight" in msg


def test_rejects_unknown_rescale_leg():
    msg = _violates([
        {"kind": "rescale_start", "scheme": "hash", "from": 1, "to": 2,
         "legs": [[0, 1, 5]]},
        {"kind": "checkpoint", "cursor": b"", "leg": 9},
    ])
    assert "not active" in msg


def test_rejects_early_rescale_finish():
    msg = _violates([
        {"kind": "rescale_start", "scheme": "hash", "from": 1, "to": 2,
         "legs": [[0, 1, 5]]},
        {"kind": "rescale_finish"},
    ])
    assert "still active" in msg


def test_rejects_overlapping_migrations():
    msg = _violates([
        {"kind": "init", "boundaries": [], "shards": []},
        {"kind": "split_start", "src": 0, "dst": 1, "at": b"m",
         "hi": None, "epoch": 0},
        {"kind": "merge_start", "src": 1, "dst": 0, "lo": b"a",
         "hi": b"z", "epoch": 0},
    ])
    assert "already in flight" in msg


def test_violation_carries_record_window():
    with pytest.raises(ProtocolViolation) as exc:
        ProtocolMonitor().validate_stream([
            {"kind": "init", "boundaries": [], "shards": []},
            {"kind": "checkpoint", "cursor": b"k"},
        ])
    assert exc.value.record == {"kind": "checkpoint", "cursor": b"k"}
    assert len(exc.value.window) == 2
    assert "offending record window" in str(exc.value)


# --------------------------------------------------- live engines: no FPs ---


def _exercise(eng: api.Engine) -> None:
    for i in range(200):
        eng.put(b"m%05d" % i, b"v" * (i % 23 + 1))
    for _ in range(6):
        eng.migration_tick()
    eng.flush_all()
    eng.gc_tick(force=True)
    for i in range(0, 200, 9):
        assert eng.get(b"m%05d" % i) == b"v" * (i % 23 + 1)
    assert len(eng.scan(b"m00000", 40)) == 40


@pytest.mark.parametrize("partitioning,execution", COMBOS)
def test_all_combos_run_clean_under_monitor(partitioning, execution):
    with open_engine(partitioning, execution, debug_checks=True) as eng:
        _exercise(eng)
        if partitioning.startswith("hash") or partitioning == "none":
            pass  # hash metalog is lazy: no records without a rescale
        else:
            assert eng.protocol_monitor is not None
            assert eng.protocol_monitor.records_checked > 0
        if partitioning == "none" and execution == "serial":
            assert eng.protocol_monitor is None  # bare store: no WAL


@pytest.mark.parametrize("partitioning", ["hash:2", "range:2"])
def test_rescale_runs_clean_under_monitor(partitioning):
    with open_engine(partitioning, "async", debug_checks=True) as eng:
        for i in range(150):
            eng.put(b"r%05d" % i, b"w" * 9)
        eng.rescale(4)
        for _ in range(300):
            if eng.topology()["rescale"] is None:
                break
            eng.migration_tick()
        assert eng.topology()["rescale"] is None
        assert eng.protocol_monitor is not None
        assert eng.protocol_monitor.records_checked > 0
        for i in range(0, 150, 11):
            assert eng.get(b"r%05d" % i) == b"w" * 9


def test_crash_recover_mid_migration_validates_replay():
    with open_engine("range:2", "serial", debug_checks=True) as eng:
        for i in range(150):
            eng.put(b"c%05d" % i, b"x" * 40)
        eng.flush_all()
        eng.migration_tick()
        eng.crash()
        eng.recover()
        for i in range(0, 150, 7):
            assert eng.get(b"c%05d" % i) == b"x" * 40
        assert eng.protocol_monitor.replays_checked >= 1


def test_crash_recover_mid_rescale_validates_replay():
    with open_engine("range:2", "serial", debug_checks=True) as eng:
        for i in range(150):
            eng.put(b"c%05d" % i, b"x" * 40)
        eng.flush_all()
        eng.rescale(4)
        eng.migration_tick()  # part-way through the legs
        eng.crash()
        eng.recover()
        for _ in range(300):
            if eng.topology()["rescale"] is None:
                break
            eng.migration_tick()
        for i in range(0, 150, 7):
            assert eng.get(b"c%05d" % i) == b"x" * 40
        assert eng.protocol_monitor.replays_checked >= 1
        assert eng.protocol_monitor.records_checked > 0


def test_snapshot_truncate_cycle_clean_under_monitor(tmp_path):
    with open_engine("range:2", "serial", debug_checks=True,
                     snapshot_dir=str(tmp_path)) as eng:
        for i in range(120):
            eng.put(b"s%05d" % i, b"y" * 25)
        eng.migration_tick()
        eng.snapshot()
        for i in range(120, 160):
            eng.put(b"s%05d" % i, b"y" * 25)
        eng.snapshot()
        assert eng.protocol_monitor.records_checked > 0


# ------------------------------------------------------- planted bug ---------


def test_planted_flush_reorder_caught_live():
    """The acceptance-criteria bug: the destination's flush is disabled so a
    migration checkpoint commits while the copied batch is still volatile —
    the monitor must raise at the exact offending append.  (The static twin
    of this bug is ``tests/fixtures/protocol_bad/fence_flush_reordered.py``.)
    """
    st = RangeShardedStore(2, small_config(), auto_rebalance=False,
                           migration_batch_keys=16)
    monitor = attach_store(st)
    assert monitor is not None
    for i in range(200):
        st.put(b"p%05d" % i, b"z" * 60)
    assert st._split(0, at=b"p00050", background=True)
    dst = st._by_id[st._migrations[0].dst_id]
    dst.flush_all = lambda: None  # the planted reorder: fence becomes a no-op
    with pytest.raises(ProtocolViolation) as exc:
        for _ in range(50):
            st.migration_tick()
    assert "flush-before-append fence broken" in str(exc.value)
    assert not store_is_clean(dst)


def test_unpatched_migration_is_fence_clean():
    # control for the planted-bug test: same run, fence intact, no violation
    st = RangeShardedStore(2, small_config(), auto_rebalance=False,
                           migration_batch_keys=16)
    monitor = attach_store(st)
    for i in range(200):
        st.put(b"p%05d" % i, b"z" * 60)
    assert st._split(0, at=b"p00050", background=True)
    for _ in range(50):
        st.migration_tick()
    assert st.migration is None
    assert monitor.records_checked >= 3  # init, split_start, checkpoints...


# ------------------------------------------------ transparency / off=off ----


def _run_workload(eng: api.Engine):
    out = []
    for i in range(150):
        eng.put(b"w%04d" % i, b"x" * (i % 17 + 1))
    for _ in range(4):
        eng.migration_tick()
    for i in range(0, 150, 5):
        out.append(eng.get(b"w%04d" % i))
    out.append(eng.scan(b"w0000", 25))
    eng.gc_tick(force=True)
    return out, eng.stats()


@pytest.mark.parametrize("partitioning,execution",
                         [("range:2", "serial"), ("hash:2", "async")])
def test_monitor_is_observationally_transparent(partitioning, execution):
    with open_engine(partitioning, execution, debug_checks=False) as eng:
        plain_out, plain_stats = _run_workload(eng)
    with open_engine(partitioning, execution, debug_checks=True) as eng:
        mon_out, mon_stats = _run_workload(eng)
    assert mon_out == plain_out
    assert mon_stats == plain_stats


def test_debug_off_no_monitor_no_import(monkeypatch):
    monkeypatch.delenv("REPRO_DEBUG_CHECKS", raising=False)
    with open_engine(debug_checks=False) as eng:
        assert eng.protocol_monitor is None
        assert not getattr(eng._store, "metalog", None) or \
            not getattr(eng._store.metalog, "_protocol_monitored", False)


def test_debug_off_never_imports_protocol_package():
    # the strongest zero-overhead statement, subprocess-pinned: a full
    # workload with checks off loads nothing under repro.analysis at all
    script = (
        "import sys\n"
        "import repro.api as api\n"
        "from repro.core import StoreConfig\n"
        "cfg = api.EngineConfig(store=StoreConfig(l0_capacity=1<<12),\n"
        "                       partitioning='range:2')\n"
        "with api.open(cfg) as eng:\n"
        "    for i in range(64):\n"
        "        eng.put(b'k%02d' % i, b'v')\n"
        "    eng.migration_tick()\n"
        "assert not any(m.startswith('repro.analysis') for m in sys.modules), \\\n"
        "    sorted(m for m in sys.modules if m.startswith('repro.analysis'))\n"
    )
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr


# ------------------------------------------------------ property test --------


def test_random_interleavings_have_zero_false_positives(tmp_path):
    hyp = pytest.importorskip("hypothesis")
    st_mod = pytest.importorskip("hypothesis.strategies")
    given, settings = hyp.given, hyp.settings

    ops = st_mod.lists(
        st_mod.tuples(st_mod.sampled_from(["put", "delete", "tick", "flush",
                                           "gc", "snapshot", "crashrec"]),
                      st_mod.integers(min_value=0, max_value=127)),
        min_size=1, max_size=40)

    @settings(max_examples=25, deadline=None)
    @given(ops=ops)
    def run(ops):
        with open_engine("range:2", "serial", debug_checks=True,
                         snapshot_dir=str(tmp_path)) as eng:
            for op, i in ops:
                key = b"h%04d" % i
                if op == "put":
                    eng.put(key, b"v" * (i % 29 + 1))
                elif op == "delete":
                    eng.delete(key)
                elif op == "tick":
                    eng.migration_tick()
                elif op == "flush":
                    eng.flush_all()
                elif op == "gc":
                    eng.gc_tick(force=True)
                elif op == "snapshot":
                    eng.snapshot()
                elif op == "crashrec":
                    eng.crash()
                    eng.recover()
            # a ProtocolViolation anywhere above is a monitor false positive
            assert eng.protocol_monitor.records_checked >= 1

    run()
