"""Property battery for the lifetime sketch (optional-deps policy: skips
without hypothesis; the deterministic smoke checks in ``test_store.py`` /
``test_differential.py`` always run).

Four families of properties, each the load-bearing guarantee of one design
decision in :mod:`repro.core.lifetime`:

* **Determinism** — the sketch is crc32-keyed, so identical ``(key, lsn)``
  streams yield identical estimates/classifications in different processes
  under different ``PYTHONHASHSEED`` (the no-``hash()`` contract; without it
  the differential oracle could not replay lifetime-enabled engines).
* **Monotonicity** — with collisions ruled out by construction, a key updated
  at smaller inter-update distances never estimates lower than the same key
  updated at larger distances over the same LSN span.
* **Window eviction** — once a key's estimate decays to zero after two epoch
  rotations without an update, no stream of *other* keys' observations can
  resurrect it: rotation only ever zeroes counters and observations only
  increment cells the key does not share (collision-free construction).
* **Oracle twin** — against :class:`~repro.core.lifetime.LifetimeOracle`
  (exact per-key update lists, brute-force collision mass) the sketch's
  estimate is an *equality*, not a bound: ``estimate == true_count +
  min-over-rows collision mass`` — and therefore never underestimates the
  windowed true count.
"""
import os
import pathlib
import subprocess
import sys

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.lifetime import (  # noqa: E402
    CLASS_LONG,
    CLASS_SHORT,
    LifetimeConfig,
    LifetimeOracle,
    LifetimeSketch,
)

_SMALL = LifetimeConfig(window=32, rows=3, width=64, ring_size=64)

# streams are (key_index, lsn_gap) pairs; LSNs are cumulative gaps so they
# are strictly increasing like the store's write LSNs
_STREAMS = st.lists(
    st.tuples(st.integers(min_value=0, max_value=12),
              st.integers(min_value=1, max_value=20)),
    min_size=1, max_size=120,
)


def _feed(sketch, oracle, stream):
    lsn = 0
    for ki, gap in stream:
        lsn += gap
        key = b"key-%03d" % ki
        sketch.observe(key, lsn)
        if oracle is not None:
            oracle.observe(key, lsn)
    return lsn


# ------------------------------------------------------------- determinism
_DETERMINISM_SCRIPT = r"""
import sys
from repro.core.lifetime import LifetimeConfig, LifetimeSketch

stream = eval(sys.stdin.read())
sk = LifetimeSketch(LifetimeConfig(window=32, rows=3, width=64, ring_size=64))
lsn = 0
for ki, gap in stream:
    lsn += gap
    sk.observe(b"key-%03d" % ki, lsn)
print([(ki, sk.estimate(b"key-%03d" % ki), sk.classify(b"key-%03d" % ki))
       for ki in range(13)])
print(sorted(sk.ring), sk.state())
"""


@settings(max_examples=8, deadline=None)
@given(stream=_STREAMS)
def test_sketch_deterministic_across_processes(stream):
    """Same stream, different PYTHONHASHSEED: bit-identical estimates,
    classifications, ring and state."""
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    outputs = []
    for seed in ("1", "31337"):
        proc = subprocess.run(
            [sys.executable, "-c", _DETERMINISM_SCRIPT],
            input=repr(stream), capture_output=True, text=True, check=True,
            env={**os.environ, "PYTHONPATH": src, "PYTHONHASHSEED": seed},
        )
        outputs.append(proc.stdout)
    assert outputs[0] == outputs[1]


# ------------------------------------------------------------ monotonicity
@settings(max_examples=60, deadline=None)
@given(
    updates=st.integers(min_value=2, max_value=12),
    tight=st.integers(min_value=1, max_value=5),
    slack=st.integers(min_value=1, max_value=8),
)
def test_estimate_monotone_in_update_distance(updates, tight, slack):
    """One key, no collisions possible (single key): shrinking every
    inter-update gap never lowers the windowed estimate, because fewer
    updates fall out of the two-epoch window."""
    loose = tight + slack
    est = {}
    for gap in (tight, loose):
        sk = LifetimeSketch(_SMALL)
        lsn = 0
        for _ in range(updates):
            lsn += gap
            sk.observe(b"k", lsn)
        est[gap] = sk.estimate(b"k")
    assert est[tight] >= est[loose]
    # and the dense stream's estimate is exact (nothing to collide with)
    assert est[tight] == min(updates, 2 * _SMALL.window // tight + 1)


# --------------------------------------------------------- window eviction
@settings(max_examples=60, deadline=None)
@given(stream=_STREAMS, idle_epochs=st.integers(min_value=2, max_value=5))
def test_window_eviction_never_resurrects(stream, idle_epochs):
    """After a key decays out of the paired window, feeding arbitrary other
    keys can only ever keep its estimate at the collision floor — it can
    never climb back to CLASS_SHORT without the key itself being updated.
    Uses a dedicated victim key and re-checks against the oracle so collision
    mass is accounted exactly."""
    sk = LifetimeSketch(_SMALL)
    orc = LifetimeOracle(_SMALL)
    victim = b"victim"
    sk.observe(victim, 1)
    sk.observe(victim, 2)
    orc.observe(victim, 1)
    orc.observe(victim, 2)
    assert sk.classify(victim) == CLASS_SHORT
    # idle the victim past two rotations, then replay the noise stream
    base = (idle_epochs + 1) * _SMALL.window
    lsn = base
    for ki, gap in stream:
        lsn += gap
        key = b"noise-%03d" % ki
        sk.observe(key, lsn)
        orc.observe(key, lsn)
    # the victim's true windowed count is zero; whatever the sketch reports
    # is purely collision mass, exactly as the oracle predicts
    assert orc.true_count(victim) == 0
    assert sk.estimate(victim) == orc.expected_estimate(victim)


def test_rotation_only_zeroes_counters():
    """The eviction mechanism itself: a rotation moves cur->prev and an
    epoch jump zeroes both — no rotation path ever *increases* a counter."""
    sk = LifetimeSketch(_SMALL)
    sk.observe(b"a", 1)
    before = sk.estimate(b"a")
    sk.observe(b"z", _SMALL.window * 10)  # jump >= 2 epochs
    assert sk.epoch == 10
    assert sk.estimate(b"a") <= before
    assert sk.estimate(b"a") == 0 or sk._cells(b"a") == sk._cells(b"z")


# ------------------------------------------------------------- oracle twin
@settings(max_examples=80, deadline=None)
@given(stream=_STREAMS)
def test_sketch_equals_oracle_exactly(stream):
    """The reference-twin property: for every key the stream touched, the
    sketch's estimate equals the oracle's collision-aware expectation
    *exactly*, the estimate never undershoots the windowed true count, and
    the two sides classify identically."""
    sk = LifetimeSketch(_SMALL)
    orc = LifetimeOracle(_SMALL)
    _feed(sk, orc, stream)
    assert sk.epoch == orc.epoch
    for key in orc.updates:
        assert sk.estimate(key) == orc.expected_estimate(key), key
        assert sk.estimate(key) >= orc.true_count(key), key
        assert sk.classify(key) == orc.classify(key), key
    # a key never observed carries only collision mass and must not be
    # classified short unless colliders make it so — again oracle-exact
    ghost = b"never-seen"
    assert sk.estimate(ghost) == orc.expected_estimate(ghost)


@settings(max_examples=40, deadline=None)
@given(stream=_STREAMS)
def test_never_seen_key_defaults_long_on_fresh_sketch(stream):
    """Fresh inserts must prove themselves hot: an untouched sketch maps
    everything to CLASS_LONG (estimate 0)."""
    sk = LifetimeSketch(_SMALL)
    for ki, _ in stream:
        assert sk.classify(b"key-%03d" % ki) == CLASS_LONG
