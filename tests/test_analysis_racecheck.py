"""Tests for the lockset race detector (``repro.analysis.racecheck``).

Three layers: the Eraser state machine itself (``LocksetChecker`` /
``ChecksafeLock`` unit tests), the instrumentation attached to a real engine
(planted races are flagged, disciplined code is silent, a full async
range-sharded workload with migration runs report-free), and the engine
contract (byte-identical stats on/off, ``RaceViolation`` on close, the
``REPRO_DEBUG_CHECKS`` env switch, and provably-zero overhead when off).
"""
from __future__ import annotations

import pathlib
import subprocess
import sys
import threading

import pytest

import repro.api as api
from repro.analysis import racecheck
from repro.analysis.lint import FRONTEND_COUNTERS
from repro.analysis.racecheck import (
    ChecksafeLock,
    LocksetChecker,
    MONITORED_COUNTERS,
    RaceReport,
    RaceViolation,
)
from repro.core import StoreConfig

REPO = pathlib.Path(__file__).resolve().parent.parent


def small_config(**kw) -> StoreConfig:
    defaults = dict(l0_capacity=1 << 12, cache_bytes=1 << 15,
                    segment_bytes=1 << 14, chunk_bytes=1 << 11)
    defaults.update(kw)
    return StoreConfig(**defaults)


def open_engine(partitioning="hash:2", execution="async", **kw) -> api.Engine:
    return api.open(api.EngineConfig(store=small_config(),
                                     partitioning=partitioning,
                                     execution=execution, **kw))


def in_thread(fn) -> None:
    t = threading.Thread(target=fn, name="rc-test-worker")
    t.start()
    t.join()


# ------------------------------------------------------------ ChecksafeLock --


def test_checksafe_lock_tracks_holding_thread():
    lock = ChecksafeLock("t")
    assert not lock.locked()
    with lock:
        assert lock.locked()
        assert lock in racecheck._held()
    assert lock not in racecheck._held()


def test_checksafe_lock_nonblocking_contended():
    lock = ChecksafeLock("t")
    lock.acquire()
    results = {}

    def attempt():
        results["ok"] = lock.acquire(blocking=False)
        results["held"] = lock in racecheck._held()

    in_thread(attempt)
    lock.release()
    # the failed acquire must not register in the worker's lockset
    assert results == {"ok": False, "held": False}


def test_checksafe_lock_wraps_existing_lock_once():
    checker = LocksetChecker()
    raw = threading.Lock()
    wrapped = checker.wrap_lock(raw, "outer")
    assert isinstance(wrapped, ChecksafeLock)
    assert checker.wrap_lock(wrapped, "again") is wrapped


# ----------------------------------------------------------- state machine --


def test_single_thread_access_never_reports():
    checker = LocksetChecker()
    for _ in range(100):
        checker.access("v", write=True)
    assert checker.reports == []


def test_unlocked_cross_thread_write_reports_once():
    checker = LocksetChecker()
    checker.access("v", write=True)
    in_thread(lambda: (checker.access("v", write=True),
                       checker.access("v", write=True)))
    assert len(checker.reports) == 1
    (report,) = checker.reports
    assert report.var == "v" and report.write and report.lockset == ()


def test_common_lock_keeps_sharing_silent():
    checker = LocksetChecker()
    lock = ChecksafeLock("shared")

    def bump():
        with lock:
            checker.access("v", write=True)

    bump()
    in_thread(bump)
    assert checker.reports == []


def test_disjoint_locks_are_not_synchronization():
    checker = LocksetChecker()
    a, b = ChecksafeLock("a"), ChecksafeLock("b")
    with a:
        checker.access("v", write=True)

    def other():
        with b:
            checker.access("v", write=True)

    in_thread(other)
    # Eraser refines the candidate set on each access: after the second
    # thread it is {b}; the next access under {a} empties it -> report
    with a:
        checker.access("v", write=True)
    assert len(checker.reports) == 1


def test_shared_reads_alone_do_not_report():
    checker = LocksetChecker()
    checker.access("v", write=False)
    in_thread(lambda: checker.access("v", write=False))
    assert checker.reports == []


def test_barrier_is_a_sequence_point():
    checker = LocksetChecker()
    checker.access("v", write=True)
    checker.barrier()
    in_thread(lambda: checker.access("v", write=True))
    assert checker.reports == []  # ordered by the barrier, not a race
    assert checker.barriers == 1


def test_check_coordinator_flags_second_submitter():
    checker = LocksetChecker()
    checker.check_coordinator("put_many")
    checker.check_coordinator("put_many")  # same thread: fine
    in_thread(lambda: checker.check_coordinator("scan"))
    assert len(checker.reports) == 1
    assert checker.reports[0].var == "executor.scan"


def test_raise_if_violations():
    checker = LocksetChecker()
    checker.raise_if_violations()  # clean: no-op
    checker.reports.append(RaceReport("v", True, "t", (), "planted"))
    with pytest.raises(RaceViolation, match="planted"):
        checker.raise_if_violations()


def test_monitored_counters_match_linter_vocabulary():
    # the dynamic detector and the static linter must police the same set
    assert MONITORED_COUNTERS == FRONTEND_COUNTERS


# ----------------------------------------------------- engine: planted race --


def test_planted_unlocked_counter_bump_is_flagged():
    eng = open_engine(debug_checks=True)
    store = eng.store
    in_thread(lambda: store.__setattr__("gets", store.gets + 1))
    store.gets += 1  # main thread, also unlocked: no common lock
    checker = eng.race_checker
    assert any(r.var == "frontend.gets" for r in checker.reports)
    with pytest.raises(RaceViolation):
        eng.close()


def test_disciplined_twin_is_silent():
    with open_engine(debug_checks=True) as eng:
        store = eng.store

        def locked_bump():
            with store._stats_lock:
                store.gets += 1

        locked_bump()
        in_thread(locked_bump)
        assert eng.race_checker.reports == []


# --------------------------------------------------- engine: real workloads --


def test_async_range_workload_with_migration_is_race_free():
    keys = [b"k%05d" % i for i in range(300)]
    with open_engine(partitioning="range:3", execution="async",
                     debug_checks=True) as eng:
        for k in keys:
            eng.put(k, b"v" + k)
        for _ in range(8):
            eng.migration_tick()
        eng.gc_tick(force=True)
        for k in keys[::7]:
            assert eng.get(k) == b"v" + k
        assert len(eng.scan(b"k00000", 50)) == 50
        checker = eng.race_checker
        assert checker.events > 0, "instrumentation never fired"
        assert checker.barriers > 0, "drain barrier never fired"
        assert checker.reports == []


@pytest.mark.parametrize("partitioning,to_shards",
                         [("hash:2", 4), ("range:2", 4)])
def test_async_rescale_concurrent_legs_race_free(partitioning, to_shards):
    """The elastic-rescale path under the detector: an online rescale on a
    serving async engine — multiple legs advanced through the executor's
    disjoint-pair scheduling, double-routed point reads, the owner-resolved
    merged scan, and (grow) shards created mid-session — must close
    report-free with the machinery engaged."""
    keys = [b"k%05d" % i for i in range(300)]
    with open_engine(partitioning=partitioning, execution="async",
                     debug_checks=True) as eng:
        for k in keys:
            eng.put(k, b"v" + k)
        eng.rescale(to_shards)
        for _ in range(200):
            if eng.topology()["rescale"] is None:
                break
            eng.migration_tick()
            for k in keys[::61]:          # reads overlap the draining legs
                assert eng.get(k) == b"v" + k
            assert len(eng.scan(b"k00100", 20)) == 20
        t = eng.topology()
        assert t["rescale"] is None and t["shards"] == to_shards
        for k in keys[::7]:
            assert eng.get(k) == b"v" + k
        assert len(eng.scan(b"k00000", 50)) == 50
        checker = eng.race_checker
        assert checker.events > 0, "instrumentation never fired"
        assert checker.barriers > 0, "drain barrier never fired"
        assert checker.reports == []


def test_lifetime_gc_and_cutover_race_free():
    """PR 8 paths under the detector: sketch observation on the write path,
    short-log placement and per-class GC (with the coordinator's gc_reclaim
    fence journaling) plus the drained cutoff cutover, on an async range
    engine — all must close report-free and with the machinery engaged."""
    from repro.core import LifetimeConfig

    cfg = api.EngineConfig(
        store=small_config(lifetime=LifetimeConfig(
            window=128, adapt_every=32, min_ring=8, ring_size=32)),
        partitioning="range:2", execution="async", debug_checks=True)
    with api.open(cfg) as eng:
        hot = [b"k%05d" % i for i in range(16)]
        for i in range(120):
            eng.put(b"k%05d" % i, b"v" * 1000)
        for round_ in range(6):
            for k in hot:
                eng.update(k, b"%d" % round_ + b"v" * 1000)
            eng.flush_all()
            eng.gc_tick(force=True)
        for k in hot:
            assert eng.get(k) == b"5" + b"v" * 1000
        stats = eng.stats()
        lt = stats["lifetime"]["shards"]
        assert sum(s["observed"] for s in lt) > 0
        assert stats["device"]["short_log_written"] > 0
        assert sum(s["cutoff_adaptations"] for s in lt) >= 1
        checker = eng.race_checker
        assert checker.events > 0, "instrumentation never fired"
        assert checker.reports == []


def test_crash_recover_under_detector():
    keys = [b"c%04d" % i for i in range(120)]
    with open_engine(partitioning="range:2", execution="serial",
                     debug_checks=True) as eng:
        for k in keys:
            eng.put(k, k * 3)
        eng.flush_all()
        eng.crash()
        eng.recover()
        for k in keys:
            assert eng.get(k) == k * 3
        assert eng.race_checker.reports == []


def _run_workload(eng: api.Engine) -> tuple[list, dict]:
    out = []
    for i in range(150):
        eng.put(b"w%04d" % i, b"x" * (i % 17 + 1))
    for _ in range(4):
        eng.migration_tick()
    for i in range(0, 150, 5):
        out.append(eng.get(b"w%04d" % i))
    out.append(eng.scan(b"w0000", 25))
    eng.gc_tick(force=True)
    return out, eng.stats()


@pytest.mark.parametrize("partitioning,execution",
                         [("hash:2", "async"), ("range:2", "async"),
                          ("none", "serial")])
def test_detector_is_observationally_transparent(partitioning, execution):
    # identical workload, detector on vs off: results AND stats byte-identical
    with open_engine(partitioning, execution, debug_checks=False) as eng:
        plain_out, plain_stats = _run_workload(eng)
    with open_engine(partitioning, execution, debug_checks=True) as eng:
        debug_out, debug_stats = _run_workload(eng)
        assert eng.race_checker.reports == []
    assert debug_out == plain_out
    assert debug_stats == plain_stats


# -------------------------------------------------------- off means *off* --


def test_debug_off_structurally_untouched():
    with open_engine(debug_checks=False) as eng:
        assert eng.race_checker is None
        assert not type(eng.store).__name__.startswith("Checked")
        assert not isinstance(eng.store._stats_lock, ChecksafeLock)
        assert "drain" not in vars(eng._executor)
        assert "_new_store_lock" not in vars(eng._executor)


def test_debug_off_never_imports_racecheck(monkeypatch):
    # the strongest zero-overhead statement: without debug_checks the
    # detector module is never even imported
    script = (
        "import sys\n"
        "import repro.api as api\n"
        "from repro.core import StoreConfig\n"
        "cfg = StoreConfig(l0_capacity=1 << 12, cache_bytes=1 << 15,\n"
        "                  segment_bytes=1 << 14, chunk_bytes=1 << 11)\n"
        "with api.open(api.EngineConfig(store=cfg, partitioning='hash:2',\n"
        "                               execution='async')) as eng:\n"
        "    for i in range(50):\n"
        "        eng.put(b'k%03d' % i, b'v')\n"
        "    assert eng.get(b'k007') == b'v'\n"
        "assert not any(m.startswith('repro.analysis') for m in sys.modules), \\\n"
        "    sorted(m for m in sys.modules if m.startswith('repro.analysis'))\n"
    )
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_env_var_enables_detector(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_CHECKS", "1")
    with open_engine() as eng:
        assert eng.race_checker is not None
    for off in ("", "0", "false", "off"):
        monkeypatch.setenv("REPRO_DEBUG_CHECKS", off)
        with open_engine() as eng:
            assert eng.race_checker is None


def test_new_shards_from_splits_are_instrumented():
    with open_engine(partitioning="range:2", execution="serial",
                     debug_checks=True) as eng:
        before = len(eng.store._all_stores())
        shard = eng.store._new_shard()
        assert getattr(shard, "_race_wrapped", False), \
            "shards created after attach must be instrumented too"
        assert before >= 2
