"""Differential store oracle: one logical history, three physical layouts.

The same YCSB op stream is replayed through a bare :class:`ParallaxStore`, the
hash-partitioned :class:`ShardedStore`, and the range-partitioned
:class:`RangeShardedStore` (with its skew rebalancer live), and the three must
agree byte-for-byte on every get, every scan, and the final live key set —
partitioning, batching, bloom filters and split/merge migration are all
invisible to correctness.  A crash/recover in the middle of a rebalance must
not break the agreement either (acceptance criterion for PR 2).

A hypothesis stateful version drives random op interleavings against a dict
model when hypothesis is installed (optional-deps policy: importorskip) —
see ``tests/test_differential_stateful.py``; this module's deterministic
streams always run.
"""
import dataclasses

import pytest

import repro.api as api
from repro.core import (
    LifetimeConfig,
    ParallaxStore,
    RangeShardedStore,
    ShardedStore,
    StoreConfig,
)
from repro.core.ycsb import Workload, execute, make_key, payload

# small windows so sketch rotation, cutoff adaptation and per-class GC all
# engage within a few hundred ops (shared by the stateful machine too)
LIFETIME_SMALL = LifetimeConfig(window=128, adapt_every=32, min_ring=8, ring_size=32)


def small_config(**kw) -> StoreConfig:
    defaults = dict(l0_capacity=1 << 12, cache_bytes=1 << 15,
                    segment_bytes=1 << 14, chunk_bytes=1 << 11)
    defaults.update(kw)
    return StoreConfig(**defaults)


def make_fleet(num_keys: int, num_shards: int = 3, rebalance_window: int = 200,
               lifetime_range: bool = False, **range_kw):
    """The three front-ends under differential test, bare store first.
    ``lifetime_range=True`` adds a fourth, lifetime-enabled range store: its
    placement (short/long value logs, adaptive cutoffs) must be invisible to
    every correctness observable."""
    fleet = {
        "bare": ParallaxStore(small_config()),
        "hash": ShardedStore(num_shards, small_config(bloom_bits_per_key=10)),
        "range": RangeShardedStore.for_keys(
            [make_key(i) for i in range(num_keys)], num_shards,
            small_config(bloom_bits_per_key=10),
            rebalance_window=rebalance_window, **range_kw,
        ),
    }
    if lifetime_range:
        fleet["range_lt"] = RangeShardedStore.for_keys(
            [make_key(i) for i in range(num_keys)], num_shards,
            small_config(bloom_bits_per_key=10, lifetime=LIFETIME_SMALL),
            rebalance_window=rebalance_window, **range_kw,
        )
    return fleet


def replay(fleet: dict, ops_factory) -> None:
    """Replay one op stream into every store (fresh iterator per store)."""
    for name, store in fleet.items():
        execute(store, ops_factory(), batch_size=0 if name == "bare" else 32)


def assert_agree(fleet: dict, num_keys: int) -> None:
    bare = fleet["bare"]
    probe = [make_key(i) for i in range(num_keys + 50)]
    expect_gets = [bare.get(k) for k in probe]
    full = bare.scan(b"", 2 * num_keys + 100)
    # the full scan *is* the final live key set (sorted, each key once)
    keys_only = [k for k, _ in full]
    assert keys_only == sorted(set(keys_only))
    for name, store in fleet.items():
        if name == "bare":
            continue
        got = store.get_many(probe)
        assert got == expect_gets, f"{name}: get mismatch"
        assert store.scan(b"", 2 * num_keys + 100) == full, f"{name}: full scan mismatch"
        for start, count in ((make_key(num_keys // 3), 40), (make_key(num_keys - 5), 30), (b"", 7)):
            assert store.scan(start, count) == bare.scan(start, count), (name, start)


def test_differential_load_and_point_ops():
    fleet = make_fleet(900)
    replay(fleet, lambda: Workload("load_a", "SD", num_keys=900, num_ops=0, seed=21).load_ops())
    replay(fleet, lambda: Workload("run_a", "SD", num_keys=900, num_ops=500, seed=21).run_ops())
    assert_agree(fleet, 900)


def test_differential_scan_heavy_with_live_rebalancer():
    # a hair-trigger policy so the balanced pre-split still splits/merges
    # under the mild residual skew of the scattered zipfian hot keys
    fleet = make_fleet(800, rebalance_window=150, split_factor=1.05, merge_factor=0.9)
    replay(fleet, lambda: Workload("load_e", "SD", num_keys=800, num_ops=0, seed=22).load_ops())
    replay(fleet, lambda: Workload("run_e", "SD", num_keys=800, num_ops=400, seed=22).run_ops())
    # the oracle is only interesting if the range topology actually moved
    assert fleet["range"].splits + fleet["range"].merges > 0
    assert_agree(fleet, 800 + 400)  # run_e inserts new keys past num_keys


def test_differential_deletes_and_reinserts():
    fleet = make_fleet(600)
    replay(fleet, lambda: Workload("load_a", "MD", num_keys=600, num_ops=0, seed=23).load_ops())
    doomed = [make_key(i) for i in range(100, 300, 2)]
    for name, store in fleet.items():
        if name == "bare":
            for k in doomed:
                store.delete(k)
        else:
            store.delete_many(doomed)
    back = [(make_key(i), payload(104)) for i in range(150, 250, 4)]
    for name, store in fleet.items():
        if name == "bare":
            for k, v in back:
                store.put(k, v)
        else:
            store.put_many(back)
    assert_agree(fleet, 600)


def test_differential_migration_perpetually_in_flight():
    """Same YCSB stream, migration throttled to 1-key batches vs unthrottled
    vs hash front-end: gets/scans/key-sets must be identical *while a
    migration is in flight* — double-routing (writes to the new owner, reads
    falling back to the draining old shard) is invisible to correctness."""
    num_keys = 700
    keys = [make_key(i) for i in range(num_keys)]
    policy = dict(rebalance_window=120, split_factor=1.05, merge_factor=0.9)
    fleet = {
        "bare": ParallaxStore(small_config()),
        "hash": ShardedStore(3, small_config(bloom_bits_per_key=10)),
        "range-throttled": RangeShardedStore.for_keys(
            keys, 3, small_config(bloom_bits_per_key=10),
            migration_batch_keys=1, **policy,
        ),
        "range-unthrottled": RangeShardedStore.for_keys(
            keys, 3, small_config(bloom_bits_per_key=10),
            migration_batch_keys=1 << 30, **policy,
        ),
    }
    replay(fleet, lambda: Workload("load_a", "SD", num_keys=num_keys, num_ops=0, seed=31).load_ops())
    replay(fleet, lambda: Workload("run_a", "SD", num_keys=num_keys, num_ops=500, seed=31).run_ops())
    throttled = fleet["range-throttled"]
    assert throttled.splits + throttled.merges > 0
    assert throttled.migration_ticks > 0
    # 1-key batches cannot drain a migration within the run: one must still be
    # in flight (force one if the policy happened to go quiet at the end)
    if throttled.migration is None:
        hot = max(range(throttled.num_shards),
                  key=lambda i: len(throttled.shards[i].live_keys_in(*throttled.bounds(i))))
        assert throttled._split(hot, background=True)
    assert throttled.migration is not None
    assert_agree(fleet, num_keys)                       # mid-flight agreement
    assert throttled.migration is not None              # ... and still in flight
    assert throttled.get_fallbacks > 0                  # old shard really served reads
    throttled.drain_migration()
    assert throttled.migration is None
    assert_agree(fleet, num_keys)                       # drained agreement


def test_differential_rescale_while_serving_matches_quiesced():
    """Rescale-while-serving oracle: the same YCSB run stream through (a) an
    online 2->4 rescale whose legs drain *between* traffic batches and (b) a
    quiesced rescale (drained before any traffic) must produce byte-identical
    gets and scans — on both sharded schemes — and both must match a bare
    store.  Double-routed reads, post-flip writes landing on new owners, and
    the concurrent-leg merge scan are all invisible to correctness."""
    nk = 600

    def load_ops():
        return Workload("load_a", "SD", num_keys=nk, num_ops=0, seed=37).load_ops()

    def run_ops():
        return Workload("run_a", "SD", num_keys=nk, num_ops=400, seed=37).run_ops()

    bare = ParallaxStore(small_config())
    execute(bare, load_ops(), batch_size=0)
    execute(bare, run_ops(), batch_size=0)
    probe = [make_key(i) for i in range(nk + 50)]
    expect = [bare.get(k) for k in probe]
    full = bare.scan(b"", 2 * nk + 100)

    def build(scheme):
        if scheme == "hash":
            return ShardedStore(2, small_config(bloom_bits_per_key=10),
                                migration_batch_keys=16)
        return RangeShardedStore.for_keys(
            [make_key(i) for i in range(nk)], 2,
            small_config(bloom_bits_per_key=10), auto_rebalance=False,
            migration_batch_keys=16)

    for scheme in ("hash", "range"):
        online, quiesced = build(scheme), build(scheme)
        for st in (online, quiesced):
            execute(st, load_ops(), batch_size=32)

        assert online.rescale(4) == 2           # two legs, in flight under load
        ops = list(run_ops())
        served_mid_rescale = False
        for lo in range(0, len(ops), 40):
            # range legs also drain at batch boundaries *inside* execute
            # (_after_batch), so the in-flight check precedes the chunk
            served_mid_rescale |= online._rescale is not None
            execute(online, iter(ops[lo:lo + 40]), batch_size=32)
            online.migration_tick()
        assert served_mid_rescale, scheme       # traffic really overlapped legs
        online.drain_migration(max_ticks=10_000)

        assert quiesced.rescale(4) == 2         # same plan, drained up front
        quiesced.drain_migration(max_ticks=10_000)
        execute(quiesced, iter(ops), batch_size=32)

        for label, st in (("online", online), ("quiesced", quiesced)):
            assert st.num_shards == 4, (scheme, label)
            assert st.get_many(probe) == expect, (scheme, label)
            assert st.scan(b"", 2 * nk + 100) == full, (scheme, label)
        assert online.migrated_keys > 0 and quiesced.migrated_keys > 0
        if scheme == "range":
            assert online.boundaries == quiesced.boundaries


# ---------------------------------------------------------------- repro.api
# Acceptance (PR 5): the same YCSB streams through repro.api.Engine for
# {none, hash, range} x {serial, async} must be byte-identical to the legacy
# front-ends — results, StoreStats, DeviceStats, and (range) the metadata-WAL
# record stream — because the engine *composes* the legacy paths, it does not
# reimplement them.

RANGE_POLICY = dict(rebalance_window=150, split_factor=1.05, merge_factor=0.9)


def engine_fleet(num_keys: int) -> dict[str, api.Engine]:
    """One engine per partitioning x execution combination, configured to
    mirror :func:`make_fleet`'s legacy stores exactly."""
    keys = [make_key(i) for i in range(num_keys)]
    range_part = api.PartitioningConfig.range_for_keys(keys, 3, **RANGE_POLICY)
    fleet = {}
    for mode in ("serial", "async"):
        fleet[f"none-{mode}"] = api.open(api.EngineConfig(
            store=small_config(), execution=mode))
        fleet[f"hash-{mode}"] = api.open(api.EngineConfig(
            store=small_config(bloom_bits_per_key=10), partitioning="hash:3",
            execution=mode))
        fleet[f"range-{mode}"] = api.open(api.EngineConfig(
            store=small_config(bloom_bits_per_key=10), partitioning=range_part,
            execution=mode))
    return fleet


def legacy_twin(name: str, legacy_fleet: dict):
    return legacy_fleet[name.split("-", 1)[0].replace("none", "bare")]


def assert_engine_state_matches_legacy(engine: api.Engine, legacy) -> None:
    """Full-state agreement beyond results: aggregate StoreStats, aggregate
    and per-store DeviceStats, front-end routing counters, and for the range
    scheme the topology + the metadata-WAL record stream."""
    store = engine.store
    if isinstance(legacy, ParallaxStore):
        # the none-partitioned engine: aggregate stats equal the bare store's
        # (the async wrapper adds front-end counters on top, nothing else)
        agg = store.stats if isinstance(store, ParallaxStore) else store.aggregate_stats()
        dev = store.device.stats if isinstance(store, ParallaxStore) else store.device_stats()
        assert dataclasses.asdict(agg) == dataclasses.asdict(legacy.stats)
        assert dataclasses.asdict(dev) == dataclasses.asdict(legacy.device.stats)
        return
    assert dataclasses.asdict(store.aggregate_stats()) == dataclasses.asdict(legacy.aggregate_stats())
    assert [dataclasses.asdict(s.device.stats) for s in store._all_stores()] == \
        [dataclasses.asdict(s.device.stats) for s in legacy._all_stores()]
    assert (store.gets, store.get_probes) == (legacy.gets, legacy.get_probes)
    assert (store.scans, store.scan_probes) == (legacy.scans, legacy.scan_probes)
    if isinstance(legacy, RangeShardedStore):
        assert store.boundaries == legacy.boundaries
        assert store._shard_ids == legacy._shard_ids
        assert store.metalog.records == legacy.metalog.records
        assert store.get_fallbacks == legacy.get_fallbacks


def test_engine_matches_legacy_all_combos():
    num_keys = 700
    legacy = make_fleet(num_keys, rebalance_window=150,
                        split_factor=1.05, merge_factor=0.9)
    engines = engine_fleet(num_keys)
    streams = [
        lambda: Workload("load_a", "SD", num_keys=num_keys, num_ops=0, seed=41).load_ops(),
        lambda: Workload("run_a", "SD", num_keys=num_keys, num_ops=400, seed=41).run_ops(),
    ]
    try:
        for ops_factory in streams:
            replay(legacy, ops_factory)
            for name, eng in engines.items():
                # the legacy replay drove bare per-op and sharded at batch 32
                bs = 0 if name == "none-serial" else 32
                api.execute(eng, ops_factory(), batch_size=bs)
        assert legacy["range"].splits + legacy["range"].merges > 0  # policy live
        for name, eng in engines.items():
            assert_engine_state_matches_legacy(eng, legacy_twin(name, legacy))
        # results through the uniform surface agree with the bare oracle
        bare = legacy["bare"]
        probe = [make_key(i) for i in range(num_keys + 50)]
        expect = [bare.get(k) for k in probe]
        full = bare.scan(b"", 2 * num_keys + 100)
        for name, eng in engines.items():
            assert [eng.get(k) for k in probe] == expect, name
            assert eng.scan(b"", 2 * num_keys + 100) == full, name
            assert list(eng.iterator()) == full, name
    finally:
        for eng in engines.values():
            eng.close()


def test_engine_crash_recover_mid_migration_matches_legacy():
    """Crash with a migration in flight: legacy serial range store vs the
    async engine — recovered topology, WAL stream and state stay identical."""
    nk = 500
    keys = [make_key(i) for i in range(nk)]
    params = dict(auto_rebalance=False, migration_batch_keys=1)
    legacy = RangeShardedStore.for_keys(
        keys, 3, small_config(bloom_bits_per_key=10), **params)
    eng = api.open(api.EngineConfig(
        store=small_config(bloom_bits_per_key=10),
        partitioning=api.PartitioningConfig.range_for_keys(keys, 3, **params),
        execution=api.ExecutionConfig(mode="async", workers=4),
    ))
    try:
        load = lambda: Workload("load_a", "SD", num_keys=nk, num_ops=0, seed=43).load_ops()
        run = lambda s: Workload("run_a", "SD", num_keys=nk, num_ops=30, seed=s).run_ops()
        execute(legacy, load(), batch_size=32)
        api.execute(eng, load(), batch_size=32)
        for st, drive in ((legacy, None), (eng.store, eng)):
            (st.flush_all if drive is None else drive.flush_all)()
            hot = max(range(st.num_shards),
                      key=lambda i: len(st.shards[i].live_keys_in(*st.bounds(i))))
            assert st._split(hot, background=True)
            if drive is None:
                st.migration_tick()
            else:
                drive.migration_tick()
        execute(legacy, run(44), batch_size=32, migrate_budget=1)
        api.execute(eng, run(44), batch_size=32, migrate_budget=1)
        assert legacy.migration is not None and eng.store.migration is not None
        legacy.crash(), legacy.recover()
        eng.crash(), eng.recover()
        assert legacy.migration is not None and eng.store.migration is not None
        assert eng.store.metalog.records == legacy.metalog.records
        # resume under traffic, then drain both and re-check everything
        execute(legacy, run(45), batch_size=32, migrate_budget=64)
        api.execute(eng, run(45), batch_size=32, migrate_budget=64)
        legacy.drain_migration()
        eng.store.drain_migration()  # queues are drained after api.execute
        assert legacy.migration is None and eng.store.migration is None
        assert_engine_state_matches_legacy(eng, legacy)
        probe = [make_key(i) for i in range(nk + 20)]
        assert [eng.get(k) for k in probe] == [legacy.get(k) for k in probe]
        assert list(eng.iterator()) == legacy.scan(b"", 2 * nk)
    finally:
        eng.close()


def test_engine_snapshot_restore_clone_all_combos(tmp_path):
    """PR 7 acceptance: snapshot/truncate/restore/clone over every
    partitioning x execution combo — with the snapshot taken while a
    throttled 1-key-batch migration is in flight on the range engines.

    Per engine: a clone serves byte-identical reads and then diverges
    independently in both directions; module-level ``restore()`` rebuilds an
    equal engine from the manifest; in-place ``restore()`` rolls the source's
    divergence back; and the restored engine survives crash + recovery and
    drains its resumed migration to completion.
    """
    nk = 400
    keys = [make_key(i) for i in range(nk)]
    part = api.PartitioningConfig.range_for_keys(
        keys, 3, auto_rebalance=False, migration_batch_keys=1)
    fleet = {}
    for mode in ("serial", "async"):
        fleet[f"none-{mode}"] = api.open(api.EngineConfig(
            store=small_config(), execution=mode))
        fleet[f"hash-{mode}"] = api.open(api.EngineConfig(
            store=small_config(bloom_bits_per_key=10), partitioning="hash:3",
            execution=mode))
        fleet[f"range-{mode}"] = api.open(api.EngineConfig(
            store=small_config(bloom_bits_per_key=10), partitioning=part,
            execution=mode))
    spawned: list[api.Engine] = []
    try:
        load = lambda: Workload("load_a", "SD", num_keys=nk, num_ops=0, seed=51).load_ops()
        run = lambda: Workload("run_a", "SD", num_keys=nk, num_ops=200, seed=51).run_ops()
        for eng in fleet.values():
            api.execute(eng, load(), batch_size=32)
            api.execute(eng, run(), batch_size=32)
        probe = [make_key(i) for i in range(nk + 30)]
        for name, eng in fleet.items():
            if name.startswith("range"):
                # put a throttled migration in flight before the snapshot
                eng.flush_all()
                st = eng.store
                hot = max(range(st.num_shards),
                          key=lambda i: len(st.shards[i].live_keys_in(*st.bounds(i))))
                assert st._split(hot, background=True)
                eng.migration_tick()
                assert st.migration is not None, name
            expect = [eng.get(k) for k in probe]
            full = eng.scan(b"", 2 * nk + 100)
            path = str(tmp_path / f"{name}.json")
            assert eng.snapshot(path) == path
            if name.startswith("range"):
                # truncate_on_snapshot (default): WAL rooted at the snapshot
                assert eng.store.metalog.replay()[0]["kind"] == "snapshot", name
                assert eng.store.migration is not None, name  # not drained by it
            # clone: identical reads, then independent divergence both ways
            c = eng.clone()
            spawned.append(c)
            assert [c.get(k) for k in probe] == expect, name
            assert c.scan(b"", 2 * nk + 100) == full, name
            c.put(b"zz-clone", b"1")
            eng.put(b"zz-src", b"2")
            assert eng.get(b"zz-clone") is None and c.get(b"zz-src") is None, name
            # a fresh engine from the manifest equals the snapshot point
            fresh = api.restore(path)
            spawned.append(fresh)
            assert [fresh.get(k) for k in probe] == expect, name
            assert fresh.scan(b"", 2 * nk + 100) == full, name
            # in-place restore rolls the source's divergence back
            eng.restore(path)
            assert eng.get(b"zz-src") is None, name
            assert [eng.get(k) for k in probe] == expect, name
            # the restored state is durable-recoverable, and the resumed
            # migration rolls forward to completion
            eng.flush_all()
            eng.crash()
            eng.recover()
            assert [eng.get(k) for k in probe] == expect, name
            if name.startswith("range"):
                assert eng.store.migration is not None, name
                eng.store.drain_migration()
                assert eng.store.migration is None, name
            assert eng.scan(b"", 2 * nk + 100) == full, name
        # after the dust settles, all six combos still agree byte-for-byte
        oracle = fleet["none-serial"].scan(b"", 2 * nk + 100)
        for name, eng in fleet.items():
            assert eng.scan(b"", 2 * nk + 100) == oracle, name
    finally:
        for eng in list(fleet.values()) + spawned:
            eng.close()


# ------------------------------------------------------------------ lifetime
# Acceptance (lifetime PR): lifetime-aware placement is a *physical* layout
# change — short/long value-log split, class migrations during GC, adaptive
# cutoff cutovers — and must be invisible to every correctness observable.
# Results (gets, scans, key sets) are compared byte-for-byte between lifetime
# on and off across all six partitioning x execution combos; stats are
# allowed (expected!) to differ.

def _lifetime_engine_fleet(num_keys: int, lifetime: LifetimeConfig | None) -> dict[str, api.Engine]:
    keys = [make_key(i) for i in range(num_keys)]
    part = api.PartitioningConfig.range_for_keys(keys, 3, **RANGE_POLICY)
    fleet = {}
    for mode in ("serial", "async"):
        fleet[f"none-{mode}"] = api.open(api.EngineConfig(
            store=small_config(lifetime=lifetime), execution=mode))
        fleet[f"hash-{mode}"] = api.open(api.EngineConfig(
            store=small_config(bloom_bits_per_key=10, lifetime=lifetime),
            partitioning="hash:3", execution=mode))
        fleet[f"range-{mode}"] = api.open(api.EngineConfig(
            store=small_config(bloom_bits_per_key=10, lifetime=lifetime),
            partitioning=part, execution=mode))
    return fleet


def test_lifetime_on_vs_off_results_identical_all_combos():
    """The same update-distance-skewed YCSB streams (hot_update_frac riding
    the zipf head, LD mix so Large values hit the value logs, periodic GC)
    through every combo with lifetime on and off: byte-identical gets, scans
    and key sets — while the lifetime machinery demonstrably engaged."""
    nk = 500
    on = _lifetime_engine_fleet(nk, LIFETIME_SMALL)
    off = _lifetime_engine_fleet(nk, None)
    streams = [
        lambda: Workload("load_a", "LD", num_keys=nk, num_ops=0, seed=61).load_ops(),
        lambda: Workload("run_a", "LD", num_keys=nk, num_ops=600, seed=61,
                         hot_update_frac=0.6, hot_update_keys=32).run_ops(),
    ]
    try:
        for ops_factory in streams:
            for eng in list(on.values()) + list(off.values()):
                api.execute(eng, ops_factory(), batch_size=32, gc_every=100)
        probe = [make_key(i) for i in range(nk + 50)]
        oracle_gets = [off["none-serial"].get(k) for k in probe]
        oracle_scan = off["none-serial"].scan(b"", 2 * nk + 100)
        for name in on:
            for fleet, label in ((on, "on"), (off, "off")):
                eng = fleet[name]
                assert [eng.get(k) for k in probe] == oracle_gets, (name, label)
                assert eng.scan(b"", 2 * nk + 100) == oracle_scan, (name, label)
        # the lifetime machinery really ran: short-log traffic and sketch
        # observations on every lifetime engine, none on the off fleet
        for name, eng in on.items():
            s = eng.stats()
            assert "lifetime" in s, name
            shards = s["lifetime"]["shards"] if "shards" in s["lifetime"] else [s["lifetime"]]
            assert sum(sh["observed"] for sh in shards) > 0, name
            assert s["device"]["short_log_written"] > 0, name
        for name, eng in off.items():
            s = eng.stats()
            assert "lifetime" not in s, name
            assert s["device"]["short_log_written"] == 0, name
        # range engines journaled adaptive cutoffs through the metadata WAL
        kinds = [r["kind"] for r in on["range-serial"].store.metalog.replay()]
        assert "cutoff" in kinds
    finally:
        for eng in list(on.values()) + list(off.values()):
            eng.close()


def test_lifetime_crash_recover_mid_migration_matches_off():
    """Crash with both a range migration and lifetime GC in flight: the
    recovered lifetime engine (replayed cutoff records, re-split value logs)
    must keep serving byte-identically to its lifetime-off twin through
    resume and drain."""
    nk = 400
    keys = [make_key(i) for i in range(nk)]

    def build(lifetime):
        part = api.PartitioningConfig.range_for_keys(
            keys, 3, auto_rebalance=False, migration_batch_keys=1)
        return api.open(api.EngineConfig(
            store=small_config(bloom_bits_per_key=10, lifetime=lifetime),
            partitioning=part))

    on, off = build(LIFETIME_SMALL), build(None)
    try:
        load = lambda: Workload("load_a", "LD", num_keys=nk, num_ops=0, seed=71).load_ops()
        run = lambda s, n: Workload("run_a", "LD", num_keys=nk, num_ops=n, seed=s,
                                    hot_update_frac=0.6, hot_update_keys=32).run_ops()
        for eng in (on, off):
            api.execute(eng, load(), batch_size=32)
            api.execute(eng, run(72, 300), batch_size=32, gc_every=60)
            eng.flush_all()
            st = eng.store
            hot = max(range(st.num_shards),
                      key=lambda i: len(st.shards[i].live_keys_in(*st.bounds(i))))
            assert st._split(hot, background=True)
            api.execute(eng, run(73, 40), batch_size=32, migrate_budget=1)
            assert st.migration is not None
            eng.flush_all()
            eng.crash()
            eng.recover()
            assert st.migration is not None  # resumes where the WAL left it
        # the recovered lifetime store reinstalled its journaled cutoffs
        lt_policies = [(s.policy.t_sm, s.policy.t_ml) for s in on.store._all_stores()]
        assert any(p != (on.config.store.t_sm, on.config.store.t_ml) for p in lt_policies)
        for eng in (on, off):
            api.execute(eng, run(74, 60), batch_size=32, migrate_budget=64, gc_every=30)
            eng.store.drain_migration()
            assert eng.store.migration is None
        probe = [make_key(i) for i in range(nk + 20)]
        assert [on.get(k) for k in probe] == [off.get(k) for k in probe]
        assert on.scan(b"", 2 * nk) == off.scan(b"", 2 * nk)
    finally:
        on.close()
        off.close()


class _CrashNow(Exception):
    pass


def test_differential_crash_mid_rebalance():
    """Acceptance: the three stores still agree after a crash/recover that
    interrupts a range-shard split between the boundary flip and the old
    shard dropping its migrated range."""
    fleet = make_fleet(700)
    replay(fleet, lambda: Workload("load_a", "SD", num_keys=700, num_ops=0, seed=24).load_ops())
    for store in fleet.values():
        store.flush_all()  # equalize durability: crash loses nothing anywhere

    rng = fleet["range"]
    victim = max(
        range(rng.num_shards),
        key=lambda i: len(rng.shards[i].live_keys_in(*rng.bounds(i))),
    )
    src = rng.shards[victim]
    src.delete_range = lambda *a, **kw: (_ for _ in ()).throw(_CrashNow())
    with pytest.raises(_CrashNow):
        rng.split(victim)  # migrated data is durable, boundary flipped,
    del src.delete_range   # ... crash hits before the old range is dropped
    assert rng.num_shards == 4  # the split's metadata did land

    for store in fleet.values():
        store.crash()
        store.recover()
    assert_agree(fleet, 700)

    # the fleet keeps running (and the interrupted shard keeps serving)
    replay(fleet, lambda: Workload("run_a", "SD", num_keys=700, num_ops=300, seed=25).run_ops())
    assert_agree(fleet, 700)
