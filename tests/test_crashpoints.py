"""Crash-point fault-injection harness for the shard-metadata WAL.

The incremental-migration protocol (PR 3) is interruptible at many points; the
set of interesting crash windows is exactly the set of
:class:`~repro.core.metalog.MetadataLog` record *sites* — the instants just
before each metadata record becomes durable, where the protocol has done
data-path work (copies, flushes, tombstones) the record would cover.  This
harness enumerates them systematically:

1. run each scenario (split / merge / migration with live traffic) once
   cleanly and count the WAL records it appends;
2. re-run it from scratch once per site with ``MetadataLog.crash_after``
   armed, so the append at that site raises :class:`CrashPoint` instead of
   committing — modeling a power cut with exactly that record prefix durable;
3. crash + recover the store and assert the differential oracle's invariant
   against a dict model: byte-identical gets, a globally sorted scan equal to
   the model's key set (**no lost and no duplicated keys**), at every site;
4. drain the (possibly resumed) migration and assert the invariant again —
   an interrupted migration must roll forward to completion.

The tier-1 run sweeps every scenario with up to ``TIER1_SITE_CAP`` sites
each (the standard batch size yields ~7 sites per scenario, so the cap is
rarely binding); the ``slow``-marked sweep re-runs the same scenarios at a
finer migration batch size, which multiplies the checkpoint sites, and
enumerates **every** one (run it with ``pytest -m slow``).
"""
import dataclasses

import pytest

from repro.core import LifetimeConfig, RangeShardedStore, ShardedStore, StoreConfig
from repro.core.metalog import CrashPoint
from repro.core.ycsb import make_key, payload

N_KEYS = 180          # 2 shards * 90 keys; a split moves ~45
BATCH_KEYS = 12       # -> 4 checkpoints per migration (>= 3 mid-migration ticks)
FINE_BATCH_KEYS = 4   # slow sweep: ~12 checkpoints per migration
TIER1_SITE_CAP = 7    # ~20 sites across the three scenarios in tier-1

# small lifetime windows so the lifetime scenarios' WAL sites — adaptive
# cutoff cutovers and GC reclaim fences — fire within a few rounds: the hot
# rounds cycle ~40 keys, so window//4 must exceed that inter-update distance
# for the controller's hot fraction (and with it a cutoff proposal) to rise
_CRASH_LIFETIME = LifetimeConfig(window=256, adapt_every=32, min_ring=8,
                                 ring_size=32, long_gc_threshold=0.2)


def small_config(**kw) -> StoreConfig:
    defaults = dict(l0_capacity=1 << 12, cache_bytes=1 << 15,
                    segment_bytes=1 << 14, chunk_bytes=1 << 11)
    defaults.update(kw)
    return StoreConfig(**defaults)


def _value(i: int, round_: int = -1) -> bytes:
    return (b"%06d/%03d:" % (i, round_)) + payload(104)


def _lvalue(i: int, round_: int = -1) -> bytes:
    """A Large-class value (lands in the lifetime-split value logs)."""
    return (b"%06d/%03d:" % (i, round_)) + payload(1004)


def build(batch_keys: int, lifetime: bool = False) -> tuple[RangeShardedStore, dict[bytes, bytes]]:
    keys = [make_key(i) for i in range(N_KEYS)]
    cfg = small_config(lifetime=_CRASH_LIFETIME) if lifetime else small_config()
    st = RangeShardedStore.for_keys(
        keys, 2, cfg, auto_rebalance=False, migration_batch_keys=batch_keys,
    )
    model = {k: _value(i) for i, k in enumerate(keys)}
    st.put_many(list(model.items()))
    st.flush_all()  # a clean durable base: a crash loses only scenario work
    return st, model


# ------------------------------------------------------------------ scenarios
# Each scenario mutates (store, model) in lockstep and is deterministic, so a
# clean run and every crash_after re-run append records at identical sites.

def _prelude_none(st, model) -> None:
    pass


def _prelude_split(st, model) -> None:
    assert st._split(0)  # synchronous: completes before the scenario starts


def scenario_split(st, model) -> None:
    assert st._split(0)


def scenario_merge(st, model) -> None:
    st._merge(0)


def _traffic_round(st, model, round_: int) -> None:
    """One deterministic round of live application traffic."""
    # update one soon-migrated and one long-pending key in the moved range
    for i in (46 + 3 * round_, 88 - 3 * round_):
        k, v = make_key(i), _value(i, round_)
        st.update(k, v)
        model[k] = v
    # delete one of each as well (tombstones must shadow stale src copies)
    for i in (48 + 3 * round_, 87 - 3 * round_):
        k = make_key(i)
        st.delete(k)
        model.pop(k, None)
    # traffic outside the migrating range: a brand-new key and an update
    for i, fresh in ((100000 + round_, True), (120 + round_, False)):
        k, v = make_key(i), _value(i, round_)
        st.put(k, v) if fresh else st.update(k, v)
        model[k] = v


def scenario_mid_migration(st, model) -> None:
    """Background split with application traffic between every tick: writes
    double-route to the new owner, reads must keep agreeing at each site."""
    assert st._split(0, background=True)
    for round_ in range(50):
        if st.migration is None:
            break
        _traffic_round(st, model, round_)
        st.flush_all()       # durable base before the next crash site
        st.migration_tick()  # the crashable step


def scenario_snapshot_mid_migration(st, model) -> None:
    """Like ``mid_migration``, but a coordinator snapshot **with WAL
    truncation** lands between two migration ticks — the crash sites cover
    the snapshot append itself (crash there: the full history survives, the
    truncation never was) and every record appended after the WAL was cut
    down to the snapshot (crash there: recovery replays the O(delta) tail)."""
    assert st._split(0, background=True)
    for round_ in range(50):
        if st.migration is None:
            break
        _traffic_round(st, model, round_)
        st.flush_all()
        if round_ == 1:
            st.snapshot_metadata(truncate=True)  # a crashable record site
        st.migration_tick()


def _hot_update_round(st, model, round_: int, n: int = 40) -> None:
    """Update-heavy round over a hot prefix with Large-class values: builds
    garbage in the lifetime-split value logs and feeds the sketch/ring."""
    for i in range(n):
        k, v = make_key(i), _lvalue(i, round_)
        st.update(k, v)
        model[k] = v


def scenario_lifetime_gc(st, model) -> None:
    """Lifetime placement under forced GC: each round's updates strand dead
    values in the short/long value logs, the flush is the durable base, and
    the GC tick is the crashable step — its WAL sites are the ``cutoff``
    cutover records (crash *at* one: the proposal never was; the shard keeps
    its prior policy) and the ``gc_reclaim`` fences between a class
    migration's relocation flush and the victim segment's reclaim (crash
    there: both copies survive and recovery's newest-LSN replay keeps exactly
    one winner)."""
    for round_ in range(6):
        _hot_update_round(st, model, round_)
        st.flush_all()
        st.gc_tick(force=True)


def scenario_lifetime_mid_migration(st, model) -> None:
    """Lifetime GC interleaved with an in-flight background split: cutoff /
    gc_reclaim sites land between migration checkpoints (the tick rides the
    GC batch boundary), so crashes cover every interleaving of the two
    protocols' records."""
    assert st._split(0, background=True)
    for round_ in range(50):
        if st.migration is None:
            break
        _traffic_round(st, model, round_)
        _hot_update_round(st, model, round_, n=20)
        st.flush_all()
        st.gc_tick(force=True)  # _after_batch also advances the migration


def _rescale_rounds(st, model, snapshot_at: int | None = None) -> None:
    for round_ in range(50):
        if st._rescale is None:
            break
        _traffic_round(st, model, round_)
        st.flush_all()       # durable base before the next crash site
        if round_ == snapshot_at:
            st.snapshot_metadata(truncate=True)  # carries the in-flight rescale
        st.migration_tick()  # advances *every* leg (the crashable step)


def scenario_rescale_concurrent(st, model) -> None:
    """Online 2->4 rescale: two split legs on disjoint shard pairs drain
    concurrently (one rescale_start, interleaved per-leg checkpoints, two
    per-leg finishes, one rescale_finish), with live traffic between ticks."""
    assert st.rescale(4) == 2
    _rescale_rounds(st, model)


def scenario_snapshot_mid_rescale(st, model) -> None:
    """Like ``rescale_concurrent``, but a truncating coordinator snapshot —
    whose record carries the multi-leg rescale state — lands between two
    migration ticks, so the sites cover recovery from the snapshot root."""
    assert st.rescale(4) == 2
    _rescale_rounds(st, model, snapshot_at=1)


def _prelude_grow4(st, model) -> None:
    assert st.rescale(4) == 2
    st.drain_migration(max_ticks=10_000)


def scenario_rescale_shrink(st, model) -> None:
    """Online 4->2 rescale: two non-adjacent merge legs in flight
    concurrently, their sources retired as each leg finishes."""
    assert st.rescale(2) == 2
    _rescale_rounds(st, model)


SCENARIOS = {
    "split": (_prelude_none, scenario_split),
    "merge": (_prelude_split, scenario_merge),
    "mid_migration": (_prelude_none, scenario_mid_migration),
    "snapshot_mid_migration": (_prelude_none, scenario_snapshot_mid_migration),
    "rescale_concurrent": (_prelude_none, scenario_rescale_concurrent),
    "snapshot_mid_rescale": (_prelude_none, scenario_snapshot_mid_rescale),
    "rescale_shrink": (_prelude_grow4, scenario_rescale_shrink),
    "lifetime_gc": (_prelude_none, scenario_lifetime_gc),
    "lifetime_mid_migration": (_prelude_none, scenario_lifetime_mid_migration),
}

_LIFETIME_SCENARIOS = {"lifetime_gc", "lifetime_mid_migration"}


# -------------------------------------------------------------------- harness
def _fresh(name: str, batch_keys: int):
    st, model = build(batch_keys, lifetime=name in _LIFETIME_SCENARIOS)
    prelude, scenario = SCENARIOS[name]
    prelude(st, model)
    return st, model, scenario


def _site_range(name: str, batch_keys: int) -> tuple[int, int, list[str]]:
    """(first site, one-past-last site, record kinds) of a clean run.

    Sites are counted in ``total_appended`` — the monotonic append counter
    ``crash_after`` is armed on — not ``n_records``, which a truncating
    scenario rewinds.  Kinds are recorded as they are appended for the same
    reason: slicing ``replay()`` misses records a truncation dropped.
    """
    st, model, scenario = _fresh(name, batch_keys)
    base = st.metalog.total_appended
    kinds: list[str] = []
    inner = st.metalog.append

    def recording_append(record):
        kinds.append(record["kind"])
        return inner(record)

    st.metalog.append = recording_append
    scenario(st, model)
    return base, st.metalog.total_appended, kinds


def _run_with_crash(name: str, batch_keys: int, site: int):
    st, model, scenario = _fresh(name, batch_keys)
    st.metalog.crash_after(site)
    crashed = False
    try:
        scenario(st, model)
    except CrashPoint:
        crashed = True
    st.metalog.disarm()
    st.crash()
    st.recover()
    return st, model, crashed


def _assert_oracle_identical(st, model, label) -> None:
    """The differential oracle's invariant: byte-identical point reads over a
    superset of keys, and a full scan equal to the model's sorted key set —
    i.e. zero lost keys, zero duplicated keys."""
    probes = sorted(set(model) | {make_key(i) for i in range(N_KEYS + 20)})
    for k in probes:
        assert st.get(k) == model.get(k), (label, k)
    rows = st.scan(b"", 4 * N_KEYS)
    assert [k for k, _ in rows] == sorted(model), label
    assert rows == [(k, model[k]) for k in sorted(model)], label


def _verify_site(name: str, batch_keys: int, site: int) -> bool:
    st, model, crashed = _run_with_crash(name, batch_keys, site)
    _assert_oracle_identical(st, model, (name, site, "post-recovery"))
    # an interrupted migration must resume (roll forward) to completion
    st.drain_migration(max_ticks=10_000)
    assert st.migration is None, (name, site)
    assert len(st._all_stores()) == st.num_shards, (name, site)  # src retired
    _assert_oracle_identical(st, model, (name, site, "post-resume"))
    return crashed


def _sample(base: int, total: int, cap: int) -> list[int]:
    """Up to ``cap`` sites including both ends and the no-crash control."""
    sites = list(range(base, total + 1))
    if len(sites) <= cap:
        return sites
    idx = {round(j * (len(sites) - 1) / (cap - 1)) for j in range(cap)}
    return [sites[i] for i in sorted(idx)]


# ---------------------------------------------------------------------- tests
def test_scenarios_emit_the_expected_record_sites():
    """Every scenario's WAL stream has a start, >= 3 mid-migration checkpoint
    ticks, and a finish — the sites the sweeps below enumerate."""
    for name, start_kind in (("split", "split_start"), ("merge", "merge_start"),
                             ("mid_migration", "split_start"),
                             ("snapshot_mid_migration", "split_start")):
        base, total, kinds = _site_range(name, BATCH_KEYS)
        assert total > base, name
        assert kinds[0] == start_kind, (name, kinds)
        assert kinds[-1] == "finish", (name, kinds)
        assert kinds.count("checkpoint") >= 3, (name, kinds)
        if name == "snapshot_mid_migration":
            assert kinds.count("snapshot") == 1, (name, kinds)


def test_rescale_scenarios_emit_the_expected_record_sites():
    """Every rescale scenario journals the new record kinds at enumerable
    sites: one ``rescale_start``, >= 2 interleaved per-leg checkpoints per
    leg, one per-leg ``finish`` each, and a closing ``rescale_finish``."""
    for name in ("rescale_concurrent", "snapshot_mid_rescale", "rescale_shrink"):
        base, total, kinds = _site_range(name, BATCH_KEYS)
        assert total > base, name
        assert kinds[0] == "rescale_start", (name, kinds)
        assert kinds[-1] == "rescale_finish", (name, kinds)
        assert kinds.count("finish") == 2, (name, kinds)
        assert kinds.count("checkpoint") >= 4, (name, kinds)
    _, _, kinds = _site_range("snapshot_mid_rescale", BATCH_KEYS)
    assert kinds.count("snapshot") == 1, kinds


def test_spec_derived_crash_coverage():
    """The crash sweep's required record-kind coverage is *derived from the
    protocol spec*, not hand-maintained: (a) the static append-site inventory
    of the real tree must emit exactly the spec's kinds — a new kind wired
    into the code without a spec entry fails in ``check_protocol.py``, and a
    spec entry with no site fails its completeness check; (b) every
    non-genesis spec kind must appear in some scenario's enumerated site
    list, so adding a record kind without extending the crash sweep is a
    test failure here, not a silent coverage gap."""
    from repro.analysis.protocol.spec import WAL_SPEC
    from repro.analysis.protocol.static_check import append_site_inventory

    inventory_kinds = {s.kind for s in append_site_inventory()}
    assert inventory_kinds == set(WAL_SPEC.kind_names)

    swept: set[str] = set()
    for name in SCENARIOS:
        _base, _total, kinds = _site_range(name, BATCH_KEYS)
        swept |= set(kinds)
    missing = WAL_SPEC.crash_coverage_kinds() - swept
    assert not missing, (
        f"spec kinds with no crash-scenario coverage: {sorted(missing)} — "
        "add or extend a scenario in SCENARIOS so the sweep enumerates a "
        "crash site at each of these records")


# ------------------------------------------------- hash-fleet rescale sweep
# The range harness above reuses the range store's registry; the hash fleet
# journals its rescale through the same record kinds but with mod routing,
# draining ex-slots on shrink, and a lazily created metalog — swept here.

def _hash_build() -> tuple[ShardedStore, dict[bytes, bytes]]:
    keys = [make_key(i) for i in range(N_KEYS)]
    st = ShardedStore(2, small_config(), migration_batch_keys=BATCH_KEYS)
    model = {k: _value(i) for i, k in enumerate(keys)}
    st.put_many(list(model.items()))
    st.flush_all()
    st._ensure_metalog()  # so crash_after can arm before the first record
    return st, model


def _hash_scenario(st, model, to_shards: int) -> None:
    assert st.rescale(to_shards) == 2
    _rescale_rounds(st, model)


def _hash_grow_first(st) -> None:
    st.rescale(4)
    st.drain_migration(max_ticks=10_000)


@pytest.mark.parametrize("to_shards,prelude",
                         [(4, None), (2, _hash_grow_first)],
                         ids=["grow", "shrink"])
def test_hash_rescale_crashpoints(to_shards, prelude):
    """Crash + recover + resume at every (sampled) rescale WAL site of a hash
    fleet: zero lost keys, zero duplicated keys, and the interrupted rescale
    rolls forward — including shrink legs whose draining ex-slots must retire."""
    def fresh():
        st, model = _hash_build()
        if prelude is not None:
            prelude(st)
        return st, model

    st, model = fresh()
    base = st.metalog.total_appended
    kinds: list[str] = []
    inner = st.metalog.append

    def recording_append(record):
        kinds.append(record["kind"])
        return inner(record)

    st.metalog.append = recording_append
    _hash_scenario(st, model, to_shards)
    total = st.metalog.total_appended
    assert kinds[0] == "rescale_start" and kinds[-1] == "rescale_finish", kinds
    assert kinds.count("finish") == 2 and kinds.count("checkpoint") >= 4, kinds

    for site in _sample(base, total, TIER1_SITE_CAP):
        st, model = fresh()
        st.metalog.crash_after(site)
        crashed = False
        try:
            _hash_scenario(st, model, to_shards)
        except CrashPoint:
            crashed = True
        st.metalog.disarm()
        st.crash()
        st.recover()
        _assert_oracle_identical(st, model, ("hash", to_shards, site, "post-recovery"))
        st.drain_migration(max_ticks=10_000)
        assert st._rescale is None and not st._migrations, (to_shards, site)
        assert not st._draining, (to_shards, site)  # ex-slots retired
        _assert_oracle_identical(st, model, ("hash", to_shards, site, "post-resume"))
        assert crashed == (site < total), (to_shards, site)


def test_lifetime_scenarios_emit_cutoff_and_reclaim_sites():
    """The lifetime scenarios' WAL streams contain both new record kinds —
    adaptive-cutoff cutovers and GC reclaim fences — and the mid-migration
    variant interleaves them with migration checkpoints, so the sweeps below
    enumerate crash sites in the copy->reclaim window and between a cutoff
    record and its apply."""
    for name in sorted(_LIFETIME_SCENARIOS):
        base, total, kinds = _site_range(name, BATCH_KEYS)
        assert total > base, name
        assert kinds.count("cutoff") >= 1, (name, kinds)
        assert kinds.count("gc_reclaim") >= 1, (name, kinds)
    _, _, kinds = _site_range("lifetime_mid_migration", BATCH_KEYS)
    assert kinds[0] == "split_start" and kinds.count("checkpoint") >= 3, kinds


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_crashpoints_tier1_sample(name):
    """Tier-1: crash + recover + resume at a capped sample of record sites
    (with the standard batch size the cap covers every site)."""
    base, total, _ = _site_range(name, BATCH_KEYS)
    for site in _sample(base, total, TIER1_SITE_CAP):
        crashed = _verify_site(name, BATCH_KEYS, site)
        assert crashed == (site < total), (name, site)


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_crashpoints_exhaustive(name):
    """Slow sweep: a finer migration batch multiplies the checkpoint sites;
    enumerate and crash at every single one (plus the no-crash control)."""
    base, total, kinds = _site_range(name, FINE_BATCH_KEYS)
    if name in _LIFETIME_SCENARIOS:
        assert kinds.count("cutoff") + kinds.count("gc_reclaim") >= 4, (name, kinds)
    else:
        assert kinds.count("checkpoint") >= 8, (name, kinds)
    for site in range(base, total + 1):
        crashed = _verify_site(name, FINE_BATCH_KEYS, site)
        assert crashed == (site < total), (name, site)


def test_crash_at_first_site_means_nothing_happened():
    """Control: crashing before the first scenario record leaves the store
    exactly at the prelude state (the aborted action never was)."""
    base, _, _ = _site_range("split", BATCH_KEYS)
    st, model, crashed = _run_with_crash("split", BATCH_KEYS, base)
    assert crashed
    assert st.num_shards == 2 and st.migration is None
    _assert_oracle_identical(st, model, "control")


def test_post_truncation_recovery_byte_identical_to_genesis():
    """Truncation is observationally free and recovery is O(delta).

    Two stores are driven through the identical mid-migration workload; both
    append the snapshot record, but only one truncates its WAL down to it.
    After crash + recovery + migration drain, every observable — point reads,
    scans, topology, aggregate :class:`StoreStats`, aggregate
    :class:`DeviceStats`, appended-WAL bytes — must be byte-identical, the
    truncated WAL must be exactly the tail of the full-history WAL (rooted at
    the snapshot record), and it must be strictly shorter: recovery replayed
    only the post-snapshot delta, not genesis history.
    """

    def drive(truncate: bool):
        st, model = build(BATCH_KEYS)
        assert st._split(0, background=True)
        for round_ in range(50):
            if st.migration is None:
                break
            _traffic_round(st, model, round_)
            st.flush_all()
            if round_ == 1:
                st.snapshot_metadata(truncate=truncate)
            st.migration_tick()
        st.flush_all()
        st.crash()
        st.recover()
        st.drain_migration(max_ticks=10_000)
        return st, model

    a, model_a = drive(True)    # truncated WAL
    b, model_b = drive(False)   # full-history WAL
    assert model_a == model_b

    # O(delta) replay: the truncated stream is a strict tail of the full one,
    # rooted at the snapshot record
    ra, rb = a.metalog.replay(), b.metalog.replay()
    assert ra[0]["kind"] == "snapshot"
    assert len(ra) < len(rb)
    assert ra == rb[-len(ra):]
    assert a.metalog.total_appended == b.metalog.total_appended
    assert a.metalog.bytes_appended == b.metalog.bytes_appended

    # byte-identical observable state after recovery from either stream
    _assert_oracle_identical(a, model_a, "truncated")
    _assert_oracle_identical(b, model_b, "full-history")
    assert a.boundaries == b.boundaries
    assert a._shard_ids == b._shard_ids
    assert a.migration is None and b.migration is None
    assert dataclasses.asdict(a.aggregate_stats()) == dataclasses.asdict(b.aggregate_stats())
    assert dataclasses.asdict(a.device_stats()) == dataclasses.asdict(b.device_stats())
