"""End-to-end behaviour: tiny-model training loop + checkpoint/restart, and the
paper's headline claim (hybrid placement reduces amplification on mixed
workloads) on a scaled-down YCSB run."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS
from repro.core import ParallaxStore, StoreConfig
from repro.core.ycsb import Workload, execute
from repro.data.pipeline import DataConfig, host_batch
from repro.models import get_model
from repro.optim import adamw
from repro.train.step import make_train_fn


def test_training_reduces_loss():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step_fn = jax.jit(make_train_fn(cfg, ocfg))
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    dcfg = DataConfig(seq_len=32, global_batch=4, seed=0)
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in host_batch(cfg, dcfg, step % 4).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_checkpoint_restart_resumes_identically(tmp_path):
    """Crash at step 10, restore, re-run: params must match the uninterrupted run."""
    cfg = ARCHS["mamba2-780m"].reduced()
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=50)
    step_fn = jax.jit(make_train_fn(cfg, ocfg))
    m = get_model(cfg)
    dcfg = DataConfig(seq_len=16, global_batch=2, seed=1)

    def run(upto, params, opt, start=0):
        for step in range(start, upto):
            batch = {k: jnp.asarray(v) for k, v in host_batch(cfg, dcfg, step).items()}
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt

    params0 = m.init_params(cfg, jax.random.PRNGKey(7))
    opt0 = adamw.init(params0)
    # uninterrupted reference
    ref_params, _ = run(15, params0, opt0)
    # interrupted run: checkpoint at 10, crash, restore, continue
    p, o = run(10, params0, adamw.init(params0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, {"params": p, "opt": o})
    del p, o  # crash
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        {"params": ref_params, "opt": adamw.init(ref_params)},
    )
    restored, step = mgr.restore(like)
    assert step == 10
    p2, _ = run(15, restored["params"], restored["opt"], start=10)
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paper_headline_hybrid_beats_baselines_on_mixed_update_workload():
    """Scaled-down Run A (SD mix): Parallax amplification < RocksDB and < BlobDB."""
    amp = {}
    for mode in ("parallax", "rocksdb", "blobdb"):
        st = ParallaxStore(StoreConfig(
            mode=mode, l0_capacity=1 << 14, growth_factor=4,
            cache_bytes=1 << 17, segment_bytes=1 << 17, chunk_bytes=1 << 13,
        ))
        w = Workload("load_a", "SD", num_keys=3000, num_ops=0, seed=11)
        execute(st, w.load_ops())
        r = Workload("run_a", "SD", num_keys=3000, num_ops=3000, seed=11)
        execute(st, r.run_ops())
        amp[mode] = st.amplification()
    assert amp["parallax"] < amp["rocksdb"]
    assert amp["parallax"] < amp["blobdb"]
