"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.merge_runs.kernel import merge_runs_pallas
from repro.kernels.merge_runs.ref import merge_runs_ref
from repro.kernels.merge_runs.ops import merge_sorted_runs
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref, ssd_reference_sequential


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize(
    "b,s,h,kh,d,bq,bk",
    [
        (1, 128, 4, 2, 32, 64, 64),
        (2, 256, 8, 2, 64, 128, 128),
        (1, 256, 4, 4, 32, 64, 128),   # MHA
        (1, 512, 2, 1, 64, 128, 256),  # MQA, rectangular blocks
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, kh, d, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kh, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kh, d), dtype)
    out = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_sliding_window():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    out = flash_attention_pallas(q, k, v, block_q=64, block_k=64, window=32, interpret=True)
    ref = flash_attention_ref(q, k, v, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_attention_is_causal():
    """Future tokens must not affect earlier outputs: perturb tail, check head."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out1 = flash_attention_pallas(q, k, v, block_q=64, block_k=64, interpret=True)
    k2 = k.at[:, 100:].set(99.0)
    v2 = v.at[:, 100:].set(-99.0)
    out2 = flash_attention_pallas(q, k2, v2, block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :100]), np.asarray(out2[:, :100]), atol=1e-6)


# ---------------------------------------------------------------- ssd scan
@pytest.mark.parametrize(
    "b,s,h,p,g,n,L",
    [
        (2, 64, 4, 16, 1, 16, 16),
        (1, 128, 4, 32, 2, 32, 32),
        (2, 256, 8, 64, 1, 64, 64),
        (1, 64, 2, 8, 1, 8, 64),  # single chunk
    ],
)
def test_ssd_scan_sweep(b, s, h, p, g, n, L):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.5
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    y_pl, s_pl = ssd_scan_pallas(x, dt, a, bm, cm, chunk=L, interpret=True)
    y_ref, s_ref = ssd_scan_ref(x, dt, a, bm, cm, chunk=L)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref), atol=2e-4, rtol=2e-4)


def test_ssd_chunked_ref_matches_sequential():
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, p, g, n = 2, 48, 4, 8, 2, 4
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.5
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    y1, s1 = ssd_scan_ref(x, dt, a, bm, cm, chunk=16)
    y2, s2 = ssd_reference_sequential(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in half and carrying state == one pass."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.5
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    y_full, s_full = ssd_scan_ref(x, dt, a, bm, cm, chunk=16)
    half = s // 2
    y1, s1 = ssd_scan_ref(x[:, :half], dt[:, :half], a, bm[:, :half], cm[:, :half], chunk=16)
    y2, s2 = ssd_scan_ref(
        x[:, half:], dt[:, half:], a, bm[:, half:], cm[:, half:], chunk=16, initial_state=s1
    )
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


# --------------------------------------------------------------- merge runs
@pytest.mark.parametrize("g,t", [(8, 64), (16, 128), (8, 256), (32, 32), (1, 512)])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_merge_runs_sweep(g, t, dtype):
    rng = np.random.default_rng(g * t)
    if dtype == np.int32:
        ak = np.sort(rng.integers(0, 1 << 30, (g, t)).astype(dtype), axis=1)
        bk = np.sort(rng.integers(0, 1 << 30, (g, t)).astype(dtype), axis=1)
    else:
        ak = np.sort(rng.standard_normal((g, t)).astype(dtype), axis=1)
        bk = np.sort(rng.standard_normal((g, t)).astype(dtype), axis=1)
    av = rng.integers(0, 1 << 30, (g, t)).astype(np.int32)
    bv = rng.integers(0, 1 << 30, (g, t)).astype(np.int32)
    ok, ov = merge_runs_pallas(jnp.array(ak), jnp.array(bk), jnp.array(av), jnp.array(bv), interpret=True)
    rk, rv = merge_runs_ref(jnp.array(ak), jnp.array(bk), jnp.array(av), jnp.array(bv))
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(rk))
    got = sorted(zip(np.asarray(ok).ravel().tolist(), np.asarray(ov).ravel().tolist()))
    exp = sorted(zip(np.asarray(rk).ravel().tolist(), np.asarray(rv).ravel().tolist()))
    assert got == exp


def test_merge_runs_with_duplicates():
    ak = np.array([[1, 1, 2, 2, 3, 3, 4, 4]], np.int32)
    bk = np.array([[1, 2, 2, 3, 3, 3, 5, 9]], np.int32)
    av = np.arange(8, dtype=np.int32)[None]
    bv = (np.arange(8, dtype=np.int32) + 100)[None]
    ok, _ = merge_runs_pallas(jnp.array(ak), jnp.array(bk), jnp.array(av), jnp.array(bv), interpret=True)
    assert np.array_equal(np.asarray(ok)[0], np.sort(np.concatenate([ak[0], bk[0]])))


def test_merge_sorted_runs_full():
    rng = np.random.default_rng(7)
    a = np.sort(rng.integers(0, 1 << 28, 3000).astype(np.int32))
    b = np.sort(rng.integers(0, 1 << 28, 1234).astype(np.int32))
    mk, mv = merge_sorted_runs(jnp.array(a), jnp.array(b))
    np.testing.assert_array_equal(np.asarray(mk), np.sort(np.concatenate([a, b])))
    assert int((np.asarray(mv) == 0).sum()) == len(a)
