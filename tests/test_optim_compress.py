"""Optimizer + gradient-compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.train.compress import (
    compress_grads,
    dequantize_int8,
    init_error_state,
    quantize_int8,
)


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0, grad_clip=0)
    params = {"x": jnp.array([5.0, -3.0])}
    opt = adamw.init(params)

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        p2, o2, _ = adamw.update(cfg, g, opt, params)
        return p2, o2, loss

    for _ in range(150):
        params, opt, loss = step(params, opt)
    assert float(jnp.abs(params["x"]).max()) < 0.1


def test_lr_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(adamw.lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(adamw.lr_at(cfg, jnp.asarray(10))) == 1.0
    end = float(adamw.lr_at(cfg, jnp.asarray(100)))
    assert abs(end - 0.1) < 1e-5
    mid = float(adamw.lr_at(cfg, jnp.asarray(55)))
    assert 0.1 < mid < 1.0


def test_grad_clip_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=0, grad_clip=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros(4)}
    opt = adamw.init(params)
    huge = {"x": jnp.full((4,), 1e6)}
    p2, _, metrics = adamw.update(cfg, huge, opt, params)
    assert float(metrics["grad_norm"]) > 1e5
    assert np.all(np.isfinite(np.asarray(p2["x"])))
    assert float(jnp.abs(p2["x"]).max()) < 10.0


def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """With error feedback, the *sum* of compressed grads tracks the true sum."""
    g = {"w": jnp.full((64,), 0.003)}  # small grads that int8 rounds to ~0 alone
    e = init_error_state(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), g))
    total = jnp.zeros((64,))
    for _ in range(50):
        out, e = compress_grads(g, method="int8", error_state=e)
        total = total + out["w"]
    expect = 0.003 * 50
    np.testing.assert_allclose(np.asarray(total), expect, rtol=0.05)


def test_topk_keeps_largest():
    g = {"w": jnp.asarray(np.arange(100, dtype=np.float32))}
    out = compress_grads(g, method="topk", topk_frac=0.05)
    w = np.asarray(out["w"])
    assert (w != 0).sum() == 5
    assert w[-5:].all()
