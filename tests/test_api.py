"""Unified engine API: config validation, lifecycle, WriteBatch, Iterator.

The byte-identity of `repro.api.Engine` against the legacy front-ends is the
differential oracle's job (tests/test_differential.py, tests/test_exec.py);
this module covers the *new* surface itself: the declarative config tree's
error contract (`ConfigError` with actionable messages), engine lifecycle
(`close`/context manager/`ClosedError`), buffered write batches, the lazy
RocksDB-style iterator (including its edge cases: empty store, seek past the
max key, iteration across a shard boundary with a migration in flight), and
the namespaced stats/device-time surface.
"""
import itertools

import pytest

import repro.api as api
from repro.core import ParallaxStore, RangeShardedStore, ShardedStore, StoreConfig
from repro.core.ycsb import Workload, make_key, payload


def small_config(**kw) -> StoreConfig:
    defaults = dict(l0_capacity=1 << 12, cache_bytes=1 << 15,
                    segment_bytes=1 << 14, chunk_bytes=1 << 11)
    defaults.update(kw)
    return StoreConfig(**defaults)


ALL_COMBOS = [(p, e) for p in ("none", "hash:3", "range:3") for e in ("serial", "async")]


def open_engine(partitioning="none", execution="serial", **kw) -> api.Engine:
    return api.open(api.EngineConfig(store=small_config(**kw.pop("store_kw", {})),
                                     partitioning=partitioning, execution=execution, **kw))


# ------------------------------------------------------------- config errors
@pytest.mark.parametrize("bad,frag", [
    (dict(partitioning="hash:-2"), "positive shard count"),
    (dict(partitioning="range:0"), "positive shard count"),
    (dict(partitioning="zebra:3"), "unknown partitioning"),
    (dict(partitioning="hash"), "missing its shard count"),
    (dict(partitioning="hash:four"), "non-integer shard count"),
    (dict(execution="warp"), "unknown execution mode"),
    (dict(execution=api.ExecutionConfig(mode="serial", pace=0.5)), "requires mode 'async'"),
    (dict(execution=api.ExecutionConfig(workers=0)), "workers must be >= 1"),
    (dict(execution=api.ExecutionConfig(overlap="channels:0")), "overlap"),
    (dict(execution=api.ExecutionConfig(overlap="warp")), "overlap"),
    (dict(execution="async", batch_size=0), "batch_size >= 1"),
    (dict(gc_every=-1), "gc_every"),
    (dict(partitioning=api.PartitioningConfig(scheme="range", boundaries=(b"a",))), "b''"),
    (dict(partitioning=api.PartitioningConfig(scheme="range", boundaries=(b"", b"b", b"b"))),
     "strictly increasing"),
    (dict(partitioning=api.PartitioningConfig(scheme="hash", shards=2, boundaries=(b"",))),
     "only apply to range"),
    (dict(partitioning=api.PartitioningConfig(scheme="none", shards=3)), "single store"),
    (dict(partitioning=api.PartitioningConfig(scheme="range", shards=2, migration_batch_keys=0)),
     "migration_batch_keys"),
])
def test_config_errors_are_actionable(bad, frag):
    with pytest.raises(api.ConfigError) as err:
        api.open(api.EngineConfig(store=small_config(), **bad))
    assert frag in str(err.value), str(err.value)


def test_config_error_is_engine_error_and_value_error():
    assert issubclass(api.ConfigError, api.EngineError)
    assert issubclass(api.ConfigError, ValueError)
    assert issubclass(api.ClosedError, api.EngineError)


def test_shorthand_strings_coerce_and_tag():
    cfg = api.EngineConfig(partitioning="hash:4", execution="async")
    assert isinstance(cfg.partitioning, api.PartitioningConfig)
    assert isinstance(cfg.execution, api.ExecutionConfig)
    assert cfg.tag() == "hash4+async4"
    assert api.EngineConfig().tag() == "none+serial"
    assert api.EngineConfig(partitioning="range:8").tag() == "range8+serial"
    bounded = api.PartitioningConfig.range_for_keys([make_key(i) for i in range(100)], 4)
    assert bounded.scheme == "range" and len(bounded.boundaries) == 4
    assert api.EngineConfig(partitioning=bounded).tag() == "range4+serial"


def test_open_builds_the_right_backend():
    with open_engine("none", "serial") as db:
        assert isinstance(db.store, ParallaxStore)
    with open_engine("none", "async") as db:  # 1-shard hash wrapper (see docs)
        assert isinstance(db.store, ShardedStore) and db.store.num_shards == 1
    with open_engine("hash:3", "serial") as db:
        assert isinstance(db.store, ShardedStore) and db.store.num_shards == 3
    with open_engine("range:3", "async") as db:
        assert isinstance(db.store, RangeShardedStore) and db.store.num_shards == 3


# --------------------------------------------------------------- lifecycle
@pytest.mark.parametrize("partitioning,execution", ALL_COMBOS)
def test_lifecycle_and_closed_error(partitioning, execution):
    db = open_engine(partitioning, execution)
    db.put(make_key(1), payload(104))
    assert db.get(make_key(1)) == payload(104)
    db.close()
    db.close()  # idempotent
    assert db.closed
    for fn in (lambda: db.put(b"k", b"v"), lambda: db.get(b"k"),
               lambda: db.delete(b"k"), lambda: db.scan(b"", 1),
               lambda: db.iterator(), lambda: db.write_batch(),
               lambda: db.crash(), lambda: api.execute(db, iter([]))):
        with pytest.raises(api.ClosedError):
            fn()
    # stats stay readable after close (post-run reporting)
    assert db.stats()["engine"]["closed"] is True
    assert db.stats()["store"]["inserts"] == 1


def test_crash_recover_round_trip():
    with open_engine("range:3", "async") as db:
        api.execute(db, Workload("load_a", "SD", num_keys=300, num_ops=0, seed=5).load_ops())
        db.flush_all()
        db.crash()
        db.recover()
        got = [db.get(make_key(i)) for i in range(300)]
        assert all(v is not None for v in got)


# -------------------------------------------------------------- write batch
@pytest.mark.parametrize("partitioning,execution", ALL_COMBOS)
def test_write_batch_matches_singles(partitioning, execution):
    with open_engine(partitioning, execution) as batched, \
         open_engine(partitioning, execution) as singles:
        wb = batched.write_batch()
        for i in range(50):
            wb.put(make_key(i), payload(104))
        wb.update(make_key(10), payload(9)).delete(make_key(20))
        assert len(wb) == 52
        batched.write(wb)
        assert len(wb) == 0  # committed batches clear
        for i in range(50):
            singles.put(make_key(i), payload(104))
        singles.update(make_key(10), payload(9))
        singles.delete(make_key(20))
        probe = [make_key(i) for i in range(55)]
        assert [batched.get(k) for k in probe] == [singles.get(k) for k in probe]
        assert batched.get(make_key(10)) == payload(9)
        assert batched.get(make_key(20)) is None


def test_write_batch_context_manager_commits_on_clean_exit_only():
    with open_engine("hash:2", "serial") as db:
        with db.write_batch() as wb:
            wb.put(make_key(1), b"v" * 30)
        assert db.get(make_key(1)) == b"v" * 30
        with pytest.raises(RuntimeError, match="boom"):
            with db.write_batch() as wb:
                wb.put(make_key(2), b"x" * 30)
                raise RuntimeError("boom")
        assert db.get(make_key(2)) is None  # discarded, not applied
        assert len(wb) == 0  # ...and emptied: reusing the batch can't replay it
        with wb:
            wb.put(make_key(3), b"y" * 30)
        assert db.get(make_key(3)) == b"y" * 30
        assert db.get(make_key(2)) is None


# ----------------------------------------------------------------- iterator
def load_keys(db, n, size=104):
    with db.write_batch() as wb:
        for i in range(n):
            wb.put(make_key(i), payload(size))


@pytest.mark.parametrize("partitioning,execution", ALL_COMBOS)
def test_iterator_matches_eager_scan(partitioning, execution):
    with open_engine(partitioning, execution) as db:
        load_keys(db, 300)
        it = db.iterator()
        rows = list(it)
        assert rows == db.scan(b"", 400)
        assert len(rows) == 300
        # mid-keyspace seek, manual cursor protocol
        it.seek(make_key(250))
        got = []
        while it.valid():
            got.append((it.key(), it.value()))
            it.next()
        assert got == db.scan(make_key(250), 100)


def test_iterator_empty_store():
    for part in ("none", "hash:3", "range:3"):
        with open_engine(part) as db:
            it = db.iterator()
            assert not it.valid()
            assert list(it) == []
            with pytest.raises(api.EngineError, match="not positioned"):
                it.key()
            with pytest.raises(api.EngineError, match="not positioned"):
                it.next()


def test_iterator_seek_past_max_key():
    for part in ("none", "hash:3", "range:3"):
        with open_engine(part) as db:
            load_keys(db, 100)
            it = db.iterator(make_key(100))  # first absent key
            assert not it.valid()
            it.seek(b"\xff" * 24)  # past every representable key
            assert not it.valid()
            with pytest.raises(api.EngineError):
                it.value()
            # re-seek recovers the cursor
            it.seek(make_key(99))
            assert it.valid() and it.key() == make_key(99)


def test_iterator_is_lazy_on_hash_backend():
    """Pulling k rows must not pay the eager path's count-per-shard reads."""
    with open_engine("hash:4", store_kw=dict(cache_bytes=0)) as lazy, \
         open_engine("hash:4", store_kw=dict(cache_bytes=0)) as eager:
        load_keys(lazy, 400)
        load_keys(eager, 400)
        before = lazy.stats()["device"]["bytes_read"]
        it = lazy.iterator()
        first = list(itertools.islice(iter(it), 10))
        lazy_read = lazy.stats()["device"]["bytes_read"] - before
        before = eager.stats()["device"]["bytes_read"]
        assert eager.scan(b"", 10) == first
        eager_read = eager.stats()["device"]["bytes_read"] - before
        assert lazy_read < eager_read, (lazy_read, eager_read)


def test_iterator_across_shard_boundary_mid_migration():
    """A split's migration left in flight: the cursor must cross the moving
    boundary and agree with the eager scan's double-routed merged view."""
    nk = 400
    keys = [make_key(i) for i in range(nk)]
    cfg = api.EngineConfig(
        store=small_config(),
        partitioning=api.PartitioningConfig.range_for_keys(
            keys, 3, auto_rebalance=False, migration_batch_keys=4),
    )
    with api.open(cfg) as db:
        load_keys(db, nk)
        # delete a stripe so tombstone suppression is exercised across the move
        with db.write_batch() as wb:
            for i in range(150, 250, 3):
                wb.delete(make_key(i))
        store = db.store
        store.flush_all()
        hot = max(range(store.num_shards),
                  key=lambda i: len(store.shards[i].live_keys_in(*store.bounds(i))))
        assert store._split(hot, background=True)
        db.migration_tick()  # move a few keys; leave the migration pending
        assert store.migration is not None
        full = db.scan(b"", nk + 50)
        assert list(db.iterator()) == full
        # start inside the migrating range, cross the new boundary
        lo = store.migration.lo
        assert list(db.iterator(lo)) == db.scan(lo, nk)
        assert store.migration is not None  # iteration never ticks the policy
        store.drain_migration()
        assert list(db.iterator()) == full  # drained world agrees too


# -------------------------------------------------------------------- stats
def test_stats_namespaces_by_backend():
    with open_engine("none") as db:
        db.put(make_key(1), payload(104))
        s = db.stats()
        assert set(s) == {"engine", "store", "device"}
        assert s["store"]["inserts"] == 1
    with open_engine("hash:2") as db:
        db.put(make_key(1), payload(104))
        assert db.get(make_key(1)) == payload(104)
        s = db.stats()
        assert set(s) == {"engine", "store", "device", "frontend"}
        assert s["engine"]["num_shards"] == 2
        assert s["frontend"]["gets"] == 1
    with open_engine("range:2") as db:
        load_keys(db, 100)
        s = db.stats()
        assert set(s) == {"engine", "store", "device", "frontend", "topology"}
        assert s["topology"]["meta_records"] >= 1
        assert len(s["topology"]["boundaries"]) == 2


def test_device_time_uses_config_overlap_policy():
    cfg = api.EngineConfig(
        store=small_config(), partitioning="hash:4",
        execution=api.ExecutionConfig(mode="serial", overlap="serial"),
    )
    with api.open(cfg) as db:
        load_keys(db, 300)
        per_shard = db.store.device_times()
        assert db.device_time() == pytest.approx(sum(per_shard))       # config default
        assert db.device_time("ideal") == pytest.approx(max(per_shard))


def test_execute_rejects_raw_stores():
    with pytest.raises(TypeError, match="drives an Engine"):
        api.execute(ParallaxStore(small_config()), iter([]))
