"""RangeShardedStore: bisect routing, range-local scans, split/merge migration."""
import dataclasses

import pytest

from repro.core import ParallaxStore, RangeShardedStore, StoreConfig
from repro.core.ycsb import Workload, execute, make_key


def small_config(**kw) -> StoreConfig:
    defaults = dict(l0_capacity=1 << 12, cache_bytes=1 << 15,
                    segment_bytes=1 << 14, chunk_bytes=1 << 11)
    defaults.update(kw)
    return StoreConfig(**defaults)


def store_with_keys(n_keys=600, n_shards=4, **kw) -> RangeShardedStore:
    keys = [make_key(i) for i in range(n_keys)]
    st = RangeShardedStore.for_keys(keys, n_shards, small_config(), **kw)
    st.put_many([(k, b"v" * 60) for k in keys])
    return st


def test_boundary_routing_is_bisect_over_sorted_boundaries():
    st = RangeShardedStore(boundaries=[b"", b"b", b"m"], config=small_config())
    assert st.shard_of(b"") == 0
    assert st.shard_of(b"a") == 0
    assert st.shard_of(b"b") == 1  # boundaries are inclusive lower bounds
    assert st.shard_of(b"lzzz") == 1
    assert st.shard_of(b"m") == 2
    assert st.shard_of(b"\xff") == 2
    # routing is stable: the same key always lands on the same shard
    assert [st.shard_of(b"qq") for _ in range(3)] == [2, 2, 2]


def test_invalid_boundaries_rejected():
    with pytest.raises(ValueError):
        RangeShardedStore(boundaries=[b"a", b"b"], config=small_config())
    with pytest.raises(ValueError):
        RangeShardedStore(boundaries=[b"", b"m", b"b"], config=small_config())
    with pytest.raises(ValueError):
        RangeShardedStore(0, small_config())


def test_shards_own_contiguous_disjoint_ranges():
    st = store_with_keys(500, 4, auto_rebalance=False)
    per_shard = [
        {k for k, _ in s.scan(b"", 1000)} for s in st.shards
    ]
    assert sum(len(ks) for ks in per_shard) == 500
    # contiguity: every shard's max key < next shard's min key
    mins_maxs = [(min(ks), max(ks)) for ks in per_shard if ks]
    for (_, hi), (lo, _) in zip(mins_maxs, mins_maxs[1:]):
        assert hi < lo


def test_scan_probes_only_overlapping_shards():
    """Acceptance: per-shard StoreStats.scans shows range-local scan probing."""
    st = store_with_keys(600, 4, auto_rebalance=False)
    for s in st.shards:
        s.stats.scans = 0
    # a short scan inside one shard's range touches exactly that shard
    got = st.scan(make_key(10), 20)
    assert [k for k, _ in got] == [make_key(i) for i in range(10, 30)]
    assert [s.stats.scans for s in st.shards] == [1, 0, 0, 0]
    # a scan spanning a boundary touches exactly the two overlapping shards
    for s in st.shards:
        s.stats.scans = 0
    st.scan(make_key(140), 20)  # 600 keys / 4 shards -> boundary at 150
    assert [s.stats.scans for s in st.shards] == [1, 1, 0, 0]
    # front-end fan-out counters agree
    assert st.scans == 2 and st.scan_probes == 3


def test_scan_concatenation_is_globally_sorted_and_complete():
    st = store_with_keys(400, 4, auto_rebalance=False)
    bare = ParallaxStore(small_config())
    for i in range(400):
        bare.put(make_key(i), b"v" * 60)
    assert st.scan(b"", 500) == bare.scan(b"", 500)
    assert st.scan(make_key(95), 50) == bare.scan(make_key(95), 50)
    assert st.scan(make_key(399), 10) == bare.scan(make_key(399), 10)


def test_split_migrates_and_preserves_results():
    st = store_with_keys(300, 2, auto_rebalance=False)
    expect = st.scan(b"", 400)
    assert st._split(0)
    assert st.num_shards == 3
    assert st.splits == 1 and st.migrated_keys > 0
    assert st.scan(b"", 400) == expect
    assert all(st.get(make_key(i)) == b"v" * 60 for i in range(300))
    # the migrated range is really gone from the source shard (post-split
    # boundary excludes it, and the tombstones land eventually)
    lo, hi = st.bounds(0)
    assert st.shards[0].live_keys_in(hi, None) == []


def test_merge_absorbs_cold_neighbor():
    st = store_with_keys(300, 4, auto_rebalance=False)
    expect = st.scan(b"", 400)
    st._merge(1)
    assert st.num_shards == 3
    assert st.merges == 1
    assert st.scan(b"", 400) == expect
    assert all(st.get(make_key(i)) == b"v" * 60 for i in range(300))
    # aggregate stats keep the retired shard's history
    assert st.aggregate_stats().inserts == 300


def test_skew_driven_rebalance_splits_hot_shard():
    """A degenerate map (all keys in one shard) is repaired by observed load."""
    cfg = small_config(bloom_bits_per_key=10)
    st = RangeShardedStore(4, cfg, rebalance_window=200, max_shards=16)
    # default uniform byte boundaries: every YCSB key lands in one shard
    owners = {st.shard_of(make_key(i)) for i in range(500)}
    assert len(owners) == 1
    w = Workload("load_a", "SD", num_keys=800, num_ops=0, seed=11)
    execute(st, w.load_ops(), batch_size=32)
    r = Workload("run_e", "SD", num_keys=800, num_ops=400, seed=11)
    execute(st, r.run_ops(), batch_size=32)
    assert st.splits > 0
    populated = sum(
        1 for i, s in enumerate(st.shards) if s.live_keys_in(*st.bounds(i))
    )
    assert populated > 1


def test_rebalance_preserves_every_result():
    """With the rebalancer live, results match a bare single store exactly."""
    cfg = small_config(bloom_bits_per_key=10)
    st = RangeShardedStore(2, cfg, rebalance_window=150)
    bare = ParallaxStore(small_config())
    w = Workload("load_a", "SD", num_keys=900, num_ops=0, seed=4)
    execute(st, w.load_ops(), batch_size=32)
    execute(bare, w.load_ops())
    r = Workload("run_a", "SD", num_keys=900, num_ops=500, seed=4)
    execute(st, r.run_ops(), batch_size=32)
    execute(bare, r.run_ops())
    assert st.splits + st.merges > 0, "policy must have fired for this test to bite"
    keys = [make_key(i) for i in range(950)]
    assert st.get_many(keys) == [bare.get(k) for k in keys]
    assert st.scan(b"", 1000) == bare.scan(b"", 1000)


def test_crash_recover_after_rebalance():
    st = store_with_keys(400, 2, auto_rebalance=False)
    st._split(0)
    st._split(1)
    st._merge(0)
    st.flush_all()
    cutoffs = st.crash()
    st.recover()
    assert len(cutoffs) == st.num_shards
    assert all(st.get(make_key(i)) == b"v" * 60 for i in range(400))
    assert [k for k, _ in st.scan(b"", 500)] == [make_key(i) for i in range(400)]


def test_double_routing_read_counts_extra_probe():
    """Regression (PR 3): a pending-region read that misses the new owner and
    falls back to the draining old shard costs one extra front-end probe —
    ``get_probes``/``get_fallbacks`` record it, scans count the extra shard."""
    st = store_with_keys(300, 2, auto_rebalance=False, migration_batch_keys=10)
    assert st._split(0, background=True)        # moved range [key75, key150)
    m = st.migration
    assert m is not None and m.cursor == m.lo  # nothing copied yet
    g0, p0, f0 = st.gets, st.get_probes, st.get_fallbacks
    # pending key: new owner misses, old shard serves -> 2 probes, 1 fallback
    assert st.get(make_key(140)) == b"v" * 60
    assert (st.gets, st.get_probes, st.get_fallbacks) == (g0 + 1, p0 + 2, f0 + 1)
    # untouched shard: the usual single probe
    assert st.get(make_key(10)) == b"v" * 60
    assert (st.gets, st.get_probes, st.get_fallbacks) == (g0 + 2, p0 + 3, f0 + 1)
    # a scan overlapping the pending window consults the draining source too
    s0, sp0 = st.scans, st.scan_probes
    rows = st.scan(make_key(140), 5)
    assert [k for k, _ in rows] == [make_key(i) for i in range(140, 145)]
    assert (st.scans, st.scan_probes) == (s0 + 1, sp0 + 2)
    # the scan's batch hook ticked the migration: keys below the cursor are
    # the new owner's alone again — back to a single probe, no fallback
    assert m.cursor > m.lo
    g, p, f = st.gets, st.get_probes, st.get_fallbacks
    assert st.get(make_key(76)) == b"v" * 60
    assert (st.gets, st.get_probes, st.get_fallbacks) == (g + 1, p + 1, f)


def test_fallback_reads_fold_into_retired_shard_stats():
    """Regression (PR 3): with incremental merges a shard serves double-routed
    reads *while draining* and only retires once drained — the reads it served
    must survive the retirement stat folding."""
    st = store_with_keys(200, 2, auto_rebalance=False, migration_batch_keys=20)
    st._merge(0, background=True)
    assert st.migration is not None
    for i in range(150, 160):  # pending keys: served by the draining source
        assert st.get(make_key(i)) == b"v" * 60
    assert st.get_fallbacks >= 10
    gets_total = st.aggregate_stats().gets
    st.drain_migration()
    assert st.migration is None
    assert len(st._all_stores()) == st.num_shards == 1  # source retired
    # the drained shard's read history survives its retirement
    assert st.aggregate_stats().gets == gets_total
    assert st.aggregate_stats().inserts == 200


def test_background_split_is_incremental_and_bounded_per_tick():
    """The migration copies at most ``migration_batch_keys`` per tick and the
    metadata WAL records every checkpoint."""
    st = store_with_keys(300, 2, auto_rebalance=False, migration_batch_keys=10)
    rec0 = st.metalog.n_records
    assert st._split(0, background=True)
    assert st.migration is not None
    ticks = 0
    while st.migration is not None:
        moved = st.migration_tick()
        assert moved <= 10
        ticks += 1
        assert ticks < 100
    assert ticks >= 75 // 10  # ~75 moved keys at 10/tick
    kinds = [r["kind"] for r in st.metalog.replay()[rec0:]]
    assert kinds[0] == "split_start" and kinds[-1] == "finish"
    assert kinds.count("checkpoint") == ticks
    assert st.migrated_keys == 75
    assert st.device_stats().meta_written > 0  # WAL bytes hit amplification


def test_bounded_scan_during_merge_with_residue():
    """Regression (PR 3 review): a *bounded* scan over a merge destination
    whose pending window holds pre-flip residue must return the true merged
    prefix — no resurrected residue, no deleted key, and no skipped post-flip
    insert — even when the residue outnumbers the scan's count."""
    st = store_with_keys(200, 2, auto_rebalance=False, migration_batch_keys=500)
    # full split, then crash: the unflushed ranged-delete tombstones are lost,
    # leaving stale live copies of the whole moved range [key50, key100) in
    # shard 0
    assert st._split(0)
    st.crash()
    st.recover()
    lo, hi = st.bounds(0)
    assert st.shards[0].live_keys_in(hi, None), "expected stale residue"
    # delete some moved keys (tombstones land in their current owner, shard 1)
    for i in (52, 54, 56, 58):
        st.delete(make_key(i))
    # merge shard 1 back: shard 0 becomes a migration destination whose
    # pending window is packed with pre-flip residue
    st._merge(0, background=True)
    assert st.migration is not None
    # a post-flip insert sorting between residue keys
    kx = make_key(52) + b"!"
    st.put(kx, b"v" * 60)
    expect = [make_key(50), make_key(51), kx, make_key(53), make_key(55),
              make_key(57), make_key(59), make_key(60)]
    assert [k for k, _ in st.scan(make_key(50), 8)] == expect
    st.drain_migration()
    assert [k for k, _ in st.scan(make_key(50), 8)] == expect


def test_delete_range_hook():
    bare = ParallaxStore(small_config())
    for i in range(200):
        bare.put(make_key(i), b"v" * 30)
    n = bare.delete_range(make_key(50), make_key(150))
    assert n == 100
    assert bare.live_keys_in(b"", None) == [make_key(i) for i in list(range(50)) + list(range(150, 200))]
    assert bare.get(make_key(60)) is None
    assert bare.get(make_key(150)) == b"v" * 30
    # scan_range honors [start, end) on the read side
    rows = bare.scan_range(make_key(10), make_key(49))
    assert [k for k, _ in rows] == [make_key(i) for i in range(10, 49)]
