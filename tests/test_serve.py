"""Serving engine + hybrid KV-cache manager tests."""
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import get_model
from repro.serve.cache_manager import CacheConfig, HybridCacheManager
from repro.serve.engine import Request, ServeEngine


def test_cache_manager_placement_classes():
    cfg = CacheConfig(bytes_per_token=1024, slab_tokens=256, arena_tokens=8192)
    mgr = HybridCacheManager(cfg)
    a = mgr.admit(1, 100)       # short -> slab
    b = mgr.admit(2, 2000)      # medium -> transient arena
    c = mgr.admit(3, 20000)     # long -> paged pool
    assert (a.kind, b.kind, c.kind) == ("slab", "transient", "paged")
    assert len(c.pages) == -(-20000 // 16)


def test_cache_manager_wholesale_arena_reclaim():
    cfg = CacheConfig(bytes_per_token=64, slab_tokens=16, arena_tokens=4096)
    mgr = HybridCacheManager(cfg)
    for i in range(4):
        assert mgr.admit(i, 1000).kind == "transient"
    assert mgr.stats()["arena_used_tokens"] == 4000
    for i in range(4):
        mgr.release(i)
    s = mgr.stats()
    # zero per-page GC for mediums; one wholesale reset (the paper's economy)
    assert s["arena_used_tokens"] == 0
    assert s["wholesale_reclaims"] == 1
    assert s["gc_page_ops"] == 0


def test_cache_manager_paged_gc_and_reuse():
    cfg = CacheConfig(bytes_per_token=64, slab_tokens=16, arena_tokens=32, pool_pages=64)
    mgr = HybridCacheManager(cfg)
    a = mgr.admit(1, 512)
    assert a.kind == "paged"
    before = mgr.stats()["free_pages"]
    mgr.release(1)
    assert mgr.stats()["free_pages"] == before + len(a.pages)
    assert mgr.stats()["gc_page_ops"] == len(a.pages)
    # pages are reusable
    b = mgr.admit(2, 512)
    assert b.kind == "paged"


def test_cache_manager_slab_overflow_promotes():
    cfg = CacheConfig(bytes_per_token=64, slab_tokens=32, arena_tokens=64, pool_pages=128)
    mgr = HybridCacheManager(cfg)
    a = mgr.admit(1, 20)
    assert a.kind == "slab"
    assert mgr.extend(1, 40)  # grew past the slab: promoted to paged
    assert mgr.allocs[1].kind == "paged"


def test_admission_control():
    cfg = CacheConfig(bytes_per_token=64, slab_tokens=4, slab_slots=1, arena_tokens=8, pool_pages=2)
    mgr = HybridCacheManager(cfg)
    assert mgr.admit(1, 4096) is None  # no capacity -> rejected, not corrupted
    assert mgr.stats()["active"] == 0


def test_serve_engine_end_to_end():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    m = get_model(cfg)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=64, batch_size=2)
    reqs = [
        Request(0, jnp.arange(8, dtype=jnp.int32) % cfg.vocab_size, max_new_tokens=6),
        Request(1, (jnp.arange(8, dtype=jnp.int32) + 3) % cfg.vocab_size, max_new_tokens=6),
    ]
    done = eng.run_batch(reqs)
    assert all(len(r.output) == 6 for r in done)
    assert all(0 <= t < cfg.vocab_padded for r in done for t in r.output)
    assert eng.cache_mgr.stats()["active"] == 0  # everything released
