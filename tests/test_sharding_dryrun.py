"""Sharding rules + a miniature dry-run on 8 forced host devices.

The full 512-device dry-run lives in ``repro.launch.dryrun`` (run separately
— results in results/*.json).  Here we verify the machinery end-to-end on a
small forced-device mesh via a subprocess, so the main pytest process keeps
its single-device view.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_specs_divisibility_all_archs():
    """Every rule-produced spec must divide its dim on the production mesh."""
    out = run_sub("""
        import jax
        from jax.sharding import PartitionSpec
        from repro.configs import ARCHS
        from repro.sharding import rules
        from repro.train.step import abstract_params

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for name, cfg0 in ARCHS.items():
            cfg = rules.pad_config_for_mesh(cfg0, mesh)
            shapes = abstract_params(cfg)
            specs = rules.param_specs(cfg, mesh, shapes)
            for (path, leaf), spec in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, PartitionSpec)),
            ):
                for dim, part in zip(leaf.shape, tuple(spec)):
                    if part is None:
                        continue
                    axes = part if isinstance(part, tuple) else (part,)
                    size = 1
                    for a in axes:
                        size *= mesh.shape[a]
                    assert dim % size == 0, (name, path, leaf.shape, spec)
        print("DIVISIBILITY-OK")
    """)
    assert "DIVISIBILITY-OK" in out


@pytest.mark.parametrize("arch,shape", [
    ("qwen2.5-3b", "train_4k"),
    ("mamba2-780m", "long_500k"),
    ("deepseek-moe-16b", "decode_32k"),
    ("whisper-medium", "prefill_32k"),
])
def test_mini_dryrun_lowers_and_compiles(arch, shape):
    """lower+compile on a (2,4) mesh with reduced shapes: the same code path
    the 512-device dry-run uses."""
    out = run_sub(f"""
        import jax, dataclasses
        import jax.numpy as jnp
        from repro.configs import ARCHS, SHAPES
        from repro.launch.dryrun import lower_cell
        import repro.launch.dryrun as dr
        import repro.configs.registry as reg

        # shrink the shape so the CPU compile is fast, keep the step kind
        spec = reg.SHAPES["{shape}"]
        reg.SHAPES["{shape}"] = dataclasses.replace(spec, seq_len=min(spec.seq_len, 256), global_batch=8)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        dr.make_production_mesh = lambda multi_pod=False: mesh  # patch the direct import
        # reduce the arch for speed
        reg.ARCHS["{arch}"] = reg.ARCHS["{arch}"].reduced()
        row = dr.run_cell("{arch}", "{shape}", "single")
        assert row["status"] == "ok", row.get("error")
        assert row["roofline"]["flops_per_device"] >= 0
        print("MINI-DRYRUN-OK", row["roofline"]["bottleneck"])
    """)
    assert "MINI-DRYRUN-OK" in out


def test_production_dryrun_results_complete():
    """Validate the recorded 512/256-device dry-run artifacts (all 40 cells)."""
    for fname, mesh in [("dryrun_single.json", "single"), ("dryrun_multi.json", "multi")]:
        path = os.path.join(os.path.dirname(__file__), "..", "results", fname)
        if not os.path.exists(path):
            pytest.skip(f"{fname} not generated yet (run repro.launch.dryrun --all)")
        rows = json.load(open(path))
        cells = {(r["arch"], r["shape"]): r for r in rows if r["mesh"] == mesh}
        assert len(cells) == 40, f"{fname}: expected 40 cells, got {len(cells)}"
        fails = [(k, v.get("error", "")) for k, v in cells.items() if v["status"] == "FAIL"]
        assert not fails, fails
        ok = [v for v in cells.values() if v["status"] == "ok"]
        skipped = [v for v in cells.values() if v["status"] == "skipped"]
        assert len(ok) == 32 and len(skipped) == 8  # long_500k skips for 8 archs
        for v in ok:
            assert v["roofline"]["bottleneck"] in ("compute", "memory", "collective")
