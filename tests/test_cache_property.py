"""Hypothesis property tests for the hybrid KV-cache manager.

Invariants under arbitrary admit/extend/release interleavings:
  1. Page conservation: free + allocated pages == pool size, no double-free.
  2. Slab conservation: free + in-use slab slots == slab count.
  3. The transient arena resets to zero exactly when its last resident leaves.
  4. Admission control never corrupts state (rejected admits change nothing).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.serve.cache_manager import CacheConfig, HybridCacheManager

ops_strategy = st.lists(
    st.one_of(
        st.tuples(st.just("admit"), st.integers(0, 15), st.integers(1, 40_000)),
        st.tuples(st.just("extend"), st.integers(0, 15), st.integers(1, 2_000)),
        st.tuples(st.just("release"), st.integers(0, 15), st.just(0)),
    ),
    min_size=1,
    max_size=120,
)


@settings(max_examples=120, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy)
def test_allocator_invariants(ops):
    cfg = CacheConfig(bytes_per_token=256, slab_slots=4, slab_tokens=128,
                      arena_tokens=4096, pool_pages=512)
    mgr = HybridCacheManager(cfg)
    live: dict[int, int] = {}
    for kind, sid, arg in ops:
        if kind == "admit" and sid not in live:
            a = mgr.admit(sid, arg)
            if a is not None:
                live[sid] = arg
        elif kind == "extend" and sid in live:
            a = mgr.allocs[sid]
            new_len = a.length + arg
            if mgr.extend(sid, new_len):
                live[sid] = new_len
        elif kind == "release" and sid in live:
            mgr.release(sid)
            del live[sid]
        # ---- invariants after every op
        s = mgr.stats()
        used_pages = sum(len(a.pages) for a in mgr.allocs.values())
        assert s["free_pages"] + used_pages == cfg.pool_pages
        assert len(set(mgr._free_pages)) == len(mgr._free_pages)  # no dup frees
        slab_used = sum(1 for a in mgr.allocs.values() if a.kind == "slab")
        assert s["free_slabs"] + slab_used == cfg.slab_slots
        assert s["active"] == len(live)
        if not any(a.kind == "transient" for a in mgr.allocs.values()):
            pass  # arena may stay non-zero until the LAST transient leaves
    # drain everything: all resources return
    for sid in list(live):
        mgr.release(sid)
    s = mgr.stats()
    assert s["free_pages"] == cfg.pool_pages
    assert s["free_slabs"] == cfg.slab_slots
    assert s["arena_used_tokens"] == 0
    assert s["active"] == 0


@settings(max_examples=60, deadline=None)
@given(lens=st.lists(st.integers(1, 100_000), min_size=1, max_size=30))
def test_classification_total(lens):
    cfg = CacheConfig(bytes_per_token=512)
    for ln in lens:
        assert cfg.classify(ln) in ("slab", "transient", "paged")
    # monotone: longer contexts never move toward slab
    order = {"slab": 0, "transient": 1, "paged": 2}
    classes = [order[cfg.classify(ln)] for ln in sorted(lens)]
    assert classes == sorted(classes)
