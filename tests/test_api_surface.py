"""Public-API snapshot: names and signatures of `repro.api` / `repro.core`.

An accidental rename, a dropped export, or a changed default in the public
surface should fail CI loudly, not surface as a downstream breakage.  The
snapshot below is the *intended* surface — when a PR changes the API on
purpose, update the snapshot in the same commit (that diff is the review
artifact).  Private names (leading underscore) and dunders other than
``__init__`` are out of scope by design.
"""
import inspect

import pytest

import repro.api as api
import repro.core as core

# ---------------------------------------------------------------- repro.api
API_ALL = [
    "ClosedError",
    "ConfigError",
    "Engine",
    "EngineConfig",
    "EngineError",
    "ExecutionConfig",
    "Iterator",
    "PartitioningConfig",
    "WriteBatch",
    "execute",
    "open",
    "reset_deprecation_warnings",
    "restore",
]

API_FUNCTIONS = {
    "open": "(config: 'EngineConfig | None' = None, **overrides) -> 'Engine'",
    "execute": "(engine: 'Engine', ops, *, batch_size: 'int | None' = None, "
               "gc_every: 'int | None' = None, migrate_budget: 'int | None' = None) -> 'dict'",
    "reset_deprecation_warnings": "() -> 'None'",
    "restore": "(path: 'str', **overrides) -> 'Engine'",
}

API_METHODS = {
    "Engine": {
        "__init__": "(self, config: 'EngineConfig')",
        "amplification": "(self) -> 'float'",
        "clone": "(self, **overrides) -> \"'Engine'\"",
        "close": "(self, wait: 'bool' = True) -> 'None'",
        "closed": "<property>",
        "crash": "(self)",
        "delete": "(self, key: 'bytes') -> 'None'",
        "device_time": "(self, policy: 'str | None' = None) -> 'float'",
        "flush_all": "(self) -> 'None'",
        "gc_tick": "(self, force: 'bool' = False)",
        "get": "(self, key: 'bytes') -> 'bytes | None'",
        "iterator": "(self, start: 'bytes' = b'') -> 'Iterator'",
        "migration_tick": "(self, budget: 'int | None' = None) -> 'int'",
        "put": "(self, key: 'bytes', value: 'bytes') -> 'None'",
        "recover": "(self) -> 'None'",
        "rescale": "(self, shards: 'int', *, budget: 'int | None' = None) -> 'dict'",
        "restore": "(self, path: 'str') -> 'None'",
        "scan": "(self, start: 'bytes', count: 'int') -> 'list[tuple[bytes, bytes]]'",
        "snapshot": "(self, path: 'str | None' = None) -> 'str'",
        "space_bytes": "(self) -> 'int'",
        "stats": "(self) -> 'dict'",
        "store": "<property>",
        "topology": "(self) -> 'dict'",
        "update": "(self, key: 'bytes', value: 'bytes') -> 'None'",
        "write": "(self, batch: 'WriteBatch') -> 'None'",
        "write_batch": "(self) -> 'WriteBatch'",
    },
    "Iterator": {
        "__init__": "(self, engine: \"'Engine'\", start: 'bytes' = b'')",
        "key": "(self) -> 'bytes'",
        "next": "(self) -> 'None'",
        "seek": "(self, key: 'bytes') -> \"'Iterator'\"",
        "seek_to_first": "(self) -> \"'Iterator'\"",
        "valid": "(self) -> 'bool'",
        "value": "(self) -> 'bytes'",
    },
    "WriteBatch": {
        "__init__": "(self, engine: \"'Engine'\")",
        "clear": "(self) -> 'None'",
        "delete": "(self, key: 'bytes') -> \"'WriteBatch'\"",
        "put": "(self, key: 'bytes', value: 'bytes') -> \"'WriteBatch'\"",
        "update": "(self, key: 'bytes', value: 'bytes') -> \"'WriteBatch'\"",
    },
}

CONFIG_FIELDS = {
    "EngineConfig": ["store", "partitioning", "execution", "batch_size", "gc_every",
                     "debug_checks", "snapshot_dir", "truncate_on_snapshot"],
    "PartitioningConfig": [
        "scheme", "shards", "boundaries", "rebalance_window", "split_factor",
        "merge_factor", "min_split_keys", "max_shards", "auto_rebalance",
        "migration_batch_keys", "migrate_budget", "rescale_budget",
    ],
    "ExecutionConfig": ["mode", "workers", "pipeline", "pace", "max_pending", "overlap"],
}

# placement-config snapshots (repro.core dataclasses reachable from
# EngineConfig.store): StoreConfig is deliberately *not* frozen (legacy call
# patterns mutate it), LifetimeConfig is frozen (shared across shards)
STORE_CONFIG_FIELDS = [
    "mode", "t_sm", "t_ml", "l0_capacity", "growth_factor", "merge_depth",
    "sorted_segments", "gc_threshold", "blobdb_scan_fraction", "cache_bytes",
    "auto_gc", "blobdb_gc_every_flushes", "prefix_size", "segment_bytes",
    "chunk_bytes", "bloom_bits_per_key", "lifetime",
]

LIFETIME_CONFIG_FIELDS = [
    "window", "rows", "width", "hot_updates", "ring_size", "adaptive",
    "adapt_every", "min_ring", "max_shift", "short_gc_threshold",
    "long_gc_threshold",
]

LIFETIME_CONFIG_DEFAULTS = {
    "window": 2048, "rows": 4, "width": 256, "hot_updates": 2,
    "ring_size": 128, "adaptive": True, "adapt_every": 2048, "min_ring": 32,
    "max_shift": 0.5, "short_gc_threshold": 0.5, "long_gc_threshold": 0.30,
}

CONFIG_DEFAULTS = {
    ("PartitioningConfig", "scheme"): "none",
    ("PartitioningConfig", "shards"): 1,
    ("PartitioningConfig", "migration_batch_keys"): 128,
    ("PartitioningConfig", "migrate_budget"): 0,
    ("PartitioningConfig", "rescale_budget"): 0,
    ("ExecutionConfig", "mode"): "serial",
    ("ExecutionConfig", "workers"): 4,
    ("ExecutionConfig", "pipeline"): True,
    ("ExecutionConfig", "pace"): 0.0,
    ("ExecutionConfig", "overlap"): "ideal",
    ("EngineConfig", "batch_size"): None,
    ("EngineConfig", "gc_every"): 0,
    ("EngineConfig", "debug_checks"): False,
    ("EngineConfig", "snapshot_dir"): None,
    ("EngineConfig", "truncate_on_snapshot"): True,
}

# --------------------------------------------------------------- repro.core
CORE_ALL = [
    "BLOCK", "CHUNK", "SEGMENT", "Device", "DeviceStats", "overlap_time",
    "BatchHandle", "ShardExecutor",
    "Log", "LogEntry", "Pointer", "TransientLog",
    "CAT_SMALL", "CAT_MEDIUM", "CAT_LARGE", "BloomFilter", "IndexEntry", "Level",
    "CLASS_SHORT", "CLASS_LONG", "LifetimeConfig", "LifetimeOracle",
    "LifetimeSketch", "propose_cutoffs",
    "CrashPoint", "MetadataLog",
    "T_ML", "T_SM", "SizePolicy",
    "amplification_inplace", "amplification_inplace_sum", "amplification_separated",
    "capacity_ratio", "levels_for_dataset", "separation_benefit",
    "ParallaxStore", "StoreConfig", "StoreStats",
    "BaseShardedStore", "ShardedStore", "MigrationState", "RangeShardedStore", "route",
]


def public_surface(klass) -> dict:
    out = {}
    for name, member in sorted(vars(klass).items()):
        if name.startswith("_") and name != "__init__":
            continue
        if isinstance(member, (staticmethod, classmethod)):
            member = member.__func__
        if isinstance(member, property):
            out[name] = "<property>"
        elif callable(member):
            out[name] = str(inspect.signature(member))
    return out


def test_api_all_is_exact():
    assert api.__all__ == API_ALL
    for name in API_ALL:
        assert hasattr(api, name), name


def test_api_function_signatures():
    for name, expected in API_FUNCTIONS.items():
        assert str(inspect.signature(getattr(api, name))) == expected, name


@pytest.mark.parametrize("klass", sorted(API_METHODS))
def test_api_class_surfaces(klass):
    assert public_surface(getattr(api, klass)) == API_METHODS[klass], klass


def test_exception_hierarchy():
    assert issubclass(api.ClosedError, api.EngineError)
    assert issubclass(api.ConfigError, api.EngineError)
    assert issubclass(api.ConfigError, ValueError)
    assert issubclass(api.EngineError, Exception)


@pytest.mark.parametrize("klass", sorted(CONFIG_FIELDS))
def test_config_dataclass_fields(klass):
    import dataclasses

    cls = getattr(api, klass)
    assert [f.name for f in dataclasses.fields(cls)] == CONFIG_FIELDS[klass]
    assert cls.__dataclass_params__.frozen


def test_config_defaults_pinned():
    for (klass, field), expected in CONFIG_DEFAULTS.items():
        inst = getattr(api, klass)()
        got = getattr(inst, field)
        # EngineConfig coerces its sub-config fields in __post_init__
        assert got == expected, (klass, field, got)


def test_core_all_is_exact():
    assert core.__all__ == CORE_ALL
    for name in CORE_ALL:
        assert hasattr(core, name), name


def test_store_config_fields():
    import dataclasses

    assert [f.name for f in dataclasses.fields(core.StoreConfig)] == STORE_CONFIG_FIELDS
    assert core.StoreConfig().lifetime is None  # lifetime placement is opt-in


def test_lifetime_config_fields_and_defaults():
    import dataclasses

    assert [f.name for f in dataclasses.fields(core.LifetimeConfig)] == LIFETIME_CONFIG_FIELDS
    assert core.LifetimeConfig.__dataclass_params__.frozen
    inst = core.LifetimeConfig()
    for field, expected in LIFETIME_CONFIG_DEFAULTS.items():
        assert getattr(inst, field) == expected, field
