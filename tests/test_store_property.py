"""Hypothesis property tests: the store vs a dict oracle, and crash recovery.

Invariants:
  1. Sequential consistency: after any op sequence, get(k) == oracle[k].
  2. Scan returns the sorted live keyspace.
  3. Crash + recover yields the exact prefix of writes up to the returned
     cutoff LSN (paper §3.4 semantics).
  4. GC at any point never changes visible state.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.core import ParallaxStore, StoreConfig

KEYS = [f"k{i:03d}".encode() for i in range(40)]
SIZES = [5, 9, 60, 104, 300, 1004, 2500]

ops_strategy = st.lists(
    st.one_of(
        st.tuples(st.just("put"), st.sampled_from(KEYS), st.sampled_from(SIZES)),
        st.tuples(st.just("update"), st.sampled_from(KEYS), st.sampled_from(SIZES)),
        st.tuples(st.just("delete"), st.sampled_from(KEYS), st.just(0)),
        st.tuples(st.just("get"), st.sampled_from(KEYS), st.just(0)),
        st.tuples(st.just("gc"), st.just(b""), st.just(0)),
    ),
    min_size=1,
    max_size=250,
)

mode_strategy = st.sampled_from(["parallax", "rocksdb", "blobdb", "nomerge"])


def _store(mode):
    return ParallaxStore(StoreConfig(
        mode=mode, l0_capacity=1 << 11, cache_bytes=1 << 14,
        segment_bytes=1 << 14, chunk_bytes=1 << 10,
    ))


def _payload(k: bytes, n: int) -> bytes:
    return (k * (n // len(k) + 1))[:n]


@settings(max_examples=60, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy, mode=mode_strategy)
def test_store_matches_dict_oracle(ops, mode):
    store = _store(mode)
    oracle = {}
    for kind, key, size in ops:
        if kind == "put":
            v = _payload(key, size)
            store.put(key, v)
            oracle[key] = v
        elif kind == "update":
            v = _payload(key, size + 1)
            store.update(key, v)
            oracle[key] = v
        elif kind == "delete":
            store.delete(key)
            oracle.pop(key, None)
        elif kind == "get":
            assert store.get(key) == oracle.get(key)
        else:
            store.gc_tick()
    for k in KEYS:
        assert store.get(k) == oracle.get(k)
    assert store.scan(b"", 100) == sorted(oracle.items())


@settings(max_examples=40, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy, mode=st.sampled_from(["parallax", "blobdb"]))
def test_crash_recovery_is_prefix_consistent(ops, mode):
    store = _store(mode)
    history = []  # (lsn, key, value-or-None)
    for kind, key, size in ops:
        if kind == "put":
            v = _payload(key, size)
            store.put(key, v)
            history.append((store.lsn, key, v))
        elif kind == "update":
            v = _payload(key, size + 1)
            store.update(key, v)
            history.append((store.lsn, key, v))
        elif kind == "delete":
            store.delete(key)
            history.append((store.lsn, key, None))
    cutoff = store.crash()
    store.recover()
    expect = {}
    for lsn, key, v in history:
        if lsn <= cutoff:
            if v is None:
                expect.pop(key, None)
            else:
                expect[key] = v
    for k in KEYS:
        assert store.get(k) == expect.get(k), (k, cutoff)


@settings(max_examples=30, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy)
def test_gc_preserves_visible_state(ops):
    store = _store("parallax")
    oracle = {}
    for kind, key, size in ops:
        if kind in ("put", "update"):
            v = _payload(key, size)
            store.put(key, v)
            oracle[key] = v
        elif kind == "delete":
            store.delete(key)
            oracle.pop(key, None)
    before = {k: store.get(k) for k in KEYS}
    store.gc_tick()
    store.gc_tick()
    after = {k: store.get(k) for k in KEYS}
    assert before == after
    assert after == {k: oracle.get(k) for k in KEYS}


@settings(max_examples=30, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    sizes=st.lists(st.tuples(st.integers(1, 64), st.integers(0, 4096)), min_size=1, max_size=50),
    t_sm=st.floats(0.05, 0.5),
    t_ml=st.floats(0.001, 0.049),
)
def test_classifier_total_and_monotone(sizes, t_sm, t_ml):
    """Classification is total and monotone in value size (for fixed key)."""
    from repro.core.model import SizePolicy

    pol = SizePolicy(t_sm=t_sm, t_ml=t_ml)
    for klen, vlen in sizes:
        c = pol.classify_scalar(klen, vlen)
        assert c in (0, 1, 2)
        bigger = pol.classify_scalar(klen, vlen + 1000)
        assert bigger >= c  # larger value never moves toward 'small'
