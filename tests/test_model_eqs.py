"""Paper Section 2: the I/O-amplification model (Eq. 1-4, R(i), thresholds)."""
import numpy as np
import pytest

from repro.core import model as M


@pytest.mark.parametrize("levels,f", [(2, 4), (3, 4), (3, 8), (4, 8), (5, 10)])
def test_eq1_literal_matches_eq2_closed_form(levels, f):
    s0 = 1024.0
    sl = s0 * f**levels
    literal = M.amplification_inplace_sum(levels, f, s0)
    closed = M.amplification_inplace(levels, f, sl)
    assert literal == pytest.approx(closed, rel=1e-9)


def test_eq4_ratio_consistent_with_eq2_eq3():
    l, f = 4, 8
    for p in [0.01, 0.02, 0.1, 0.2, 0.5, 1.0]:
        d = M.amplification_inplace(l, f, 1.0)
        dp = M.amplification_separated(l, f, p, 1.0)
        ratio = float(M.separation_benefit(l, f, p))
        assert ratio == pytest.approx(d / dp, rel=1e-5)


def test_paper_fig2a_magnitudes():
    """Fig. 2a: order-of-magnitude benefit for large, <=~3x for small KVs."""
    l, f = 4, 8  # production-like tree
    large = float(M.separation_benefit(l, f, 0.012))  # 1004B values, 12B prefix
    med = float(M.separation_benefit(l, f, 0.094))    # 104B values
    small = float(M.separation_benefit(l, f, 0.33))   # 9B values w/ 12B prefix
    assert large > 6.0
    assert 3.0 < med < large
    assert small < 3.0


def test_capacity_ratio_matches_paper_fig2b():
    # paper: merging at N-1 delays ~10% (f=8) to ~25% (f=4) of capacity
    assert 0.09 < M.capacity_ratio(5, 8, 1) < 0.15
    assert 0.2 < M.capacity_ratio(5, 4, 1) < 0.27
    # merging at N-2 delays at most ~6%
    assert M.capacity_ratio(5, 4, 2) < 0.07
    assert M.capacity_ratio(5, 8, 2) < 0.03


def test_capacity_ratio_monotonic():
    for f in (4, 8, 10):
        rs = [M.capacity_ratio(6, f, i) for i in range(1, 5)]
        assert all(a > b for a, b in zip(rs, rs[1:]))


def test_classifier_paper_sizes():
    """Table 1 sizes: 24B keys; 9/104/1004B values -> small/medium/large."""
    pol = M.SizePolicy()
    assert pol.classify_scalar(24, 9) == 0      # small: in place
    assert pol.classify_scalar(24, 104) == 1    # medium: transient log
    assert pol.classify_scalar(24, 1004) == 2   # large: log + GC


def test_classifier_thresholds_are_boundaries():
    pol = M.SizePolicy(prefix_size=12)
    # p exactly above T_SM -> small; below T_ML -> large
    assert pol.classify_scalar(12, 12) == 0       # p = 0.5
    assert pol.classify_scalar(12, 1200) == 2     # p ~ 0.0099
    assert pol.classify_scalar(12, 100) == 1      # p ~ 0.107


def test_classifier_vectorized_matches_scalar():
    pol = M.SizePolicy()
    ks = np.array([24, 24, 24, 12, 100])
    vs = np.array([9, 104, 1004, 5000, 4])
    vec = np.asarray(pol.classify(ks, vs))
    scl = np.array([pol.classify_scalar(int(k), int(v)) for k, v in zip(ks, vs)])
    assert np.array_equal(vec, scl)


def test_levels_for_dataset():
    assert M.levels_for_dataset(100 * 2**30, 2**27, 8) == 4  # 100GB, 128MB L0
    assert M.levels_for_dataset(2**27, 2**27, 8) == 1
