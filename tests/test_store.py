"""ParallaxStore functional behaviour across all placement modes."""
import random

import pytest

from repro.core import ParallaxStore, StoreConfig
from repro.core.lsm import CAT_LARGE, CAT_MEDIUM, CAT_SMALL

MODES = ["parallax", "rocksdb", "blobdb", "nomerge"]


def payload(n: int) -> bytes:
    return (b"v" * n)


def small_store(mode, **kw):
    defaults = dict(mode=mode, l0_capacity=1 << 14, cache_bytes=1 << 16,
                    segment_bytes=1 << 16, chunk_bytes=1 << 12)
    defaults.update(kw)
    return ParallaxStore(StoreConfig(**defaults))


@pytest.mark.parametrize("mode", MODES)
def test_put_get_update_delete(mode):
    st = small_store(mode)
    st.put(b"alpha", payload(9))
    st.put(b"beta", payload(104))
    st.put(b"gamma", payload(1004))
    assert st.get(b"alpha") == payload(9)
    assert st.get(b"beta") == payload(104)
    assert st.get(b"gamma") == payload(1004)
    st.update(b"alpha", payload(50))
    assert st.get(b"alpha") == payload(50)
    st.delete(b"beta")
    assert st.get(b"beta") is None
    assert st.get(b"missing") is None


@pytest.mark.parametrize("mode", MODES)
def test_multi_level_correctness(mode):
    st = small_store(mode, growth_factor=4)
    oracle = {}
    rng = random.Random(0)
    for i in range(8000):
        k = f"key{rng.randrange(3000):05d}".encode()
        sz = rng.choice([9, 104, 1004])
        st.put(k, payload(sz))
        oracle[k] = payload(sz)
    assert len(st.levels) >= 2, "expected a multi-level tree"
    for k, v in oracle.items():
        assert st.get(k) == v
    # full scan equals sorted oracle
    res = st.scan(b"", len(oracle) + 10)
    assert res == sorted(oracle.items())


def test_category_placement():
    st = small_store("parallax", l0_capacity=1 << 20)
    st.put(b"k" * 24, payload(9))
    st.put(b"m" * 24, payload(104))
    st.put(b"l" * 24, payload(1004))
    assert st.l0[b"k" * 24].category == CAT_SMALL
    assert st.l0[b"m" * 24].category == CAT_MEDIUM
    assert st.l0[b"l" * 24].category == CAT_LARGE
    assert st.l0[b"l" * 24].ptr is not None          # large goes to log at insert
    assert st.l0[b"m" * 24].value is not None        # medium rides in L0


def test_medium_merged_in_place_at_last_level():
    st = small_store("parallax")
    for i in range(3000):
        st.put(f"key{i:06d}".encode(), payload(104))
    # in-place zone = last merge_depth levels: entries there must hold values
    last = st.levels[-1]
    assert len(last) > 0
    in_place = [e for e in last.entries if e.category == CAT_MEDIUM and e.in_place]
    assert len(in_place) == len([e for e in last.entries if e.category == CAT_MEDIUM])


def test_nomerge_keeps_mediums_in_log():
    st = small_store("nomerge")
    for i in range(3000):
        st.put(f"key{i:06d}".encode(), payload(104))
    assert len(st.medium_log.segments) > 0
    last = st.levels[-1]
    med = [e for e in last.entries if e.category == CAT_MEDIUM]
    assert med and all(not e.in_place for e in med)


def test_gc_reclaims_invalid_large_segments():
    st = small_store("parallax")
    for rounds in range(4):
        for i in range(300):
            st.put(f"key{i:05d}".encode(), payload(1004))
    before = len(st.large_log.segments)
    reclaimed = st.gc_tick()
    assert reclaimed > 0
    assert len(st.large_log.segments) < before
    for i in range(300):
        assert st.get(f"key{i:05d}".encode()) == payload(1004)


def test_gc_noop_on_pure_inserts():
    st = small_store("parallax")
    for i in range(600):
        st.put(f"key{i:05d}".encode(), payload(1004))
    assert st.gc_tick() == 0  # nothing invalid -> no segment eligible (paper Load A)


def test_scan_with_tombstones_and_updates():
    st = small_store("parallax")
    for i in range(200):
        st.put(f"key{i:04d}".encode(), payload(104))
    for i in range(0, 200, 2):
        st.delete(f"key{i:04d}".encode())
    st.update(b"key0001", payload(9))
    res = st.scan(b"key0000", 10)
    keys = [k for k, _ in res]
    assert b"key0000" not in keys
    assert res[0] == (b"key0001", payload(9))
    assert all(int(k[3:]) % 2 == 1 for k, _ in res)


def test_category_changing_updates():
    """Paper §3.4: updates may change a KV's size category."""
    st = small_store("parallax")
    k = b"mutating-key-0123456789"
    for size in (9, 1004, 104, 9, 1004):
        st.update(k, payload(size))
        assert st.get(k) == payload(size)
    # push through compactions and re-verify
    for i in range(2000):
        st.put(f"fill{i:06d}".encode(), payload(104))
    assert st.get(k) == payload(1004)


def test_amplification_ordering_medium_load():
    """Paper Fig. 8 trend: parallax < rocksdb for medium-dominated loads."""
    results = {}
    for mode in ("parallax", "rocksdb"):
        st = small_store(mode, l0_capacity=1 << 14)
        for i in range(4000):
            st.put(f"key{i:06d}".encode(), payload(104))
        results[mode] = st.amplification()
    assert results["parallax"] < results["rocksdb"]


def test_space_reclaimed_after_medium_merge():
    st = small_store("parallax")
    for i in range(4000):
        st.put(f"key{i:06d}".encode(), payload(104))
    # transient log only holds segments still attached to non-last levels
    attached = {s for lvl in st.levels for s in lvl.transient_segments}
    assert set(st.medium_log.segments).issuperset(attached)
    live = st.medium_log.live_bytes
    dataset = sum(e.kv_size for lvl in st.levels for e in lvl.entries)
    assert live < dataset  # most mediums merged in place; log is bounded
