"""Roofline analytic-model unit tests: invariants a correct cost model obeys."""
import jax
import pytest

from repro.configs import ARCHS, SHAPES
from repro.launch import roofline


class FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.size = 1
        for v in shape.values():
            self.size *= v


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_terms_positive_and_finite(arch):
    cfg = ARCHS[arch]
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        a = roofline.analytic_costs(cfg, SHAPES[shape], MESH)
        assert a["flops_dev"] > 0 and a["hbm_dev"] > 0 and a["wire_dev"] >= 0
        for v in a.values():
            assert v == v and v != float("inf")


def test_multipod_divides_work():
    cfg = ARCHS["qwen3-8b"]
    single = roofline.analytic_costs(cfg, SHAPES["train_4k"], MESH)
    multi = roofline.analytic_costs(cfg, SHAPES["train_4k"], POD)
    # 2x devices -> per-device matmul flops halve (attention too)
    assert multi["flops_dev"] < 0.6 * single["flops_dev"]


def test_pure_dp_removes_tp_collectives():
    cfg = ARCHS["mamba2-780m"]
    base = roofline.analytic_costs(cfg, SHAPES["train_4k"], MESH, "baseline")
    pure = roofline.analytic_costs(cfg, SHAPES["train_4k"], MESH, "pure-dp")
    assert pure["wire_dev"] < 0.1 * base["wire_dev"]


def test_replicated_weights_kills_decode_gather():
    cfg = ARCHS["qwen3-moe-30b-a3b"]
    base = roofline.analytic_costs(cfg, SHAPES["decode_32k"], MESH, "baseline")
    repl = roofline.analytic_costs(cfg, SHAPES["decode_32k"], MESH, "replicated-weights")
    assert repl["wire_dev"] < 0.05 * base["wire_dev"]


def test_bf16_grads_halve_grad_reduction():
    cfg = ARCHS["mamba2-780m"]
    f32 = roofline.analytic_costs(cfg, SHAPES["train_4k"], MESH, "pure-dp", grad_bytes=4)
    bf16 = roofline.analytic_costs(cfg, SHAPES["train_4k"], MESH, "pure-dp", grad_bytes=2)
    assert bf16["wire_dev"] == pytest.approx(f32["wire_dev"] / 2, rel=0.01)


def test_train_flops_track_remat():
    import dataclasses

    cfg = ARCHS["qwen2.5-3b"]
    with_r = roofline.analytic_costs(cfg, SHAPES["train_4k"], MESH)
    no_r = roofline.analytic_costs(dataclasses.replace(cfg, remat=False), SHAPES["train_4k"], MESH)
    assert with_r["flops_dev"] == pytest.approx(no_r["flops_dev"] * 8 / 6, rel=0.02)


def test_model_flops_definition():
    cfg = ARCHS["deepseek-moe-16b"]
    mf_train = roofline.model_flops_for(cfg, SHAPES["train_4k"])
    assert mf_train == pytest.approx(6 * cfg.active_param_count() * 256 * 4096)
    mf_dec = roofline.model_flops_for(cfg, SHAPES["decode_32k"])
    assert mf_dec == pytest.approx(2 * cfg.active_param_count() * 128)


def test_collective_parse_handles_hlo_shapes():
    hlo = """
      %ar = f32[16,4096] all-reduce(f32[16,4096] %x), replica_groups={{0,1,2,3}}
      %ag = bf16[8,128] all-gather(bf16[2,128] %y), replica_groups=[4,8]<=[32]
      %cp = f32[4] collective-permute(f32[4] %z)
    """
    stats = roofline.collective_bytes(hlo)
    assert stats.count == 3
    ar = 2 * 16 * 4096 * 4 * (3 / 4)
    ag = 8 * 128 * 2 * (7 / 8)
    cp = 16
    assert stats.wire_bytes == pytest.approx(ar + ag + cp)
