"""Sharded batch front-end: throughput/amplification vs shard count and blooms.

Drives YCSB runs A/B/C through :class:`repro.core.shard.ShardedStore` with the
batched ``execute`` path for every combination of shard count (1/2/4/8) and
per-level bloom filters (on/off), against a seed-style baseline (one bare
``ParallaxStore``, blooms off, per-op execute).

Throughput model: shards are independent devices and cores, so device time is
the max over shards and modeled CPU cycles are divided across shards.

Claims asserted:
* batched run C with blooms pays fewer index probes per op than the seed
  single-store path (the filters skip levels that cannot hold the key);
* ``ShardedStore(num_shards=1)`` returns get/scan results identical to a bare
  ``ParallaxStore`` over the same workload.
"""
from __future__ import annotations

import dataclasses
import time

import repro.api as api
from .common import AVG_KV, C_BYTE, C_GC_LOOKUP, C_MERGE, C_OP, C_PROBE, CLOCK_HZ, open_engine, run_phase, scaled_config
from .common import run_async_claim
from repro.core.ycsb import Workload, make_key

MIX = "SD"
# run E makes the hash-shard scan fan-out cost visible: every scan must probe
# all N shards (k-way merge), the baseline bench_range's range partitioning
# beats
RUNS = ("run_a", "run_b", "run_c", "run_e")
BATCH = 64


def _reset_op_counters(store) -> None:
    for s in store.shards:
        s.stats.index_probes = 0
        s.stats.bloom_skips = 0
        s.stats.entries_merged = 0
        s.stats.gc_lookups = 0


def run_sharded_phase(name: str, engine: api.Engine, ops, batch: int = BATCH) -> dict:
    store = engine.store
    t0 = time.time()
    snaps = [s.device.stats.snapshot() for s in store.shards]
    app0 = sum(s.stats.app_bytes for s in store.shards)
    _reset_op_counters(store)
    counts = api.execute(engine, ops, batch_size=batch)
    nops = sum(counts.values())
    deltas = [s.device.stats.delta(sn) for s, sn in zip(store.shards, snaps)]
    total_bytes = sum(d.total for d in deltas)
    app = sum(s.stats.app_bytes for s in store.shards) - app0
    agg = store.aggregate_stats()
    cycles = (
        C_OP * nops
        + C_PROBE * agg.index_probes
        + C_MERGE * agg.entries_merged
        + C_GC_LOOKUP * agg.gc_lookups
        + C_BYTE * total_bytes
    )
    dev_time = max(s.device.device_time(d) for s, d in zip(store.shards, deltas))
    cpu_time = cycles / CLOCK_HZ / store.num_shards  # one core per shard
    return {
        "name": name,
        "ops": nops,
        "amp": total_bytes / max(app, 1),
        "kops": nops / max(dev_time, cpu_time, 1e-9) / 1e3,
        "probes_per_op": agg.index_probes / max(nops, 1),
        "bloom_skips": agg.bloom_skips,
        "wall_s": time.time() - t0,
        "cfg": engine.config.tag(),
    }


def _row(r: dict, shards: int, bloom: bool) -> str:
    us = 1e6 * r["wall_s"] / max(r["ops"], 1)
    return (
        f"{r['name']}/parallax-x{shards}{'+bloom' if bloom else ''}@{r['cfg']},{us:.2f},"
        f"amp={r['amp']:.2f};kops={r['kops']:.1f};"
        f"probes_op={r['probes_per_op']:.2f};bloom_skips={r['bloom_skips']}"
    )


def main(emit, smoke: bool = False) -> None:
    # smoke keeps enough keys that the 1-shard tree has >= 2 levels (blooms
    # have nothing to skip in a single-level tree)
    keys = 2000 if smoke else 4000
    num_ops = keys // 2
    shard_counts = (1, 2) if smoke else (1, 2, 4, 8)
    base_cfg = scaled_config("parallax", dataset_keys=keys, avg_kv_bytes=AVG_KV[MIX])

    # seed-style baseline: one store, blooms off, per-op execute
    seed_cfg = dataclasses.replace(base_cfg, bloom_bits_per_key=0)
    seed = open_engine(seed_cfg)
    load_w = Workload("load_a", MIX, num_keys=keys, num_ops=0)
    emit(run_phase("shard:seed:load_a", "parallax-seed", seed, load_w.load_ops()).row())
    seed_probes: dict[str, float] = {}
    for run_kind in RUNS:
        w = Workload(run_kind, MIX, num_keys=keys, num_ops=num_ops)
        res = run_phase(f"shard:seed:{run_kind}", "parallax-seed", seed, w.run_ops())
        seed_probes[run_kind] = seed.store.stats.index_probes / max(res.ops, 1)
        emit(res.row())

    probes_run_c: dict[tuple[bool, int], float] = {}
    bloom_skips_run_c: dict[int, int] = {}
    for bloom in (False, True):
        for n in shard_counts:
            # fixed TOTAL memory budget across the fleet: L0 and cache are
            # split over the shards (otherwise per-shard trees collapse to a
            # single level and the bloom dimension measures nothing for n>1)
            cfg = dataclasses.replace(
                base_cfg,
                l0_capacity=max(base_cfg.l0_capacity // n, 1 << 11),
                cache_bytes=base_cfg.cache_bytes // n,
                bloom_bits_per_key=10 if bloom else 0,
            )
            engine = open_engine(cfg, partitioning=f"hash:{n}")
            tag = f"x{n}{'b' if bloom else ''}"
            r = run_sharded_phase(f"shard:{tag}:load_a", engine, load_w.load_ops())
            emit(_row(r, n, bloom))
            for run_kind in RUNS:
                w = Workload(run_kind, MIX, num_keys=keys, num_ops=num_ops)
                r = run_sharded_phase(f"shard:{tag}:{run_kind}", engine, w.run_ops())
                emit(_row(r, n, bloom))
                if run_kind == "run_c":
                    probes_run_c[(bloom, n)] = r["probes_per_op"]
                    if bloom:
                        bloom_skips_run_c[n] = r["bloom_skips"]

    # claim 1: filters do real work on the read-only run — at every shard
    # count blooms fire and cut probes/op vs the same config without them,
    # and the batched 1-shard bloom path beats the seed single-store path
    # (same tree shape, so the delta is purely the filters)
    for n in shard_counts:
        assert bloom_skips_run_c[n] > 0, (n, bloom_skips_run_c)
        assert probes_run_c[(True, n)] < probes_run_c[(False, n)], (n, probes_run_c)
    assert probes_run_c[(True, 1)] < seed_probes["run_c"], (probes_run_c, seed_probes)
    emit(
        "shard/claims,0,"
        f"runC_probes_seed={seed_probes['run_c']:.2f};"
        f"runC_probes_bloom_x1={probes_run_c[(True, 1)]:.2f};"
        f"runC_bloom_vs_nobloom_all_shards=lower"
    )

    # claim 3 (PR 4, acceptance): the async engine realizes the overlap the
    # device model promises — paced wall-clock batch throughput on run C at 4
    # shards with 4 workers is >= 2x the 1-worker serialization of the same
    # engine, and the modeled overlap policies bracket the measurement
    async_n, async_workers = 4, 4
    async_cfg = dataclasses.replace(
        base_cfg,
        l0_capacity=max(base_cfg.l0_capacity // async_n, 1 << 11),
        cache_bytes=base_cfg.cache_bytes // async_n,
        bloom_bits_per_key=10,
    )

    def make_async_engine(execution: api.ExecutionConfig) -> api.Engine:
        eng = open_engine(async_cfg, partitioning=f"hash:{async_n}", execution=execution)
        api.execute(eng, load_w.load_ops(), batch_size=BATCH)
        return eng

    run_c = lambda: Workload("run_c", MIX, num_keys=keys, num_ops=num_ops).run_ops()
    run_async_claim(emit, "shard:async",
                    f"shard:async:run_c/parallax-x{async_n}w{async_workers}",
                    make_async_engine, run_c, workers=async_workers, batch=BATCH)

    # claim 2: a 1-shard bloom-filtered front-end is indistinguishable from the
    # bare filterless store (routing + batching + filters change no results)
    bare = open_engine(dataclasses.replace(base_cfg, bloom_bits_per_key=0))
    front = open_engine(dataclasses.replace(base_cfg, bloom_bits_per_key=10),
                        partitioning="hash:1")
    api.execute(bare, load_w.load_ops())
    api.execute(front, load_w.load_ops(), batch_size=BATCH)
    probe_keys = [make_key(i) for i in range(keys + 10)]
    assert [front.get(k) for k in probe_keys] == [bare.get(k) for k in probe_keys]
    assert front.scan(b"", keys + 10) == bare.scan(b"", keys + 10)
    emit(f"shard/claims,0,n1_equivalent_to_bare_store=true;keys={keys}")
