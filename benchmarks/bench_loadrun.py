"""Paper Fig. 6: Load A (top) and Run A (bottom) across all six KV-size mixes
for Parallax / RocksDB / BlobDB: throughput, amplification, efficiency."""
from __future__ import annotations

from .common import load_then_run

MIXES = ["S", "M", "L", "SD", "MD", "LD"]
SYSTEMS = ["parallax", "rocksdb", "blobdb"]
KEYS = {"S": 20_000, "M": 12_000, "L": 5_000, "SD": 10_000, "MD": 10_000, "LD": 8_000}


def main(emit) -> None:
    amps: dict[tuple[str, str, str], float] = {}
    for mix in MIXES:
        for system in SYSTEMS:
            load, run, _ = load_then_run(
                f"fig6:{mix}", system, mix,
                num_keys=KEYS[mix], num_ops=KEYS[mix] // 2,
                cfg_kw={"dataset_keys": KEYS[mix]},
            )
            emit(load.row())
            emit(run.row())
            amps[(mix, system, "load")] = load.amplification
            amps[(mix, system, "run")] = run.amplification
    # paper claims (Fig. 6): for all mixes except S, Parallax amp < RocksDB on
    # Load A; on Run A Parallax beats both baselines for mixed workloads
    for mix in ("M", "L", "SD", "MD", "LD"):
        assert amps[(mix, "parallax", "load")] < amps[(mix, "rocksdb", "load")], mix
    for mix in ("SD", "MD", "LD"):
        assert amps[(mix, "parallax", "run")] < amps[(mix, "rocksdb", "run")], mix
        assert amps[(mix, "parallax", "run")] < amps[(mix, "blobdb", "run")], mix
    emit("fig6/claims,0,parallax_beats_baselines_on_mixed_runA=true")
