"""Benchmark harness: one module per paper table/figure.

Each benchmark emits ``name,us_per_call,derived`` CSV rows and asserts the
paper's qualitative claims (orderings/ratios) on the scaled workloads —
failures here mean the reproduction no longer matches the paper.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run fig1 merge  # substring filter
    PYTHONPATH=src python -m benchmarks.run --smoke     # CI: tiny shard+ycsb
    PYTHONPATH=src python -m benchmarks.run --smoke --json OUT.json
                                                        # + machine-readable rows

``--json`` writes every emitted row as ``{"name", "us_per_call", "derived"}``
(plus the failure list); ``scripts/check_bench.py`` diffs such a file against
the checked-in ``BENCH_BASELINE.json`` — that pair is the CI bench-regression
gate (.github/workflows/ci.yml).  A substring filter that matches nothing is
an error (exit 2, listing valid names): CI must not green-light a typo'd
bench job by silently running zero benchmarks.
"""
from __future__ import annotations

import json
import sys
import time
import traceback

from . import (
    bench_ablation,
    bench_analysis,
    bench_thresholds,
    bench_checkpoint,
    bench_elastic,
    bench_fig1,
    bench_kernels,
    bench_lifetime,
    bench_loadrun,
    bench_merge,
    bench_model,
    bench_range,
    bench_roofline,
    bench_shard,
    bench_ycsb,
)

BENCHES = [
    ("model_fig2", bench_model.main),
    ("fig1_small_kv_gc", bench_fig1.main),
    ("fig5_ycsb", bench_ycsb.main),
    ("shard_batch_frontend", bench_shard.main),
    ("range_vs_hash_sharding", bench_range.main),
    ("fig6_loadrun", bench_loadrun.main),
    ("fig7_medium_ablation", bench_ablation.main),
    ("thresholds_beyond_paper", bench_thresholds.main),
    ("fig8_merge_level", bench_merge.main),
    ("kernels", bench_kernels.main),
    ("checkpoint_substrate", bench_checkpoint.main),
    ("roofline", bench_roofline.main),
    ("analysis_overhead", bench_analysis.main),
    ("lifetime_placement", bench_lifetime.main),
    ("elastic_rescale", bench_elastic.main),
]


# --smoke: a seconds-long CI job — the YCSB suite plus both sharded
# front-ends (hash + range) at tiny num_keys/num_ops (claims that need scale
# are skipped); any registered bench raising fails the job (exit 1)
SMOKE_BENCHES = [
    ("fig5_ycsb", lambda emit: bench_ycsb.main(emit, smoke=True)),
    ("shard_batch_frontend", lambda emit: bench_shard.main(emit, smoke=True)),
    ("range_vs_hash_sharding", lambda emit: bench_range.main(emit, smoke=True)),
    ("analysis_overhead", lambda emit: bench_analysis.main(emit, smoke=True)),
    ("checkpoint_substrate", lambda emit: bench_checkpoint.main(emit, smoke=True)),
    ("lifetime_placement", lambda emit: bench_lifetime.main(emit, smoke=True)),
    ("elastic_rescale", lambda emit: bench_elastic.main(emit, smoke=True)),
]


def main() -> None:
    argv = list(sys.argv[1:])
    json_out: str | None = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv) or argv[i + 1].startswith("-"):
            print("error: --json needs an output path", file=sys.stderr)
            sys.exit(2)
        json_out = argv[i + 1]
        del argv[i:i + 2]
    smoke = "--smoke" in argv
    unknown = [a for a in argv if a.startswith("-") and a != "--smoke"]
    if unknown:
        # same failure class as the zero-match filter: a typo'd flag silently
        # running the wrong bench set must not green-light a CI job
        print(f"error: unknown flag(s) {unknown!r}; valid flags: --smoke, --json OUT.json",
              file=sys.stderr)
        sys.exit(2)
    filters = [a for a in argv if not a.startswith("-")]
    benches = SMOKE_BENCHES if smoke else BENCHES
    selected = [(name, fn) for name, fn in benches
                if not filters or any(f in name for f in filters)]
    if filters and not selected:
        valid = ", ".join(name for name, _ in benches)
        print(f"error: filter(s) {filters!r} matched no benchmarks; "
              f"valid names ({'smoke' if smoke else 'full'} set): {valid}",
              file=sys.stderr)
        sys.exit(2)

    rows: list[str] = []

    def emit(row: str) -> None:
        print(row, flush=True)
        rows.append(row)

    print("name,us_per_call,derived")
    failures = []
    for name, fn in selected:
        t0 = time.time()
        try:
            fn(emit)
            emit(f"bench:{name}/total,{(time.time()-t0)*1e6:.0f},ok")
        except AssertionError as e:
            failures.append((name, e))
            emit(f"bench:{name}/total,{(time.time()-t0)*1e6:.0f},CLAIM-FAILED:{e}")
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()
            emit(f"bench:{name}/total,{(time.time()-t0)*1e6:.0f},ERROR:{type(e).__name__}")
    if json_out:
        def row_dict(row: str) -> dict:
            d = dict(zip(("name", "us_per_call", "derived"), row.split(",", 2)))
            # PR 5: per-engine rows carry the engine-config tag after '@' in
            # their id (EngineConfig.tag(), e.g. "hash4+serial"); surface it
            # as its own field so baseline diffs can key on configuration
            # without parsing row names.  Gate rows append ':gate' after the
            # tag ('<prefix>@<tag>:gate') — tags never contain ':', so the
            # suffix is split back off here.
            name = d["name"]
            d["engine"] = name.split("@", 1)[1].split(":", 1)[0] if "@" in name else ""
            return d

        payload = {
            "smoke": smoke,
            "rows": [row_dict(row) for row in rows],
            "failures": [name for name, _ in failures],
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
