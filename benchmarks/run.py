"""Benchmark harness: one module per paper table/figure.

Each benchmark emits ``name,us_per_call,derived`` CSV rows and asserts the
paper's qualitative claims (orderings/ratios) on the scaled workloads —
failures here mean the reproduction no longer matches the paper.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run fig1 merge  # substring filter
    PYTHONPATH=src python -m benchmarks.run --smoke     # CI: tiny shard+ycsb
"""
from __future__ import annotations

import sys
import time
import traceback

from . import (
    bench_ablation,
    bench_thresholds,
    bench_checkpoint,
    bench_fig1,
    bench_kernels,
    bench_loadrun,
    bench_merge,
    bench_model,
    bench_range,
    bench_roofline,
    bench_shard,
    bench_ycsb,
)

BENCHES = [
    ("model_fig2", bench_model.main),
    ("fig1_small_kv_gc", bench_fig1.main),
    ("fig5_ycsb", bench_ycsb.main),
    ("shard_batch_frontend", bench_shard.main),
    ("range_vs_hash_sharding", bench_range.main),
    ("fig6_loadrun", bench_loadrun.main),
    ("fig7_medium_ablation", bench_ablation.main),
    ("thresholds_beyond_paper", bench_thresholds.main),
    ("fig8_merge_level", bench_merge.main),
    ("kernels", bench_kernels.main),
    ("checkpoint_substrate", bench_checkpoint.main),
    ("roofline", bench_roofline.main),
]


# --smoke: a seconds-long CI job — the YCSB suite plus both sharded
# front-ends (hash + range) at tiny num_keys/num_ops (claims that need scale
# are skipped); any registered bench raising fails the job (exit 1)
SMOKE_BENCHES = [
    ("fig5_ycsb", lambda emit: bench_ycsb.main(emit, smoke=True)),
    ("shard_batch_frontend", lambda emit: bench_shard.main(emit, smoke=True)),
    ("range_vs_hash_sharding", lambda emit: bench_range.main(emit, smoke=True)),
]


def main() -> None:
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    benches = SMOKE_BENCHES if "--smoke" in sys.argv[1:] else BENCHES
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches:
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        try:
            fn(lambda row: print(row, flush=True))
            print(f"bench:{name}/total,{(time.time()-t0)*1e6:.0f},ok", flush=True)
        except AssertionError as e:
            failures.append((name, e))
            print(f"bench:{name}/total,{(time.time()-t0)*1e6:.0f},CLAIM-FAILED:{e}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            traceback.print_exc()
            print(f"bench:{name}/total,{(time.time()-t0)*1e6:.0f},ERROR:{type(e).__name__}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
