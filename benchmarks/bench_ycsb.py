"""Paper Fig. 5: full YCSB suite (A-E) for SD and MD mixes, three systems.

Run E (scans) is the separation-hostile workload: expect RocksDB > Parallax
>> BlobDB on throughput, with Parallax closing most of the gap (paper: within
~40% of RocksDB while BlobDB is ~8x off)."""
from __future__ import annotations

from .common import open_engine, run_phase, scaled_config
from repro.core.ycsb import Workload

SYSTEMS = ["parallax", "rocksdb", "blobdb"]
RUNS = ["run_a", "run_b", "run_c", "run_d"]
KEYS = 10_000


def main(emit, smoke: bool = False) -> None:
    # --smoke (CI): tiny keyspace, SD only, skip the scale-sensitive ordering
    # assertion — the goal is exercising every phase end-to-end in seconds.
    keys = 1200 if smoke else KEYS
    mixes = ("SD",) if smoke else ("SD", "MD")
    scan_ops = 80 if smoke else 600
    scan_kops: dict[str, float] = {}
    for mix in mixes:
        for system in SYSTEMS:
            from .common import AVG_KV

            cfg = scaled_config(system, dataset_keys=keys, avg_kv_bytes=AVG_KV[mix])
            engine = open_engine(cfg)
            load = run_phase(
                f"fig5:{mix}:load_a", system, engine,
                Workload("load_a", mix, num_keys=keys, num_ops=0).load_ops(),
            )
            emit(load.row())
            for run_kind in RUNS:
                w = Workload(run_kind, mix, num_keys=keys, num_ops=keys // 4)
                res = run_phase(f"fig5:{mix}:{run_kind}", system, engine, w.run_ops())
                emit(res.row())
            # Run E: scan-heavy
            w = Workload("run_e", mix, num_keys=keys, num_ops=scan_ops)
            res = run_phase(f"fig5:{mix}:run_e", system, engine, w.run_ops())
            emit(res.row())
            if mix == "SD":
                scan_kops[system] = res.kops
    if smoke:
        return
    # paper Run E ordering: rocksdb > parallax >> blobdb
    assert scan_kops["rocksdb"] > scan_kops["parallax"] > scan_kops["blobdb"], scan_kops
    gap_rocks = scan_kops["rocksdb"] / scan_kops["parallax"]
    gap_blob = scan_kops["parallax"] / scan_kops["blobdb"]
    emit(f"fig5/claims,0,runE_rocksdb_over_parallax={gap_rocks:.2f}x;parallax_over_blobdb={gap_blob:.2f}x")
