"""Race-detector overhead: run C throughput with ``debug_checks`` off vs on.

Two identical batched YCSB run C phases over a 2-shard async engine, one with
the :mod:`repro.analysis.racecheck` lockset detector attached.  The off row is
a normal gated bench row (the detector must cost *nothing* when disabled — it
is never even imported); the on/off comparison is an informational ``:gate``
row because instrumentation overhead is wall-clock, and wall-clock is not
gated.

Claims asserted:
* the modeled metrics (amplification, kops, probes/op, bloom skips) are
  byte-identical with the detector on and off — observation must not perturb
  the modeled system;
* the instrumented run observes a healthy number of events and zero race
  reports (the engine's clean close raises otherwise).
"""
from __future__ import annotations

import dataclasses

import repro.api as api
from .bench_shard import BATCH, MIX, run_sharded_phase, _row
from .common import AVG_KV, open_engine, scaled_config
from repro.core.ycsb import Workload


def main(emit, smoke: bool = False) -> None:
    keys = 2000 if smoke else 4000
    num_ops = keys // 2
    n = 2
    base = scaled_config("parallax", dataset_keys=keys, avg_kv_bytes=AVG_KV[MIX])
    cfg = dataclasses.replace(
        base,
        l0_capacity=max(base.l0_capacity // n, 1 << 11),
        cache_bytes=base.cache_bytes // n,
        bloom_bits_per_key=10,
    )
    load_w = Workload("load_a", MIX, num_keys=keys, num_ops=0)

    results: dict[bool, dict] = {}
    events = 0
    for debug in (False, True):
        engine = open_engine(cfg, partitioning=f"hash:{n}", execution="async",
                             debug_checks=debug)
        api.execute(engine, load_w.load_ops(), batch_size=BATCH)
        run_c = Workload("run_c", MIX, num_keys=keys, num_ops=num_ops)
        mode = "on" if debug else "off"
        results[debug] = run_sharded_phase(f"analysis:run_c:{mode}", engine,
                                           run_c.run_ops())
        if debug:
            checker = engine.race_checker
            events = checker.events
            assert events > 0, "detector attached but never observed an event"
            assert checker.reports == [], checker.reports
        engine.close()  # clean close raises RaceViolation on any report

    off, on = results[False], results[True]
    # observational transparency: the detector must not move a single modeled
    # number — only wall-clock may differ
    for metric in ("ops", "amp", "kops", "probes_per_op", "bloom_skips"):
        assert on[metric] == off[metric], (metric, on[metric], off[metric])

    # the off row is gated against BENCH_BASELINE.json like any other
    emit(_row(off, n, True))
    overhead = on["wall_s"] / max(off["wall_s"], 1e-9)
    emit(
        "analysis/detector:gate,0,"
        f"overhead_x={overhead:.2f};events={events};"
        f"modeled_metrics_identical=true"
    )
