"""Paper Fig. 8: (top) merge level N-1 vs N-2 and the NoMerge ideal;
(bottom) sorted vs unsorted transient-log segments.  Workload M (all-medium),
growth factor 4 — the paper's stress setup for the transient log."""
from __future__ import annotations

from .common import open_engine, run_phase, scaled_config, tagged
from repro.core.ycsb import Workload

KEYS = 25_000


def one(emit, name: str, *, merge_depth: int, sorted_segments: bool, mode: str = "parallax"):
    cfg = scaled_config(
        mode, growth_factor=4, dataset_keys=KEYS, avg_kv_bytes=128,
        merge_depth=merge_depth, sorted_segments=sorted_segments,
    )
    engine = open_engine(cfg)
    w = Workload("load_a", "M", num_keys=KEYS, num_ops=0)
    res = run_phase(f"fig8:{name}", name, engine, w.load_ops())
    emit(res.row())
    # space amplification: transient-log live bytes over dataset
    space = engine.space_bytes()
    dataset = KEYS * (24 + 104)
    emit(f"{tagged(f'fig8:{name}/space', engine)},0,"
         f"space_amp={space/dataset:.2f};medium_segments={len(engine.store.medium_log.segments)}")
    return res.amplification


def main(emit) -> None:
    amp_n1 = one(emit, "N-1_sorted", merge_depth=1, sorted_segments=True)
    amp_n2 = one(emit, "N-2_sorted", merge_depth=2, sorted_segments=True)
    amp_n1u = one(emit, "N-1_unsorted", merge_depth=1, sorted_segments=False)
    amp_n2u = one(emit, "N-2_unsorted", merge_depth=2, sorted_segments=False)
    amp_ideal = one(emit, "NoMerge_ideal", merge_depth=1, sorted_segments=True, mode="nomerge")
    amp_rocks = one(emit, "rocksdb_ref", merge_depth=1, sorted_segments=True, mode="rocksdb")
    # paper claims:
    assert amp_ideal < amp_n1 < amp_rocks, (amp_ideal, amp_n1, amp_rocks)
    # sorted segments cut amplification substantially at N-1 (paper: ~4x)
    assert amp_n1u / amp_n1 > 1.5, (amp_n1u, amp_n1)
    # merging at N-1 beats N-2 on I/O amplification (paper top row: 6.8 vs 9.6)
    assert amp_n1 < amp_n2, (amp_n1, amp_n2)
    # NOTE: the paper's *secondary* observation (unsorted prefers N-2) does
    # not reproduce at 3-4 levels — recorded, not asserted; see EXPERIMENTS.md
    emit(
        f"fig8/claims,0,sorted_gain_at_N1={amp_n1u/amp_n1:.2f}x;"
        f"N1_vs_N2={amp_n2/amp_n1:.2f}x;unsortedN2_vs_N1={amp_n2u/amp_n1u:.2f}x;"
        f"ideal={amp_ideal:.2f};rocksdb={amp_rocks:.2f}"
    )
