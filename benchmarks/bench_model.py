"""Paper Fig. 2: the analytical model curves (pure math, validates Eq. 2-4)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import model as M


def main(emit) -> None:
    t0 = time.time()
    # Fig 2(a): D/D' vs p for a production tree (l=4, f=8)
    ps = [0.001, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.4, 0.72, 1.0]
    ratios = [float(M.separation_benefit(4, 8, p)) for p in ps]
    for p, r in zip(ps, ratios):
        emit(f"fig2a/ratio_p={p},{(time.time()-t0)*1e6:.1f},DdivDp={r:.2f}")
    # threshold sanity: the paper's categories
    assert ratios[ps.index(0.01)] > 6.0     # large: order of magnitude
    assert ratios[ps.index(0.72)] < 2.0     # small: not worth a log
    # Fig 2(b): R(1), R(2) for f in 4..10
    for f in range(4, 11):
        r1 = M.capacity_ratio(5, f, 1)
        r2 = M.capacity_ratio(5, f, 2)
        emit(f"fig2b/R_f={f},{(time.time()-t0)*1e6:.1f},R1={r1:.4f};R2={r2:.4f}")
    # Eq.1 literal == Eq.2 closed form (model self-check)
    lit = M.amplification_inplace_sum(4, 8, 1024.0)
    clo = M.amplification_inplace(4, 8, 1024.0 * 8**4)
    emit(f"fig2/eq1_vs_eq2,{(time.time()-t0)*1e6:.1f},literal={lit:.0f};closed={clo:.0f};rel_err={abs(lit-clo)/clo:.2e}")
