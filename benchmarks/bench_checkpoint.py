"""Framework-substrate benchmark (beyond paper): the paper's placement
economics applied to incremental checkpointing.  Compares write
amplification and on-disk space for hybrid / inline / log placements over a
training-like trace (large embeddings rarely change layout, medium tensors
update every step, scalars every step).

The ``ckpt:recovery`` row (PR 7, gated in the smoke baseline) measures the
snapshot/truncation win on the shard-metadata WAL: after topology churn, a
``snapshot_metadata(truncate=True)`` cuts recovery replay from the genesis
record count down to the O(delta) post-snapshot tail — the record counts are
deterministic and diffed by ``scripts/check_bench.py``; the ``*_s`` replay
timings are informational."""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.checkpoint.store import LogStructuredCheckpointer
from repro.core import RangeShardedStore, StoreConfig
from repro.core.ycsb import make_key, payload


def trace_state(rng):
    return {
        # a few large tensors (change every step — grads flow everywhere)
        **{f"block{i}/ffn": rng.standard_normal((128, 256)).astype(np.float32) for i in range(4)},
        # many medium tensors
        **{f"block{i}/norm": rng.standard_normal((96,)).astype(np.float32) for i in range(12)},
        # tiny scalars
        **{f"block{i}/step_scale": np.float32(i) for i in range(12)},
    }


def _time_replay(st: RangeShardedStore, repeats: int = 5) -> float:
    """Best-of-N wall time of one full metadata-WAL topology replay."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        st._replay_metalog()
        best = min(best, time.perf_counter() - t0)
    return best


def recovery_bench(emit, smoke: bool = False) -> None:
    """WAL-truncation economics: genesis replay vs post-snapshot replay."""
    nk = 240 if smoke else 720
    rounds = 4 if smoke else 16
    cfg = StoreConfig(l0_capacity=1 << 12, cache_bytes=1 << 15,
                      segment_bytes=1 << 14, chunk_bytes=1 << 11)
    st = RangeShardedStore.for_keys(
        [make_key(i) for i in range(nk)], 2, cfg,
        auto_rebalance=False, migration_batch_keys=16,
    )
    st.put_many([(make_key(i), payload(104)) for i in range(nk)])
    st.flush_all()
    t0 = time.time()
    for _ in range(rounds):  # topology churn: every round appends WAL records
        assert st._split(0)
        st._merge(0)
    genesis_records = st.metalog.n_records
    genesis_replay = _time_replay(st)
    st.snapshot_metadata(truncate=True)
    assert st._split(0)  # post-snapshot delta: the only history left to replay
    delta_records = st.metalog.n_records
    delta_replay = _time_replay(st)
    wall = time.time() - t0
    emit(
        f"ckpt:recovery,{1e6*wall/rounds:.1f},"
        f"genesis_records={genesis_records};delta_records={delta_records};"
        f"genesis_replay_s={genesis_replay:.6f};delta_replay_s={delta_replay:.6f};"
        f"speedup={genesis_replay/max(delta_replay, 1e-9):.1f}"
    )
    # the paper-level claim: recovery replays O(delta), not O(history)
    assert delta_records * 4 <= genesis_records, (delta_records, genesis_records)
    if not smoke:  # timing claims need scale; the smoke run only gates counts
        assert delta_replay < genesis_replay, (delta_replay, genesis_replay)


def main(emit, smoke: bool = False) -> None:
    for mode in ("hybrid", "inline", "log"):
        d = tempfile.mkdtemp(prefix=f"ckpt-{mode}-")
        try:
            ck = LogStructuredCheckpointer(d, mode=mode, consolidate_every=8)
            rng = np.random.default_rng(0)
            state = trace_state(rng)
            t0 = time.time()
            steps = 8 if smoke else 24
            for step in range(steps):
                for k in state:
                    if "ffn" in k or "norm" in k or "scale" in k:
                        state[k] = np.asarray(state[k]) * 0.999
                ck.save(step, state)
            out, got_step = ck.restore()
            assert got_step == steps - 1
            for k in state:
                np.testing.assert_allclose(out[k], state[k], rtol=1e-6)
            wall = time.time() - t0
            live = sum(np.asarray(v).nbytes for v in state.values())
            emit(
                f"ckpt:{mode},{1e6*wall/steps:.1f},write_amp={ck.write_amplification():.2f};"
                f"space_x_live={ck.space_bytes()/live:.2f};gc_reads={ck.device.stats.gc_read}"
            )
        finally:
            shutil.rmtree(d, ignore_errors=True)
    recovery_bench(emit, smoke=smoke)
