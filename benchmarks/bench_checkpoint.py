"""Framework-substrate benchmark (beyond paper): the paper's placement
economics applied to incremental checkpointing.  Compares write
amplification and on-disk space for hybrid / inline / log placements over a
training-like trace (large embeddings rarely change layout, medium tensors
update every step, scalars every step)."""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.checkpoint.store import LogStructuredCheckpointer


def trace_state(rng):
    return {
        # a few large tensors (change every step — grads flow everywhere)
        **{f"block{i}/ffn": rng.standard_normal((128, 256)).astype(np.float32) for i in range(4)},
        # many medium tensors
        **{f"block{i}/norm": rng.standard_normal((96,)).astype(np.float32) for i in range(12)},
        # tiny scalars
        **{f"block{i}/step_scale": np.float32(i) for i in range(12)},
    }


def main(emit) -> None:
    for mode in ("hybrid", "inline", "log"):
        d = tempfile.mkdtemp(prefix=f"ckpt-{mode}-")
        try:
            ck = LogStructuredCheckpointer(d, mode=mode, consolidate_every=8)
            rng = np.random.default_rng(0)
            state = trace_state(rng)
            t0 = time.time()
            steps = 24
            for step in range(steps):
                for k in state:
                    if "ffn" in k or "norm" in k or "scale" in k:
                        state[k] = np.asarray(state[k]) * 0.999
                ck.save(step, state)
            out, got_step = ck.restore()
            assert got_step == steps - 1
            for k in state:
                np.testing.assert_allclose(out[k], state[k], rtol=1e-6)
            wall = time.time() - t0
            live = sum(np.asarray(v).nbytes for v in state.values())
            emit(
                f"ckpt:{mode},{1e6*wall/steps:.1f},write_amp={ck.write_amplification():.2f};"
                f"space_x_live={ck.space_bytes()/live:.2f};gc_reads={ck.device.stats.gc_read}"
            )
        finally:
            shutil.rmtree(d, ignore_errors=True)
