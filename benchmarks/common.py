"""Shared benchmark machinery: scaled workloads + metric extraction.

Scaling note (EXPERIMENTS.md §Scaling): the paper loads 100-500M keys onto a
375 GB Optane device.  The byte-accounted store reproduces the paper's
*ratios* (amplification, relative throughput/efficiency) at ~1000x smaller
keyspaces by scaling L0 (128 MB -> 32 KB), segments (2 MB -> 128 KB), cache
(Table 1 ratios preserved: ~18-40%% of dataset) and log chunks together, so
the LSM has the same number of levels (3-4) as the paper's datasets.

All benchmarks drive stores through :mod:`repro.api` engines (PR 5): helpers
take/construct an :class:`repro.api.Engine`, and every per-engine row carries
the engine-config tag (``EngineConfig.tag()``) after ``@`` in its row id —
``scripts/check_bench.py`` keys baseline rows on the full id, so config
changes rename rows (a loud baseline diff) instead of silently shifting
numbers under an unchanged name.

Metrics:
* amplification  — device traffic / application traffic (the paper's metric)
* kops           — ops / simulated device time (P4800X bandwidths); a device-
                   bound throughput proxy
* kcycles_per_op — modeled CPU cost: documented constants x op counters
"""
from __future__ import annotations

import dataclasses
import time

import repro.api as api
from repro.core import ParallaxStore, StoreConfig, overlap_time
from repro.core.ycsb import Workload

# modeled CPU constants (cycles); see module docstring
C_OP = 2_000          # per user op (parse, memtable, WAL append)
C_PROBE = 2_500       # per index leaf probe (search + fault amortized)
C_MERGE = 150         # per entry merged in compaction
C_GC_LOOKUP = 3_000   # per GC validity lookup
C_BYTE = 0.1          # per device byte (checksum/memcpy share)
CLOCK_HZ = 3.2e9      # paper testbed cores


AVG_KV = {"S": 33, "M": 128, "L": 1028, "SD": 251, "MD": 289, "LD": 649}


def scaled_config(mode: str, *, growth_factor: int = 4, dataset_keys: int = 20_000,
                  cache_frac: float = 0.2, merge_depth: int = 1,
                  sorted_segments: bool = True, t_sm: float = 0.2, t_ml: float = 0.02,
                  auto_gc: bool = True, avg_kv_bytes: int = 250) -> StoreConfig:
    # growth_factor 4 + 16 KB L0 gives the scaled datasets the same 3-4 level
    # depth as the paper's 10-100 GB datasets (level count drives level
    # amplification, Eq. 2) — see EXPERIMENTS.md §Scaling.
    approx_bytes = dataset_keys * avg_kv_bytes
    return StoreConfig(
        mode=mode,
        t_sm=t_sm,
        t_ml=t_ml,
        l0_capacity=1 << 14,
        growth_factor=growth_factor,
        merge_depth=merge_depth,
        sorted_segments=sorted_segments,
        cache_bytes=int(approx_bytes * cache_frac),
        segment_bytes=1 << 17,
        chunk_bytes=1 << 13,
        auto_gc=auto_gc,
    )


def open_engine(store_config: StoreConfig, **engine_kw) -> api.Engine:
    """One-liner for the benches: an engine over a scaled store config."""
    return api.open(api.EngineConfig(store=store_config, **engine_kw))


def tagged(name: str, engine: api.Engine) -> str:
    """Row id carrying the engine-config tag (see module docstring)."""
    return f"{name}@{engine.config.tag()}"


@dataclasses.dataclass
class BenchResult:
    name: str
    system: str
    ops: int
    amplification: float
    kops: float
    kcycles_per_op: float
    wall_s: float
    cfg: str = ""
    extra: dict = dataclasses.field(default_factory=dict)

    def row(self) -> str:
        us_per_call = 1e6 * self.wall_s / max(self.ops, 1)
        ident = f"{self.name}/{self.system}"
        if self.cfg:
            ident = f"{ident}@{self.cfg}"
        return (
            f"{ident},{us_per_call:.2f},"
            f"amp={self.amplification:.2f};kops={self.kops:.1f};"
            f"kcyc_op={self.kcycles_per_op:.1f}"
        )


def metrics(store: ParallaxStore, ops: int, *, since=None, app_since: int = 0,
            ops_breakdown=None) -> tuple[float, float, float]:
    dstats = store.device.stats if since is None else store.device.stats.delta(since)
    app = store.stats.app_bytes - app_since
    amp = dstats.total / max(app, 1)
    dev_time = store.device.device_time(dstats)
    s = store.stats
    cycles = (
        C_OP * ops
        + C_PROBE * s.index_probes
        + C_MERGE * s.entries_merged
        + C_GC_LOOKUP * s.gc_lookups
        + C_BYTE * dstats.total
    )
    cpu_time = cycles / CLOCK_HZ
    kops = ops / max(dev_time, cpu_time, 1e-9) / 1e3
    kcyc = cycles / max(ops, 1) / 1e3
    return amp, kops, kcyc


def run_phase(name: str, system: str, engine: api.Engine, workload_ops,
              ops_count_hint=None) -> BenchResult:
    """One workload phase through a none-partitioned engine (bare store)."""
    store = engine.store
    t0 = time.time()
    since = store.device.stats.snapshot()
    app0 = store.stats.app_bytes
    # zero op-counters for a clean phase measurement
    store.stats.index_probes = 0
    store.stats.entries_merged = 0
    store.stats.gc_lookups = 0
    counts = api.execute(engine, workload_ops)
    ops = sum(counts.values())
    amp, kops, kcyc = metrics(store, ops, since=since, app_since=app0)
    return BenchResult(name, system, ops, amp, kops, kcyc, time.time() - t0,
                       cfg=engine.config.tag())


def async_speedup_phase(make_engine, run_ops_factory, *, workers: int = 4,
                        batch: int = 64, target_serial_s: float = 0.8) -> dict:
    """Measured wall-clock of the async engine vs its 1-worker serialization,
    against the modeled overlap policies, on one workload phase.

    ``make_engine(execution)`` must build an identically-loaded engine for the
    given :class:`repro.api.ExecutionConfig` each call (three are built: a
    serial model probe plus the two paced async runs).  The probe runs the
    phase on the plain serial path and yields per-shard device-time deltas,
    from which the ``serial`` / ``channels:k`` / ``ideal`` policy times are
    modeled (:func:`repro.core.io.overlap_time`) and the pace is chosen so
    the paced 1-worker run sleeps ~``target_serial_s`` — the GIL makes *CPU*
    overlap impossible, so wall-clock comparisons are meaningful exactly for
    the paced device time (see docs/execution.md).  Both paced runs must
    finish with byte-identical per-shard device stats (pacing and threading
    change no state — the executor's core claim).

    Returns ``model`` (policy -> modeled seconds), ``walls`` (workers ->
    measured seconds), ``speedup`` (1-worker wall / k-worker wall), ``pace``.
    """
    probe = make_engine(api.ExecutionConfig(mode="serial"))
    before = probe.store.device_times()
    api.execute(probe, run_ops_factory(), batch_size=batch)
    after = probe.store.device_times()
    probe.close()
    # per-store deltas are positional: a topology change mid-phase (a range
    # store with its rebalancer live) would misalign them silently — callers
    # must measure on a static topology (hash, or auto_rebalance=False)
    assert len(after) == len(before), (
        f"topology changed during the model probe ({len(before)} -> {len(after)} "
        "stores); async_speedup_phase needs a static topology"
    )
    deltas = [a - b for a, b in zip(after, before)]
    policies = ("serial", "channels:2", f"channels:{workers}", "ideal")
    model = {p: overlap_time(deltas, p) for p in policies}
    pace = target_serial_s / max(model["serial"], 1e-9)
    walls: dict[int, float] = {}
    fleets: dict[int, list] = {}
    tag = ""
    for w, pipelined in ((1, False), (workers, True)):
        engine = make_engine(api.ExecutionConfig(
            mode="async", workers=w, pipeline=pipelined, pace=pace))
        t0 = time.time()
        api.execute(engine, run_ops_factory(), batch_size=batch)
        walls[w] = time.time() - t0
        fleets[w] = [dataclasses.asdict(s.device.stats)
                     for s in engine.store._all_stores()]
        tag = engine.config.tag()  # last iteration: the nominal k-worker config
        engine.close()
    assert fleets[1] == fleets[workers], "pacing/threading must not change device traffic"
    return {
        "model": model,
        "walls": walls,
        "speedup": walls[1] / max(walls[workers], 1e-9),
        "pace": pace,
        "tag": tag,
    }


def async_speedup_row(name: str, r: dict, workers: int) -> str:
    """CSV row for an async_speedup_phase result.  Timing-dependent fields
    end in ``_s`` or are named ``speedup``/``pace`` so the bench-regression
    gate (scripts/check_bench.py) knows to skip them; the ``model_*_us``
    fields are deterministic and gated."""
    model = ";".join(
        f"model_{p.replace(':', '')}_us={t * 1e6:.1f}" for p, t in r["model"].items()
    )
    return (
        f"{name},0,{model};speedup={r['speedup']:.2f};"
        f"serial_wall_s={r['walls'][1]:.3f};async_wall_s={r['walls'][workers]:.3f};"
        f"pace={r['pace']:.0f}"
    )


def run_async_claim(emit, prefix: str, row_name: str, make_engine, run_ops_factory,
                    *, workers: int = 4, batch: int = 64,
                    target_serial_s: float = 2.0) -> dict:
    """The PR 4 async acceptance claim, shared by bench_shard/bench_range:
    measure the paced speedup phase, emit the model-vs-measured row and the
    gate status row, and assert the >=2x wall-clock claim (when meaningful)
    plus the model ladder.  One call site per bench keeps the two benches'
    acceptance criteria identical by construction.  ``make_engine`` is the
    :func:`async_speedup_phase` engine factory; the emitted ids carry the
    nominal async config tag."""
    r = async_speedup_phase(make_engine, run_ops_factory, workers=workers,
                            batch=batch, target_serial_s=target_serial_s)
    emit(async_speedup_row(f"{row_name}@{r['tag']}", r, workers))
    emit_speedup_gate(emit, f"{prefix}@{r['tag']}", r, workers, target_serial_s)
    return r


def emit_speedup_gate(emit, prefix: str, r: dict, workers: int,
                      target_serial_s: float, min_speedup: float = 2.0) -> None:
    """The PR 4 acceptance gate on an async_speedup_phase result.

    The wall-clock assertion is only meaningful while sleeps dominate: the
    non-sleep share of the 1-worker wall (GIL-serialized CPU + executor
    overhead, added equally to both walls) compresses the ratio, so on a
    pathologically loaded host (CPU share > 0.3x the paced sleep — where even
    a >=3x overlap could be squeezed under 2x with no code regression) the
    assertion is skipped.  The ``:gate`` status row is emitted either way
    (deterministic presence; scripts/check_bench.py excludes ``:gate`` rows
    from the regression diff since their values are host-load-dependent).
    Also asserts the modeled policy ladder is consistent.
    """
    cpu_overhead = r["walls"][1] - target_serial_s
    applied = cpu_overhead <= 0.3 * target_serial_s
    emit(f"{prefix}:gate,0,speedup_gate={'applied' if applied else 'skipped_cpu_bound'};"
         f"cpu_overhead_s={cpu_overhead:.2f}")
    if applied:
        assert r["speedup"] >= min_speedup, r
    assert r["model"]["ideal"] <= r["model"][f"channels:{workers}"] <= r["model"]["serial"], r


def load_then_run(name: str, mode: str, mix: str, *, num_keys: int, num_ops: int,
                  run_kind: str = "run_a", cfg_kw: dict | None = None,
                  config: StoreConfig | None = None, seed: int = 7) -> tuple[BenchResult, BenchResult, api.Engine]:
    kw = dict(cfg_kw or {})
    kw.setdefault("avg_kv_bytes", AVG_KV.get(mix, 250))
    kw.setdefault("dataset_keys", num_keys)
    cfg = config or scaled_config(mode, **kw)
    engine = open_engine(cfg)
    w = Workload("load_a", mix, num_keys=num_keys, num_ops=0, seed=seed)
    load_res = run_phase(f"{name}:load_a", mode, engine, w.load_ops())
    r = Workload(run_kind, mix, num_keys=num_keys, num_ops=num_ops, seed=seed)
    run_res = run_phase(f"{name}:{run_kind}", mode, engine, r.run_ops())
    return load_res, run_res, engine
