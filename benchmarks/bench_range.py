"""Hash vs range partitioning: scan locality and skew-driven rebalancing.

Sweeps :class:`repro.core.shard.ShardedStore` (crc32 hash routing) against
:class:`repro.core.range_shard.RangeShardedStore` (contiguous ranges) at equal
shard counts over YCSB runs C (zipfian point reads) and E (5% insert / 95%
short scans), reporting amplification, device-time throughput, and **scan
probes per scan** — the number of shards a scan has to consult.  Hash routing
destroys key locality, so every scan fans out to all N shards and k-way
merges; range partitioning touches only the shards overlapping the scanned
range (concatenation, already globally ordered).

A third variant starts the range store with the default uniform-byte
boundaries (all YCSB keys land in one shard) and lets the skew-driven
rebalancer discover the populated region: the zipfian hot-spot drives
``split()`` until the map adapts, which is the paper-adjacent Scavenger-style
"placement adapts to observed load" behavior named in the ROADMAP.

Claims asserted:
* hash scans probe exactly N shards per scan; range scans probe strictly
  fewer at every shard count (acceptance criterion for PR 2);
* the adaptive variant performs splits (the splitter fires on skew) and ends
  with more than one populated shard;
* at equal shard count, hash and range front-ends return identical scan
  results (partitioning is invisible to correctness);
* (PR 3) incremental migration bounds the per-tick stall: a background split
  throttled to ``migration_batch_keys`` keys per tick moves far fewer device
  bytes in its worst tick than the stop-the-world split's single stall, and
  the shard-metadata WAL's bytes are visible in amplification
  (``DeviceStats.meta_written``).
"""
from __future__ import annotations

import dataclasses
import time

import itertools

import repro.api as api
from .common import AVG_KV, C_BYTE, C_GC_LOOKUP, C_MERGE, C_OP, C_PROBE, CLOCK_HZ, open_engine, scaled_config
from .common import run_async_claim
from repro.core.ycsb import Workload, make_key, payload

MIX = "SD"
RUNS = ("run_c", "run_e")
BATCH = 64


def range_part(sample, n, **kw) -> api.PartitioningConfig:
    return api.PartitioningConfig.range_for_keys(sample, n, **kw)


def run_front_phase(name: str, engine: api.Engine, ops, batch: int = BATCH) -> dict:
    """One workload phase against a sharded engine; topology may change."""
    store = engine.store
    t0 = time.time()
    dev0 = store.device_stats()
    agg0 = store.aggregate_stats()
    scans0, probes0 = store.scans, store.scan_probes
    counts = api.execute(engine, ops, batch_size=batch)
    nops = sum(counts.values())
    dev = store.device_stats().delta(dev0)
    agg = store.aggregate_stats()
    app = agg.app_bytes - agg0.app_bytes
    cycles = (
        C_OP * nops
        + C_PROBE * (agg.index_probes - agg0.index_probes)
        + C_MERGE * (agg.entries_merged - agg0.entries_merged)
        + C_GC_LOOKUP * (agg.gc_lookups - agg0.gc_lookups)
        + C_BYTE * dev.total
    )
    # parallel-device model (ideal balance): aggregate bytes spread over N
    # devices at P4800X bandwidths; topology changes make per-device phase
    # deltas ill-defined, so the aggregate proxy is used for both systems
    dev_time = (dev.bytes_read / 2.4e9 + dev.bytes_written / 2.0e9) / max(1, store.num_shards)
    cpu_time = cycles / CLOCK_HZ / store.num_shards
    scans = store.scans - scans0
    return {
        "name": name,
        "ops": nops,
        "scans": scans,
        "amp": dev.total / max(app, 1),
        "kops": nops / max(dev_time, cpu_time, 1e-9) / 1e3,
        "probes_per_scan": (store.scan_probes - probes0) / max(scans, 1),
        "shards": store.num_shards,
        "wall_s": time.time() - t0,
        "cfg": engine.config.tag(),
    }


def _row(r: dict, system: str) -> str:
    us = 1e6 * r["wall_s"] / max(r["ops"], 1)
    return (
        f"{r['name']}/{system}@{r['cfg']},{us:.2f},"
        f"amp={r['amp']:.2f};kops={r['kops']:.1f};"
        f"scan_probes={r['probes_per_scan']:.2f};shards={r['shards']}"
    )


def main(emit, smoke: bool = False) -> None:
    keys = 2000 if smoke else 5000
    num_ops = keys // 2
    shard_counts = (2, 4) if smoke else (2, 4, 8)
    base_cfg = scaled_config("parallax", dataset_keys=keys, avg_kv_bytes=AVG_KV[MIX])
    load_w = Workload("load_e", MIX, num_keys=keys, num_ops=0)
    # runs insert ~5% new keys; pre-splitting over the loaded keyspace only
    sample = [make_key(i) for i in range(keys)]

    probes: dict[tuple[str, int, str], float] = {}
    for n in shard_counts:
        cfg = dataclasses.replace(
            base_cfg,
            l0_capacity=max(base_cfg.l0_capacity // n, 1 << 11),
            cache_bytes=base_cfg.cache_bytes // n,
            bloom_bits_per_key=10,
        )
        fronts = {
            "hash": open_engine(cfg, partitioning=f"hash:{n}"),
            # pre-split on the loaded keyspace; the rebalancer stays live so
            # run-phase skew can still move boundaries
            "range": open_engine(cfg, partitioning=range_part(sample, n)),
        }
        for system, engine in fronts.items():
            tag = f"{system}-x{n}"
            emit(_row(run_front_phase(f"range:{tag}:load_e", engine, load_w.load_ops()), tag))
            for run_kind in RUNS:
                w = Workload(run_kind, MIX, num_keys=keys, num_ops=num_ops)
                r = run_front_phase(f"range:{tag}:{run_kind}", engine, w.run_ops())
                emit(_row(r, tag))
                probes[(system, n, run_kind)] = r["probes_per_scan"]

        # claim 3: partitioning is invisible to results — both fronts agree
        h, rg = fronts["hash"], fronts["range"]
        assert h.scan(b"", 64) == rg.scan(b"", 64), n
        mid = make_key(keys // 2)
        assert h.scan(mid, 40) == rg.scan(mid, 40), n
        some = [make_key(i) for i in range(0, keys, max(1, keys // 50))]
        assert [h.get(k) for k in some] == [rg.get(k) for k in some], n

    # claim 1 (acceptance): hash scans fan out to every shard; range scans
    # probe only the range-overlapping shards — strictly fewer at equal count
    for n in shard_counts:
        assert probes[("hash", n, "run_e")] == n, (n, probes)
        assert probes[("range", n, "run_e")] < probes[("hash", n, "run_e")], (n, probes)
    emit(
        "range/claims,0,"
        + ";".join(
            f"runE_probes_x{n}_hash={probes[('hash', n, 'run_e')]:.2f}"
            f"_range={probes[('range', n, 'run_e')]:.2f}"
            for n in shard_counts
        )
    )

    # claim 4 (PR 3): throttled vs stop-the-world migration tail latency per
    # tick, and metadata-WAL amplification accounting
    def split_profile(batch_keys: int):
        cfgm = dataclasses.replace(base_cfg, bloom_bits_per_key=10)
        eng = open_engine(cfgm, partitioning=range_part(
            sample, 2, auto_rebalance=False, migration_batch_keys=batch_keys))
        api.execute(eng, load_w.load_ops(), batch_size=BATCH)
        stm = eng.store
        eng.flush_all()
        stm._split(0, background=True)
        tick_bytes = []
        while stm.migration is not None:
            before = stm.device_stats().total
            eng.migration_tick()
            tick_bytes.append(stm.device_stats().total - before)
        return stm, tick_bytes

    stw_store, stw_ticks = split_profile(1 << 30)  # stop-the-world: one stall
    thr_store, thr_ticks = split_profile(64)       # throttled background ticks
    assert len(stw_ticks) == 1, stw_ticks
    assert len(thr_ticks) >= 4, thr_ticks
    assert max(thr_ticks) < max(stw_ticks), (max(thr_ticks), max(stw_ticks))
    meta_bytes = thr_store.device_stats().meta_written
    assert meta_bytes > 0  # boundary/checkpoint records hit the device, and
    # the front-end aggregate really folds the metadata device in (shard
    # devices never write kind="meta", so this equality pins the override)
    assert meta_bytes == thr_store.meta_device.stats.meta_written
    assert thr_store.metalog.n_records > len(thr_ticks)  # ckpts + start/finish
    emit(
        f"range/migration,0,stw_tail_bytes={max(stw_ticks)};"
        f"throttled_tail_bytes={max(thr_ticks)};throttled_ticks={len(thr_ticks)};"
        f"meta_wal_bytes={meta_bytes};amp_incl_meta={thr_store.amplification():.2f}"
    )

    # claim 5 (PR 4, acceptance): async wall-clock throughput on the range
    # front-end — even with the per-batch policy sequence point (the range
    # store's rebalancer hook drains the pipeline every batch), within-batch
    # shard fan-out still overlaps the paced device time >= 2x with 4 workers
    # on run C.  8 shards, not 4: zipf point reads concentrate device time in
    # a hot shard, and LPT-packing 8 shard times onto 4 workers rides out the
    # skew (the modeled channels:4 ceiling shows the same effect)
    async_n, async_workers = 8, 4
    async_cfg = dataclasses.replace(
        base_cfg,
        l0_capacity=max(base_cfg.l0_capacity // async_n, 1 << 11),
        cache_bytes=base_cfg.cache_bytes // async_n,
        bloom_bits_per_key=10,
    )

    def make_async_engine(execution: api.ExecutionConfig) -> api.Engine:
        # a static balanced topology: the paced comparison measures execution
        # overlap, not rebalancing (bench claims 2/4 cover the policy)
        eng = open_engine(async_cfg,
                          partitioning=range_part(sample, async_n, auto_rebalance=False),
                          execution=execution)
        api.execute(eng, load_w.load_ops(), batch_size=BATCH)
        return eng

    run_c = lambda: Workload("run_c", MIX, num_keys=keys, num_ops=num_ops).run_ops()
    run_async_claim(emit, "range:async",
                    f"range:async:run_c/range-x{async_n}w{async_workers}",
                    make_async_engine, run_c, workers=async_workers, batch=BATCH)

    # claim 2: the skew-driven splitter adapts a degenerate map — start with
    # uniform byte boundaries (all YCSB keys in one shard) and let run E's
    # zipfian stream drive splits
    cfg = dataclasses.replace(base_cfg, bloom_bits_per_key=10)
    adaptive_eng = open_engine(cfg, partitioning=api.PartitioningConfig(
        scheme="range", shards=4,
        rebalance_window=max(256, num_ops // 8), max_shards=16,
    ))
    adaptive = adaptive_eng.store
    api.execute(adaptive_eng, load_w.load_ops(), batch_size=BATCH)
    w = Workload("run_e", MIX, num_keys=keys, num_ops=num_ops)
    api.execute(adaptive_eng, w.run_ops(), batch_size=BATCH)
    populated = sum(
        1 for i, s in enumerate(adaptive.shards) if s.live_keys_in(*adaptive.bounds(i))
    )
    assert adaptive.splits > 0, adaptive.checkpoint_stats()
    assert populated > 1, (populated, adaptive.splits, adaptive.merges)
    emit(
        f"range/adaptive,0,splits={adaptive.splits};merges={adaptive.merges};"
        f"migrated={adaptive.migrated_keys};shards={adaptive.num_shards};"
        f"populated={populated}"
    )

    # claim 6 (PR 5): the lazy iterator serves run E's scans without
    # regressing the eager path — identical rows, probes/op and device time
    # no worse (the cursor pulls exactly the rows the scan returns, shard by
    # shard, instead of materializing per-shard lists)
    iter_part = range_part(sample, 4, auto_rebalance=False)
    iter_cfg = dataclasses.replace(base_cfg, bloom_bits_per_key=10)
    engines = {}
    for variant in ("eager", "iter"):
        eng = open_engine(iter_cfg, partitioning=iter_part)
        api.execute(eng, load_w.load_ops(), batch_size=BATCH)
        engines[variant] = eng
    scan_w = Workload("run_e", MIX, num_keys=keys, num_ops=min(num_ops, 400))
    results = {v: [] for v in engines}
    stats = {}
    for variant, eng in engines.items():
        store = eng.store
        dev0 = store.device_stats()
        # the Device model's own bandwidths turn bytes into time (topology is
        # static here, so the per-store sum delta is well-defined)
        time0 = store.device_time("serial")
        scans0, probes0 = store.scans, store.scan_probes
        for op in scan_w.run_ops():
            if op.kind == "insert":
                eng.put(op.key, payload(op.value_size))
            elif variant == "eager":
                results[variant].append(eng.scan(op.key, op.scan_len))
            else:
                cursor = eng.iterator(op.key)
                results[variant].append(
                    list(itertools.islice(iter(cursor), op.scan_len)))
        dev = store.device_stats().delta(dev0)
        stats[variant] = {
            "probes_per_scan": (store.scan_probes - probes0) / max(store.scans - scans0, 1),
            "dev_time": store.device_time("serial") - time0,
            "dev_bytes": dev.total,
        }
    assert results["iter"] == results["eager"], "iterator rows diverge from eager scan"
    assert stats["iter"]["probes_per_scan"] <= stats["eager"]["probes_per_scan"], stats
    assert stats["iter"]["dev_time"] <= stats["eager"]["dev_time"] * 1.0001, stats
    emit(
        f"range:iter_vs_scan:run_e/range-x4@{engines['iter'].config.tag()},0,"
        f"iter_probes={stats['iter']['probes_per_scan']:.2f};"
        f"eager_probes={stats['eager']['probes_per_scan']:.2f};"
        f"iter_dev_us={stats['iter']['dev_time'] * 1e6:.1f};"
        f"eager_dev_us={stats['eager']['dev_time'] * 1e6:.1f};"
        f"iter_dev_bytes={stats['iter']['dev_bytes']}"
    )
