"""Lifetime-aware placement: GC vs I/O amplification on update-heavy runs.

The paper's triage is static and its Large log pays full §4 GC regardless of
how hot its keys are.  :mod:`repro.core.lifetime` splits the value log by
observed update lifetime (HashKV-style grouping driven by an update-distance
sketch) and adapts the medium/large cutoff per store.  This bench runs the
three placements over the *same* skewed-update YCSB A phase at an equal
space budget (identical L0/cache/segment config; final on-device footprint
asserted within a narrow band):

* ``lifetime`` — parallax + ``LifetimeConfig()`` defaults: hot values land in
  the short log and are swept once half dead (hot churn gets a segment there
  within ~one update cycle, so relocation is nearly free), cold values ride
  the long log to a lazier threshold than the static anchor;
* ``parallax`` — the paper's static single-log config (``gc_threshold``);
* ``blobdb``  — the all-log config (scan-fraction GC, Fig. 1's loser).

Claims asserted (the tentpole's acceptance gate):
* on the update-heavy run, lifetime placement *strictly* improves total
  amplification (device bytes / app bytes, write+GC) over both the static
  parallax config and the all-log config;
* it does so without losing device-time throughput (modeled kops no worse —
  the amplification win is not bought with a slower device schedule);
* at equal space budget: the lifetime store's final footprint stays within
  10%% of the static config's (laziness on the long log must not masquerade
  as an amplification win by hoarding garbage);
* the split actually engages: short-log writes, per-class GC reads and at
  least one adaptive cutoff cutover are all observed (reported per-class in
  the ``lifetime/classes`` row so the baseline gates them).
"""
from __future__ import annotations

import dataclasses

from .common import AVG_KV, open_engine, run_phase, scaled_config
from repro.core import LifetimeConfig
from repro.core.ycsb import Workload

MIX = "L"  # value-log-resident payloads: placement is the whole story
HOT_FRAC = 0.6  # of updates, redirected to a small recirculating hot set
HOT_KEYS = 64


def main(emit, smoke: bool = False) -> None:
    keys = 2000 if smoke else 4000
    num_ops = keys
    run_res: dict[str, object] = {}
    stores: dict[str, object] = {}
    for system, mode, lifetime in [
        ("lifetime", "parallax", LifetimeConfig()),
        ("parallax", "parallax", None),
        ("blobdb", "blobdb", None),
    ]:
        cfg = scaled_config(mode, dataset_keys=keys, avg_kv_bytes=AVG_KV[MIX])
        cfg = dataclasses.replace(cfg, lifetime=lifetime)
        engine = open_engine(cfg)
        load = Workload("load_a", MIX, num_keys=keys, num_ops=0)
        emit(run_phase("lifetime:load_a", system, engine, load.load_ops()).row())
        run = Workload("run_a", MIX, num_keys=keys, num_ops=num_ops,
                       hot_update_frac=HOT_FRAC, hot_update_keys=HOT_KEYS)
        res = run_phase("lifetime:run_a", system, engine, run.run_ops())
        emit(res.row())
        run_res[system] = res
        stores[system] = engine.store

    lt = stores["lifetime"]
    d = lt.device.stats
    # per-class GC traffic + adaptation activity: deterministic byte
    # accounting, gated by the baseline like any other derived field
    emit(
        f"lifetime/classes@{run_res['lifetime'].cfg},0,"
        f"gc_short_read={d.gc_short_read};short_log_written={d.short_log_written};"
        f"gc_long_read={d.gc_read - d.gc_short_read};"
        f"class_migrations={lt.stats.class_migrations};"
        f"cutoff_adaptations={lt.stats.cutoff_adaptations};"
        f"t_ml={lt.policy.t_ml:.4f}"
    )

    amp = {s: run_res[s].amplification for s in run_res}
    kops = {s: run_res[s].kops for s in run_res}
    space = {s: st.space_bytes() for s, st in stores.items()}
    # claim 1: strict total-amplification win on the update-heavy run
    assert amp["lifetime"] < amp["parallax"], amp
    assert amp["lifetime"] < amp["blobdb"], amp
    # claim 2: not bought with device time — modeled throughput no worse
    assert kops["lifetime"] >= kops["parallax"], kops
    assert kops["lifetime"] >= kops["blobdb"], kops
    # claim 3: equal space budget — the lazy long log must not hoard garbage
    assert space["lifetime"] <= 1.10 * space["parallax"], space
    # claim 4: the machinery engaged (a win with the split idle would mean
    # the comparison measured something else)
    assert d.short_log_written > 0 and d.gc_short_read > 0
    assert lt.stats.cutoff_adaptations >= 1
    emit(
        "lifetime/claims,0,"
        f"amp_lifetime={amp['lifetime']:.2f};amp_parallax={amp['parallax']:.2f};"
        f"amp_blobdb={amp['blobdb']:.2f};"
        f"space_vs_parallax={space['lifetime'] / space['parallax']:.3f};"
        f"kops_vs_parallax={kops['lifetime'] / kops['parallax']:.2f}"
    )
