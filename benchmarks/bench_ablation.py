"""Paper Fig. 7: is the medium category worth it?  Parallax vs Parallax-MS
(mediums treated as small: T_SM=T_ML=0.02) vs Parallax-ML (mediums treated as
large: T_SM=T_ML=0.2) on Run A for MD and LD mixes."""
from __future__ import annotations

from .common import load_then_run

VARIANTS = {
    "parallax": dict(t_sm=0.2, t_ml=0.02),
    "parallax-MS": dict(t_sm=0.02, t_ml=0.02),
    "parallax-ML": dict(t_sm=0.2, t_ml=0.2),
}
KEYS = 10_000


def main(emit) -> None:
    amp: dict[tuple[str, str], float] = {}
    for mix in ("MD", "LD"):
        for name, thr in VARIANTS.items():
            load, run, _ = load_then_run(
                f"fig7:{mix}", name, mix,
                num_keys=KEYS, num_ops=KEYS,
                cfg_kw={"dataset_keys": KEYS, **thr},
            )
            emit(run.row())
            amp[(mix, name)] = run.amplification
    # paper: the 3-category Parallax improves on both 2-category variants,
    # most visibly on MD
    assert amp[("MD", "parallax")] < amp[("MD", "parallax-MS")], amp
    assert amp[("MD", "parallax")] < amp[("MD", "parallax-ML")], amp
    ms = amp[("MD", "parallax-MS")] / amp[("MD", "parallax")]
    ml = amp[("MD", "parallax-ML")] / amp[("MD", "parallax")]
    emit(f"fig7/claims,0,MD_amp_gain_vs_MS={ms:.2f}x;vs_ML={ml:.2f}x")
