"""Roofline table from the recorded dry-run artifacts (results/*.json).

Prints one row per (arch, shape): the three terms, the bottleneck, and
MODEL_FLOPS/HLO_FLOPs (useful-compute ratio).  This is §Roofline's source."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def main(emit) -> None:
    path = os.path.join(RESULTS, "dryrun_single.json")
    if not os.path.exists(path):
        emit("roofline/missing,0,run `python -m repro.launch.dryrun --all --mesh single --out results/dryrun_single.json` first")
        return
    rows = json.load(open(path))
    worst = None
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok":
            emit(f"roofline:{r['arch']}:{r['shape']},0,status={r['status']}")
            continue
        rl = r["roofline"]
        dom = rl["bottleneck"]
        dom_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        frac = rl["compute_s"] / max(dom_s, 1e-12)  # compute roofline fraction
        emit(
            f"roofline:{r['arch']}:{r['shape']},{dom_s*1e6:.0f},"
            f"bottleneck={dom};compute_s={rl['compute_s']:.4f};memory_s={rl['memory_s']:.4f};"
            f"collective_s={rl['collective_s']:.4f};useful_ratio={rl['useful_ratio']:.3f};"
            f"roofline_frac={frac:.3f}"
        )
        if r["shape"] == "train_4k" and (worst is None or frac < worst[1]):
            worst = (r["arch"], frac)
    if worst:
        emit(f"roofline/worst_train_cell,0,arch={worst[0]};compute_fraction={worst[1]:.3f}")
