"""Elastic rescale: online 4→8 under sustained YCSB C vs stop-the-world.

The topology API (PR 9) lets a running fleet grow N→M with the per-leg
migrations metered by a shared device-byte budget per tick.  The pause a
client sees is bounded by the *worst single tick* of foreground device
traffic — the stop-the-world alternative charges the entire remap in one
burst.  This bench measures both over the same loaded keyspace:

* ``stw``    — ``rescale(8)`` unthrottled, drained with no serving traffic
  interleaved: its total remap bytes are the one-burst pause cost;
* ``online`` — ``rescale(8, budget=remap/16)`` with YCSB run C chunks served
  between ticks; per-tick fleet device bytes are sampled around each
  ``migration_tick`` alone, so serving reads don't pollute the pause proxy.

Claims asserted (the ISSUE's acceptance gate):
* worst-tick foreground device bytes ≤ 25%% of the stop-the-world remap;
* serving genuinely overlapped the rescale (reads landed while legs were
  in flight) and every key remained reachable afterwards;
* both paths converge to the same 8-shard topology with keys moved.
"""
from __future__ import annotations

import time

import repro.api as api
from repro.core.ycsb import Workload

from .common import AVG_KV, open_engine, scaled_config, tagged

MIX = "SD"
FROM_SHARDS = 4
TO_SHARDS = 8
BUDGET_DIV = 16   # online budget = stop-the-world remap bytes / 16
CHUNK = 100       # run C ops served between consecutive ticks
GATE = 0.25       # worst online tick must stay under this fraction of stw


def _open(keys: int) -> api.Engine:
    cfg = scaled_config("parallax", dataset_keys=keys, avg_kv_bytes=AVG_KV[MIX])
    return open_engine(
        cfg, partitioning=api.PartitioningConfig.parse(f"hash:{FROM_SHARDS}"))


def _load(db: api.Engine, keys: int) -> None:
    load = Workload("load_a", MIX, num_keys=keys, num_ops=0)
    api.execute(db, load.load_ops())
    db.store.flush_all()


def main(emit, smoke: bool = False) -> None:
    keys = 1500 if smoke else 6000
    num_ops = keys

    # --- stop-the-world: unthrottled remap, nothing served in between -----
    stw = _open(keys)
    _load(stw, keys)
    t0 = time.time()
    b0 = stw.store._fleet_bytes()
    stw.rescale(TO_SHARDS)
    ticks = 0
    while stw.topology()["rescale"] is not None:
        stw.migration_tick()
        ticks += 1
    stw_bytes = stw.store._fleet_bytes() - b0
    emit(f"{tagged('elastic:rescale/stw', stw)},"
         f"{1e6 * (time.time() - t0):.0f},"
         f"remap_bytes={stw_bytes};ticks={ticks};"
         f"keys_moved={stw.store.migrated_keys}")
    assert stw.topology()["shards"] == TO_SHARDS
    assert stw.store.migrated_keys > 0
    stw.close()

    # --- online: budgeted legs with YCSB run C served between ticks -------
    db = _open(keys)
    _load(db, keys)
    ops = list(Workload("run_c", MIX, num_keys=keys, num_ops=num_ops).run_ops())
    budget = max(1, stw_bytes // BUDGET_DIV)
    t0 = time.time()
    db.rescale(TO_SHARDS, budget=budget)
    worst_tick = 0
    online_ticks = 0
    served_in_flight = 0
    served = 0
    while db.topology()["rescale"] is not None or served < len(ops):
        if served < len(ops):
            chunk = ops[served:served + CHUNK]
            if db.topology()["rescale"] is not None:
                served_in_flight += len(chunk)
            api.execute(db, chunk)
            served += len(chunk)
        if db.topology()["rescale"] is not None:
            b0 = db.store._fleet_bytes()
            db.migration_tick()
            worst_tick = max(worst_tick, db.store._fleet_bytes() - b0)
            online_ticks += 1
    worst_frac = worst_tick / max(stw_bytes, 1)
    emit(f"{tagged('elastic:rescale/online', db)},"
         f"{1e6 * (time.time() - t0):.0f},"
         f"budget={budget};worst_tick={worst_tick};ticks={online_ticks};"
         f"keys_moved={db.store.migrated_keys};served_in_flight={served_in_flight}")

    # claim 1: the per-tick pause proxy stays under the gate fraction
    assert worst_tick <= GATE * stw_bytes, (worst_tick, stw_bytes)
    # claim 2: serving genuinely overlapped the in-flight legs
    assert served_in_flight > 0 and online_ticks > 1
    # claim 3: same destination topology, every key still reachable
    topo = db.topology()
    assert topo["shards"] == TO_SHARDS and topo["rescale"] is None
    assert db.store.migrated_keys > 0
    assert len(db.scan(b"", keys + 8)) == keys
    emit(f"elastic/claims,0,"
         f"worst_frac={worst_frac:.4f};gate={GATE};served_ops={served};"
         f"shards={topo['shards']}")
    db.close()
