"""Kernel microbenchmarks: XLA-oracle wall time (CPU) + interpret-mode
validation of each Pallas kernel at bench shapes.  On-TPU timing is the
deploy-time path; here the derived column reports correctness deltas and
achieved CPU-oracle throughput for regression tracking."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.merge_runs.kernel import merge_runs_pallas
from repro.kernels.merge_runs.ref import merge_runs_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def timeit(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps, out


def main(emit) -> None:
    key = jax.random.PRNGKey(0)
    # flash attention: serving-like shape
    b, s, h, kh, d = 1, 1024, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32)
    tref, ref = timeit(jax.jit(flash_attention_ref), q, k, v)
    out = flash_attention_pallas(q, k, v, block_q=128, block_k=128, interpret=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    flops = 4 * b * s * s // 2 * h * d
    emit(f"kernel:flash_attn_b{b}s{s}h{h}d{d},{tref*1e6:.1f},gflops_oracle={flops/tref/1e9:.1f};pallas_err={err:.1e}")

    # ssd scan: mamba2-like head block
    b, s, hh, p, g, n, L = 2, 2048, 8, 64, 1, 64, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, hh, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, hh))) * 0.5
    a = -jnp.exp(jax.random.normal(ks[2], (hh,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    f = jax.jit(lambda *a_: ssd_scan_ref(*a_, chunk=L))
    tref, (yref, sref) = timeit(f, x, dt, a, bm, cm)
    ypl, spl = ssd_scan_pallas(x[:, :256], dt[:, :256], a, bm[:, :256], cm[:, :256], chunk=64, interpret=True)
    yr2, _ = ssd_scan_ref(x[:, :256], dt[:, :256], a, bm[:, :256], cm[:, :256], chunk=64)
    err = float(jnp.max(jnp.abs(ypl - yr2)))
    emit(f"kernel:ssd_scan_b{b}s{s}h{hh}p{p}n{n},{tref*1e6:.1f},tokens_per_s_oracle={b*s/tref:.0f};pallas_err={err:.1e}")

    # merge runs: compaction tile merge
    g_, t_ = 64, 512
    rng = np.random.default_rng(0)
    ak = jnp.asarray(np.sort(rng.integers(0, 1 << 30, (g_, t_)).astype(np.int32), axis=1))
    bk = jnp.asarray(np.sort(rng.integers(0, 1 << 30, (g_, t_)).astype(np.int32), axis=1))
    av = jnp.asarray(rng.integers(0, 1 << 30, (g_, t_)).astype(np.int32))
    bv = jnp.asarray(rng.integers(0, 1 << 30, (g_, t_)).astype(np.int32))
    tref, refout = timeit(jax.jit(merge_runs_ref), ak, bk, av, bv)
    ok, ov = merge_runs_pallas(ak[:8], bk[:8], av[:8], bv[:8], interpret=True)
    rk, _ = merge_runs_ref(ak[:8], bk[:8], av[:8], bv[:8])
    exact = bool(jnp.all(ok == rk))
    keys_per_s = g_ * 2 * t_ / tref
    emit(f"kernel:merge_runs_g{g_}t{t_},{tref*1e6:.1f},keys_per_s_oracle={keys_per_s/1e6:.1f}M;pallas_exact={exact}")
