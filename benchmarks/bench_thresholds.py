"""Beyond-paper ablation: sweep the classification thresholds T_SM / T_ML.

The paper fixes T_SM=0.2, T_ML=0.02 and explicitly leaves "examining these
thresholds in more detail" to future work (§2.2).  This sweep runs Run A over
the MD mix for a grid of thresholds and reports amplification — validating
that the paper's chosen operating point sits on the flat bottom of the basin
(small deviations don't help), while collapsing either threshold (the MS/ML
degenerate corners) hurts."""
from __future__ import annotations

from .common import load_then_run

KEYS = 8_000


def main(emit) -> None:
    grid = [
        (0.2, 0.02),   # paper operating point
        (0.3, 0.02),
        (0.12, 0.02),
        (0.2, 0.05),
        (0.2, 0.008),
        (0.3, 0.05),
        (0.02, 0.02),  # degenerate: no medium class (MS corner)
        (0.2, 0.2),    # degenerate: no medium class (ML corner)
    ]
    results = {}
    for t_sm, t_ml in grid:
        _, run, _ = load_then_run(
            f"thresholds:tsm{t_sm}_tml{t_ml}", "parallax", "MD",
            num_keys=KEYS, num_ops=KEYS,
            cfg_kw={"t_sm": t_sm, "t_ml": t_ml},
        )
        results[(t_sm, t_ml)] = run.amplification
        emit(run.row())
    paper = results[(0.2, 0.02)]
    best = min(results.values())
    # the paper's point is within 15% of the best grid point, and both
    # degenerate corners are worse than the paper's choice
    assert paper <= best * 1.15, (paper, best, results)
    assert results[(0.02, 0.02)] > paper * 0.98, results
    assert results[(0.2, 0.2)] > paper * 0.98, results
    emit(
        f"thresholds/claims,0,paper_amp={paper:.2f};grid_best={best:.2f};"
        f"paper_within={paper/best:.3f}x_of_best"
    )
