"""Paper Fig. 1: I/O amplification for small KV inserts — BlobDB with GC,
BlobDB without GC, RocksDB (and Parallax for reference).

Expected trend (paper: 27.4 / 2.1 / 17.4): KV separation without GC is far
cheaper than in-place, but GC *identification* alone (pure-insert load!)
pushes BlobDB past RocksDB."""
from __future__ import annotations

from .common import open_engine, run_phase, scaled_config
from repro.core.ycsb import Workload

KEYS = 30_000


def main(emit) -> None:
    results = {}
    for system, mode, auto_gc in [
        ("blobdb_gc", "blobdb", True),
        ("blobdb_nogc", "blobdb", False),
        ("rocksdb", "rocksdb", True),
        ("parallax", "parallax", True),
    ]:
        cfg = scaled_config(mode, dataset_keys=KEYS, auto_gc=auto_gc, avg_kv_bytes=33)
        engine = open_engine(cfg)
        w = Workload("load_a", "S", num_keys=KEYS, num_ops=0)
        res = run_phase("fig1:small_load", system, engine, w.load_ops())
        results[system] = res.amplification
        emit(res.row())
    # paper claims: blobdb_gc > rocksdb > blobdb_nogc; >13x gap with/without GC
    assert results["blobdb_gc"] > results["rocksdb"], results
    assert results["blobdb_gc"] / results["blobdb_nogc"] > 3.0, results
    emit(
        f"fig1/claims,0,blobdb_gc_over_nogc={results['blobdb_gc']/results['blobdb_nogc']:.1f}x;"
        f"blobdb_gc_vs_rocksdb={results['blobdb_gc']/results['rocksdb']:.2f}x"
    )
