"""Minimal batched serving engine: admit -> prefill -> decode loop.

Uses the model's prefill/decode steps and the HybridCacheManager for
placement decisions.  Single-host reference implementation (the dry-run
serve_step is the scale path); drives the examples and serving tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import get_model
from repro.models.config import ArchConfig
from .cache_manager import CacheConfig, HybridCacheManager


@dataclasses.dataclass
class Request:
    seq_id: int
    prompt: jax.Array          # (S,) int32
    max_new_tokens: int = 16
    output: list[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_len: int = 512, batch_size: int = 4):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_len = max_len
        self.batch_size = batch_size
        bytes_per_token = (
            2 * max(cfg.num_kv_heads, 1) * cfg.resolved_head_dim * 2 * cfg.num_layers
        )
        self.cache_mgr = HybridCacheManager(CacheConfig(
            bytes_per_token=bytes_per_token, slab_tokens=min(max_len // 2, 512),
            arena_tokens=max_len * batch_size,
        ))
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(cfg, p, c, t)
        )

    def run_batch(self, requests: list[Request]) -> list[Request]:
        """Prefill a uniform batch then greedy-decode to completion."""
        assert len(requests) <= self.batch_size
        for r in requests:
            alloc = self.cache_mgr.admit(r.seq_id, len(r.prompt) + r.max_new_tokens)
            if alloc is None:
                raise RuntimeError("admission control: cache pool exhausted")
        prompts = jnp.stack([r.prompt for r in requests])
        batch = {"tokens": prompts}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (len(requests), self.cfg.num_patches, self.cfg.d_model), jnp.float32
            )
        if self.cfg.family == "encdec":
            batch["frame_embeds"] = jnp.zeros(
                (len(requests), self.cfg.encoder_frames, self.cfg.d_model), jnp.float32
            )
        logits, cache = self.model.prefill(self.cfg, self.params, batch, self.max_len)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        steps = max(r.max_new_tokens for r in requests)
        for step in range(steps):
            for i, r in enumerate(requests):
                if len(r.output) < r.max_new_tokens:
                    r.output.append(int(tok[i, 0]))
                    self.cache_mgr.extend(r.seq_id, len(r.prompt) + len(r.output))
            if all(len(r.output) >= r.max_new_tokens for r in requests):
                break
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for r in requests:
            self.cache_mgr.release(r.seq_id)
        return requests
