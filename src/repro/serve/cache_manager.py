"""Hybrid KV-cache placement for serving (the paper's idea, HBM edition).

A serving engine's KV-cache pool has the paper's exact tension: *paged*
(log-structured) placement gives allocation flexibility but needs free-list
maintenance and fragmentation GC; *contiguous in-place* slabs are scan/attend
-friendly but waste reserved space.  We classify sequences by context length
with the same thresholds-on-p structure (p = metadata / (metadata + bytes)):

* **short** contexts (p > T_SM): a fixed contiguous slab — block-table
  overhead would rival the payload (the paper's small-KV argument).
* **long** contexts (p < T_ML): the paged pool — pages reclaimed by
  free-list GC on sequence completion (the Large-log economy).
* **medium** contexts: a *transient arena* attached to the decode batch and
  reclaimed **wholesale** when the batch generation completes — no per-page
  GC walk (the transient-log economy).

The manager does placement/accounting; attention kernels consume the block
tables.  Byte accounting mirrors repro.core.io so EXPERIMENTS.md can compare
hybrid vs all-paged vs all-slab management overhead.
"""
from __future__ import annotations

import dataclasses

PAGE = 16  # tokens per page (paged pool granularity)
BLOCK_TABLE_ENTRY = 4  # bytes per page pointer
SLAB_RESERVE = 512  # tokens reserved per slab slot


@dataclasses.dataclass
class SeqAlloc:
    seq_id: int
    kind: str             # slab | transient | paged
    start: int = 0        # slab slot or arena offset (tokens)
    pages: list[int] = dataclasses.field(default_factory=list)
    length: int = 0


@dataclasses.dataclass
class CacheConfig:
    bytes_per_token: int          # 2 * K * hd * dtype * layers (model-derived)
    slab_slots: int = 64
    slab_tokens: int = SLAB_RESERVE
    arena_tokens: int = 65536
    pool_pages: int = 16384
    t_sm: float = 0.2
    t_ml: float = 0.02

    def classify(self, expected_len: int) -> str:
        meta = BLOCK_TABLE_ENTRY * max(1, expected_len // PAGE)
        payload = expected_len * self.bytes_per_token
        p = meta / (meta + payload)
        # short contexts: meta dominates relative to a slab reservation
        if expected_len <= self.slab_tokens:
            return "slab"
        if expected_len >= self.arena_tokens:
            return "paged"
        return "transient"


class HybridCacheManager:
    def __init__(self, cfg: CacheConfig):
        self.cfg = cfg
        self._free_slabs = list(range(cfg.slab_slots))
        self._arena_used = 0
        self._arena_seqs: set[int] = set()
        self._free_pages = list(range(cfg.pool_pages))
        self.allocs: dict[int, SeqAlloc] = {}
        # accounting
        self.gc_page_ops = 0
        self.wholesale_reclaims = 0
        self.bytes_reserved = 0
        self.bytes_used = 0

    # ------------------------------------------------------------------ admit
    def admit(self, seq_id: int, expected_len: int) -> SeqAlloc | None:
        kind = self.cfg.classify(expected_len)
        if kind == "slab":
            if not self._free_slabs:
                kind = "transient"  # overflow path
            else:
                slot = self._free_slabs.pop()
                a = SeqAlloc(seq_id, "slab", start=slot)
                self.bytes_reserved += self.cfg.slab_tokens * self.cfg.bytes_per_token
                self.allocs[seq_id] = a
                return a
        if kind == "transient":
            if self._arena_used + expected_len > self.cfg.arena_tokens:
                kind = "paged"      # arena full: spill to the pool
            else:
                a = SeqAlloc(seq_id, "transient", start=self._arena_used)
                self._arena_used += expected_len
                self._arena_seqs.add(seq_id)
                self.bytes_reserved += expected_len * self.cfg.bytes_per_token
                self.allocs[seq_id] = a
                return a
        npages = -(-expected_len // PAGE)
        if len(self._free_pages) < npages:
            return None  # admission control: no capacity
        a = SeqAlloc(seq_id, "paged", pages=[self._free_pages.pop() for _ in range(npages)])
        self.bytes_reserved += npages * PAGE * self.cfg.bytes_per_token
        self.allocs[seq_id] = a
        return a

    def extend(self, seq_id: int, new_len: int) -> bool:
        """Grow a sequence during decode; paged seqs take pages on demand."""
        a = self.allocs[seq_id]
        a.length = new_len
        self.bytes_used = max(self.bytes_used, new_len * self.cfg.bytes_per_token)
        if a.kind == "paged" and new_len > len(a.pages) * PAGE:
            if not self._free_pages:
                return False
            a.pages.append(self._free_pages.pop())
        if a.kind == "slab" and new_len > self.cfg.slab_tokens:
            # slab overflow: promote to paged (rare by classification)
            npages = -(-new_len // PAGE)
            if len(self._free_pages) < npages:
                return False
            self._free_slabs.append(a.start)
            a.kind, a.pages = "paged", [self._free_pages.pop() for _ in range(npages)]
        return True

    # ---------------------------------------------------------------- release
    def release(self, seq_id: int) -> None:
        a = self.allocs.pop(seq_id)
        if a.kind == "slab":
            self._free_slabs.append(a.start)
        elif a.kind == "paged":
            # free-list GC: per-page reclamation (the Large-log economy)
            self.gc_page_ops += len(a.pages)
            self._free_pages.extend(a.pages)
        else:
            self._arena_seqs.discard(seq_id)
            if not self._arena_seqs:
                # wholesale arena reset — the transient-log zero-GC reclaim
                self._arena_used = 0
                self.wholesale_reclaims += 1

    # ------------------------------------------------------------------ stats
    def stats(self) -> dict:
        return {
            "free_slabs": len(self._free_slabs),
            "free_pages": len(self._free_pages),
            "arena_used_tokens": self._arena_used,
            "gc_page_ops": self.gc_page_ops,
            "wholesale_reclaims": self.wholesale_reclaims,
            "active": len(self.allocs),
        }
