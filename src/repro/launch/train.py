"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

On this CPU container it runs reduced configs end-to-end (data pipeline ->
sharded step -> LSM checkpointing -> resume).  On a real TPU slice the same
entry point runs the full config: the mesh comes from ``--mesh production``
(16x16 per pod) and jax.distributed handles multi-host.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, host_batch
from repro.elastic.remap import StragglerPolicy
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import get_model
from repro.optim import adamw
from repro.sharding import rules
from repro.train.step import make_train_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced config (CPU); full config needs TPUs")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--mesh", choices=["host", "production", "multipod"], default="host")
    ap.add_argument("--layout", choices=list(rules.LAYOUTS), default="baseline")
    ap.add_argument("--grad-dtype", choices=["float32", "bfloat16"], default="float32")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    cfg = rules.pad_config_for_mesh(cfg, mesh, args.layout)

    model = get_model(cfg)
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                             total_steps=args.steps)
    step_fn = jax.jit(make_train_fn(cfg, ocfg, grad_dtype=args.grad_dtype),
                      donate_argnums=(0, 1))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch)
    mgr = CheckpointManager(args.ckpt_dir, consolidate_every=4) if args.ckpt_dir else None
    straggler = StragglerPolicy()

    start = 0
    if args.resume and mgr is not None:
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            {"params": params, "opt": opt})
        restored, start = mgr.restore(like)
        params, opt = restored["params"], restored["opt"]
        print(f"resumed at step {start}")

    n = sum(int(jnp.size(x)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M mesh={dict(mesh.shape)} layout={args.layout}")
    for step in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in host_batch(cfg, dcfg, step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        straggler.observe(jax.process_index(), time.time() - t0)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} {(time.time()-t0)*1e3:.0f}ms", flush=True)
        if mgr is not None and step and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt})
    print("done")


if __name__ == "__main__":
    main()
