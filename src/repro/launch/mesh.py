"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run entry
point sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before
any jax import to materialize the placeholder devices.

Mesh shapes (TPU v5e pods):
    single-pod:  (16, 16)      axes ("data", "model")   — 256 chips
    multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many devices this host has (tests/examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
