"""Serving launcher: `python -m repro.launch.serve --arch <id> [...]`.

Runs the batched engine with hybrid KV-cache placement on synthetic request
streams and reports throughput + cache-manager placement stats.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import get_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    model = get_model(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=args.max_len, batch_size=args.batch_size)
    rng = np.random.default_rng(0)

    t0 = time.time()
    total = 0
    sid = 0
    for b in range(args.batches):
        reqs = []
        for _ in range(args.batch_size):
            prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, args.prompt_len), jnp.int32)
            reqs.append(Request(sid, prompt, max_new_tokens=args.new_tokens))
            sid += 1
        done = eng.run_batch(reqs)
        total += sum(len(r.output) for r in done)
        print(f"batch {b}: generated {sum(len(r.output) for r in done)} tokens; "
              f"cache={eng.cache_mgr.stats()}", flush=True)
    dt = time.time() - t0
    print(f"throughput: {total/dt:.1f} tok/s ({total} tokens in {dt:.1f}s)")


if __name__ == "__main__":
    main()
