"""Roofline-term extraction for the dry-run cells.

Three terms per (arch, shape, mesh), all in seconds (TPU v5e constants):

    compute    = FLOPs_per_device / 197e12        (bf16 MXU peak)
    memory     = bytes_per_device / 819e9         (HBM bandwidth)
    collective = wire_bytes_per_device / 50e9     (ICI per-link)

Methodology note (validated in EXPERIMENTS.md §Dry-run): the models scan over
stacked layers for compile speed, and XLA *CPU* ``cost_analysis`` does not
multiply ``while``-body costs by trip count — its flops/bytes undercount
layer work by ~num_layers and its collective set likewise.  The headline
terms are therefore **analytic** (formulas below, standard roofline
practice), while the raw ``cost_analysis`` numbers and the HLO-parsed
collective census are recorded alongside as compiler-side evidence.

Analytic model (per device; D devices, dp = data-parallel, tp = model axis):

* FLOPs: matmul term ``m·N_active·T`` with m = 2 (inference fwd), 6 (train),
  8 (train+remat); attention ``a·2·B·H·S²·hd`` per causal layer (a = 1 fwd,
  3 train, 4 train+remat; x2 for non-causal); SSD chunk term
  ``2·B·S·H·(Lc·(N+P) + 2·N·P)``; decode attention ``4·B·H·S_cache·hd``/layer.
* HBM bytes: optimizer state streams (8 fp32 arrays r/w) for train; one bf16
  weight pass per fwd/bwd/remat; activation traffic ``k·L·(B/dp)·S·d·2`` with
  k = 16 train / 8 prefill; KV-cache read+slot-write for decode; SSD states.
* Collective wire bytes: dp-axis gradient reduce-scatter + FSDP all-gathers
  (train), tp-axis per-layer activation all-reduces (2/layer fwd, 6 with
  bwd+remat), ring factors (k-1)/k, all-reduce x2.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<single>\w+\[[^\]]*\]))?\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ALT = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_ALT.search(line)
    if m:  # iota format [N,M]<=[...]: N groups of M
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_op: dict = dataclasses.field(default_factory=dict)
    count: int = 0


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device on-wire bytes parsed from a *partitioned* HLO module.

    NOTE: collectives inside ``while`` bodies are counted once (see module
    docstring); recorded as compiler-side evidence next to the analytic term.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m or line.startswith("//"):
            continue
        op = m.group("op")
        head = line.split("=", 1)
        if len(head) < 2:
            continue
        result_text = head[1].split(op)[0]
        nbytes = _shape_bytes(result_text)
        if nbytes == 0:
            continue
        k = max(2, _group_size(line))
        if op == "all-reduce":
            wire = 2.0 * nbytes * (k - 1) / k
        elif op == "collective-permute":
            wire = float(nbytes)
        else:
            wire = float(nbytes) * (k - 1) / k
        stats.wire_bytes += wire
        stats.by_op[op] = stats.by_op.get(op, 0.0) + wire
        stats.count += 1
    return stats


# --------------------------------------------------------------- analytic ---
def _axes(mesh, layout: str = "baseline") -> tuple[int, int]:
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    tp = mesh.shape.get("model", 1)
    if layout in ("dp-only", "pure-dp"):
        return dp * tp, 1
    return dp, tp


def analytic_costs(cfg, spec, mesh, layout: str = "baseline", grad_bytes: int = 4) -> dict:
    """Per-device (flops, hbm_bytes, wire_bytes) from the formulas above."""
    dp, tp = _axes(mesh, layout)
    D = dp * tp
    B = spec.global_batch
    S = spec.seq_len
    d = cfg.d_model
    L = cfg.num_layers
    hd = cfg.resolved_head_dim
    H = max(cfg.num_heads, 1)
    n_act = cfg.active_param_count()
    n_tot = cfg.param_count()
    step = spec.step
    remat = cfg.remat and step == "train"

    # ---- attention / ssd structure per family
    causal_layers, noncausal_pairs = 0, []  # (layers, q_len, kv_len)
    ssd_layers = 0
    if cfg.family in ("dense", "moe", "vlm"):
        causal_layers = L
        s_eff = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    elif cfg.family == "hybrid":
        causal_layers = L // max(cfg.attn_every, 1)
        ssd_layers = L
        s_eff = S
    elif cfg.family == "ssm":
        ssd_layers = L
        s_eff = S
    else:  # encdec
        causal_layers = L
        noncausal_pairs = [(cfg.encoder_layers, cfg.encoder_frames, cfg.encoder_frames),
                           (L, S, cfg.encoder_frames)]
        s_eff = S

    # ---- FLOPs
    if step == "train":
        m_mat, m_attn = (8, 4) if remat else (6, 3)
        T = B * S
    elif step == "prefill":
        m_mat, m_attn = 2, 1
        T = B * S
    else:
        m_mat, m_attn = 2, 1
        T = B  # one token per sequence

    flops = m_mat * n_act * T
    if step == "decode":
        flops += causal_layers * 4.0 * B * H * s_eff * hd * m_attn
        flops += ssd_layers * 3.0 * 2.0 * B * cfg.ssm_num_heads * cfg.ssm_head_dim * cfg.ssm_state
        for (nl, q, kv) in noncausal_pairs:
            flops += nl * 4.0 * B * H * kv * hd * m_attn  # cross-attn reads enc kv
    else:
        flops += causal_layers * 2.0 * B * H * float(s_eff) ** 2 * hd * m_attn
        for (nl, q, kv) in noncausal_pairs:
            flops += nl * 4.0 * B * H * q * kv * hd * m_attn
        if ssd_layers:
            Lc, N, P = cfg.ssm_chunk, cfg.ssm_state, cfg.ssm_head_dim
            Hs = cfg.ssm_num_heads
            flops += ssd_layers * m_attn * 2.0 * B * S * Hs * (Lc * (N + P) + 2 * N * P)
    flops_dev = flops / D

    # ---- HBM bytes
    if step == "train":
        opt_stream = 8.0 * n_tot * 4 / D            # p, g, mu, nu read+write
        weight_passes = (3 if remat else 2) * n_tot * 2 / tp
        act = 16.0 * L * (B / dp) * S * d * 2
        hbm = opt_stream + weight_passes + act
    elif step == "prefill":
        weight_passes = n_tot * 2 / tp
        act = 8.0 * L * (B / dp) * S * d * 2
        cache_w = 2.0 * causal_layers * (B / dp) * S * cfg.num_kv_heads * hd * 2 / max(tp // 1, 1)
        hbm = weight_passes + act + cache_w
    else:
        weight_passes = n_tot * 2 / tp
        cache_r = 2.0 * causal_layers * (B / dp) * s_eff * cfg.num_kv_heads * hd * 2 / tp
        ssd_state = ssd_layers * (B / dp) * cfg.ssm_num_heads * cfg.ssm_head_dim * cfg.ssm_state * 4 * 2
        act = 2.0 * L * (B / dp) * 1 * d * 2 * 8
        hbm = weight_passes + cache_r + ssd_state + act

    # ---- collective wire bytes
    rs = (dp - 1) / max(dp, 1)
    rt = (tp - 1) / max(tp, 1)
    replicated = layout in ("replicated-weights", "pure-dp")
    if step == "train":
        grad_rs = n_tot * grad_bytes / tp * rs              # dp reduce-scatter
        opt_ag = n_tot * 4 / tp * rs                        # param re-gather
        fsdp_ag = (3 if remat else 2) * n_tot * 2 / tp * rs # per-pass weight gathers
        if replicated:
            fsdp_ag = 0.0
            grad_rs = n_tot * grad_bytes * rs * 2 / tp      # full all-reduce instead
            opt_ag = 0.0
        tp_ar = (6 if remat else 4) * L * (B / dp) * S * d * 2 * 2 * rt
        wire = grad_rs + opt_ag + fsdp_ag + tp_ar
    elif step == "prefill":
        fsdp_ag = 0.0 if replicated else n_tot * 2 / tp * rs
        tp_ar = 2.0 * L * (B / dp) * S * d * 2 * 2 * rt
        wire = fsdp_ag + tp_ar
    else:
        # baseline finding: 2-D sharded weights are re-gathered EVERY decode
        # step; 'replicated-weights' removes this entirely
        fsdp_ag = 0.0 if replicated else n_tot * 2 / tp * rs
        tp_ar = 2.0 * L * (B / dp) * 1 * d * 2 * 2 * rt
        softmax_stats = causal_layers * (B / dp) * H * 4 * 2 * 2 * rt
        wire = fsdp_ag + tp_ar + softmax_stats

    return {"flops_dev": flops_dev, "hbm_dev": hbm, "wire_dev": wire}


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    collectives: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float       # MODEL_FLOPS / (FLOPs * chips)
    xla_flops_per_device: float = 0.0
    xla_bytes_per_device: float = 0.0
    xla_wire_bytes_per_device: float = 0.0

    def dominant_seconds(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def analyze(compiled, *, cfg, spec, mesh, model_flops: float, layout: str = "baseline", grad_bytes: int = 4) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    a = analytic_costs(cfg, spec, mesh, layout, grad_bytes)
    compute_s = a["flops_dev"] / PEAK_FLOPS
    memory_s = a["hbm_dev"] / HBM_BW
    collective_s = a["wire_dev"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    num_devices = mesh.size
    useful = model_flops / max(a["flops_dev"] * num_devices, 1.0)
    return Roofline(
        flops_per_device=a["flops_dev"],
        hbm_bytes_per_device=a["hbm_dev"],
        wire_bytes_per_device=a["wire_dev"],
        collectives=coll.by_op,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=useful,
        xla_flops_per_device=xla_flops,
        xla_bytes_per_device=xla_bytes,
        xla_wire_bytes_per_device=coll.wire_bytes,
    )


def model_flops_for(cfg, shape_spec) -> float:
    """MODEL_FLOPS: 6·N·D (train) or 2·N_active·D (inference), D = tokens."""
    n_active = cfg.active_param_count()
    tokens = shape_spec.global_batch * (1 if shape_spec.step == "decode" else shape_spec.seq_len)
    mult = 6 if shape_spec.step == "train" else 2
    return float(mult) * n_active * tokens
