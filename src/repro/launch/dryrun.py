import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init).  For every cell this script:

    1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod),
    2. pads the arch config for the mesh (head/vocab divisibility),
    3. constructs abstract params / optimizer / cache / batch with shardings,
    4. ``jax.jit(step).lower(...).compile()`` — sharding or memory bugs fail
       here exactly as they would on real hardware,
    5. records memory_analysis / cost_analysis / collective bytes into a JSON
       row for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh both
    python -m repro.launch.dryrun --all --mesh single --out results/dryrun.json
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, applicable
from repro.data.pipeline import batch_struct
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw
from repro.sharding import rules
from repro.train import step as step_lib


def _attach(shardings, tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), tree, shardings
    )


def input_specs(cfg, shape_spec, mesh, layout: str = "baseline"):
    """ShapeDtypeStruct stand-ins (weak-type correct, shardable, no alloc)."""
    b = batch_struct(cfg, shape_spec.seq_len, shape_spec.global_batch)
    specs = rules.batch_specs(cfg, mesh, b, layout)
    shardings = rules.to_shardings(mesh, specs)
    return _attach(shardings, b)


def lower_cell(arch: str, shape: str, mesh, *, donate: bool = True, layout: str = "baseline", grad_dtype: str = "float32", remat: bool = True, zero1: bool = False):
    """Returns (lowered, cfg, meta) for one cell on `mesh`."""
    spec = SHAPES[shape]
    cfg = rules.pad_config_for_mesh(ARCHS[arch], mesh, layout)
    if not remat:
        cfg = dataclasses.replace(cfg, remat=False)
    params_shape = step_lib.abstract_params(cfg)
    pshard = rules.param_shardings(cfg, mesh, params_shape, layout)
    abstract_p = _attach(pshard, params_shape)
    repl = NamedSharding(mesh, P())

    if True:  # NamedShardings carry the mesh; no ambient mesh context needed
        if spec.step == "train":
            ocfg = adamw.AdamWConfig()
            fn = step_lib.make_train_fn(cfg, ocfg, grad_dtype=grad_dtype)
            opt_shape = step_lib.abstract_opt_state(params_shape)
            if zero1:
                # ZeRO-1: optimizer states sharded over the data axes even
                # when params are replicated (grad RS + param AG per step)
                zshard = rules.param_shardings(cfg, mesh, params_shape, "dp-only")
                oshard = {"mu": zshard, "nu": zshard, "step": repl}
            else:
                oshard = {"mu": pshard, "nu": pshard, "step": repl}
            abstract_o = _attach(oshard, opt_shape)
            batch = input_specs(cfg, spec, mesh, layout)
            jitted = jax.jit(
                fn,
                in_shardings=(pshard, oshard, None),
                out_shardings=(pshard, oshard, repl),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(abstract_p, abstract_o, batch)
        elif spec.step == "prefill":
            fn = step_lib.make_prefill_fn(cfg, max_len=spec.seq_len)
            batch = input_specs(cfg, spec, mesh, layout)
            batch.pop("labels", None)
            cache_shape = step_lib.abstract_cache(cfg, spec.global_batch, spec.seq_len)
            cshard = rules.to_shardings(mesh, rules.cache_specs(cfg, mesh, cache_shape, layout))
            jitted = jax.jit(fn, in_shardings=(pshard, None), out_shardings=(None, cshard))
            lowered = jitted.lower(abstract_p, batch)
        else:  # decode
            fn = step_lib.make_decode_fn(cfg)
            cache_shape = step_lib.abstract_cache(cfg, spec.global_batch, spec.seq_len)
            cshard = rules.to_shardings(mesh, rules.cache_specs(cfg, mesh, cache_shape, layout))
            abstract_c = _attach(cshard, cache_shape)
            tok_shard = rules.to_shardings(
                mesh, rules.batch_specs(cfg, mesh, {"tokens": jax.ShapeDtypeStruct((spec.global_batch, 1), jnp.int32)}, layout)
            )["tokens"]
            toks = jax.ShapeDtypeStruct((spec.global_batch, 1), jnp.int32, sharding=tok_shard)
            jitted = jax.jit(
                fn,
                in_shardings=(pshard, cshard, tok_shard),
                out_shardings=(tok_shard, cshard),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(abstract_p, abstract_c, toks)
    return lowered, cfg, spec


def run_cell(arch: str, shape: str, mesh_kind: str, layout: str = "baseline", grad_dtype: str = "float32", remat: bool = True, zero1: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    row = {"arch": arch, "shape": shape, "mesh": mesh_kind, "devices": mesh.size,
           "layout": layout, "grad_dtype": grad_dtype, "remat": remat, "zero1": zero1}
    if not applicable(arch, shape):
        row["status"] = "skipped"
        row["reason"] = "full-attention arch: long_500k inapplicable (DESIGN.md)"
        return row
    t0 = time.time()
    try:
        lowered, cfg, spec = lower_cell(arch, shape, mesh, layout=layout, grad_dtype=grad_dtype, remat=remat, zero1=zero1)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        try:
            mem = compiled.memory_analysis()
        except Exception:
            mem = None
        rl = roofline.analyze(
            compiled, cfg=cfg, spec=spec, mesh=mesh, layout=layout,
            grad_bytes=2 if grad_dtype == "bfloat16" else 4,
            model_flops=roofline.model_flops_for(cfg, spec),
        )
        row.update(
            status="ok",
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0),
            },
            roofline=dataclasses.asdict(rl),
        )
    except Exception as e:  # a failure here is a sharding/memory bug
        row["status"] = "FAIL"
        row["error"] = f"{type(e).__name__}: {e}"
        row["traceback"] = traceback.format_exc()[-2000:]
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--layout", choices=["baseline", "dp-only", "replicated-weights", "pure-dp"], default="baseline")
    ap.add_argument("--grad-dtype", choices=["float32", "bfloat16"], default="float32")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        archs = [args.arch] if args.arch else list(ARCHS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(a, s) for a in archs for s in shapes]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    rows = []
    for arch, shape in cells:
        for mk in meshes:
            row = run_cell(arch, shape, mk, layout=args.layout, grad_dtype=args.grad_dtype, remat=not args.no_remat, zero1=args.zero1)
            rows.append(row)
            rl = row.get("roofline", {})
            print(
                f"[{row['status']:7s}] {arch:20s} {shape:12s} {mk:6s} "
                f"compile={row.get('compile_s', '-'):>7}s "
                f"bottleneck={rl.get('bottleneck', '-'):10s} "
                f"terms(ms)=c{1e3*rl.get('compute_s', 0):.1f}/m{1e3*rl.get('memory_s', 0):.1f}/x{1e3*rl.get('collective_s', 0):.1f}",
                flush=True,
            )
            if row["status"] == "FAIL":
                print(row["error"], flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
            keys = {(r["arch"], r["shape"], r["mesh"], r.get("layout", "baseline"), r.get("grad_dtype", "float32")) for r in rows}
            existing = [r for r in existing if (r["arch"], r["shape"], r["mesh"], r.get("layout", "baseline"), r.get("grad_dtype", "float32")) not in keys]
        with open(args.out, "w") as f:
            json.dump(existing + rows, f, indent=1)
        print(f"wrote {len(rows)} rows -> {args.out}")


if __name__ == "__main__":
    main()
