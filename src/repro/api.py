"""Unified engine API: one declarative entry point over every front-end.

The reproduction has grown three front-ends (:class:`~repro.core.store.ParallaxStore`,
:class:`~repro.core.shard.ShardedStore`, :class:`~repro.core.range_shard.RangeShardedStore`)
and two execution modes (serial and :class:`~repro.core.exec.ShardExecutor`
async), with knobs smeared across constructors and ``ycsb.execute`` kwargs.
This module is the single surface in front of all of them:

    import repro.api as api

    cfg = api.EngineConfig(
        store=StoreConfig(mode="parallax", bloom_bits_per_key=10),
        partitioning="range:4",          # "none" | "hash:<N>" | "range:<N>"
        execution="async",               # "serial" | "async"
    )
    with api.open(cfg) as db:
        db.put(b"k", b"v")
        with db.write_batch() as wb:     # buffered, applied at a sequence point
            wb.put(b"a", b"1").delete(b"k")
        it = db.iterator(b"a")           # lazy RocksDB-style cursor
        while it.valid():
            print(it.key(), it.value())
            it.next()
        api.execute(db, workload_ops)    # the one YCSB op-stream driver
        print(db.stats()["device"])

Design rules:

* **Declarative config.**  :class:`EngineConfig` is a validated dataclass tree
  — placement (:class:`~repro.core.store.StoreConfig`), partitioning
  (:class:`PartitioningConfig`: scheme + rebalance/migration budgets),
  execution (:class:`ExecutionConfig`: workers/pipeline/pace/overlap policy)
  and driver defaults.  ``partitioning``/``execution`` accept shorthand
  strings.  Invalid combinations fail at :func:`open` with a
  :class:`ConfigError` naming the field and the accepted forms.

* **One operation surface.**  ``put/get/delete/update``, :class:`WriteBatch`
  (replaces ad-hoc ``put_many``/``update_many``/``delete_many`` call
  patterns), a lazy :class:`Iterator` (replaces eager ``scan(start, count)``
  list materialization — the range back-end streams shard-by-shard, the hash
  back-end k-way merges incrementally), lifecycle (``close``, context
  manager, ``crash()``/``recover()`` for tests), namespaced
  :meth:`Engine.stats` and :meth:`Engine.device_time`.

* **Byte-identical to the legacy paths.**  The engine composes the existing
  front-ends and drivers rather than reimplementing them, so results,
  ``StoreStats``, ``DeviceStats`` and metadata-WAL record streams match the
  legacy call patterns exactly — ``tests/test_differential.py`` /
  ``tests/test_exec.py`` enforce this for every partitioning × execution
  combination.  ``partitioning="none"`` with async execution wraps a 1-shard
  hash front-end (op-for-op identical to the bare store) because the executor
  needs the batched-front-end plumbing.

* **Escape hatch.**  :attr:`Engine.store` exposes the backing front-end for
  maintenance/test surfaces the uniform API does not wrap (``split``,
  ``metalog``, per-shard devices).  With async execution, touch it only when
  no driver call is in flight (every ``api.execute`` returns drained).

The legacy module-level drivers (``repro.core.ycsb.execute`` /
``execute_async``) remain as thin deprecation shims for one release — they
warn once per process and delegate unchanged (``tests/test_deprecations.py``).
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
from typing import Iterable, Iterator as _TypingIterator

from repro.checkpoint.atomic import atomic_write_bytes
from repro.core import ycsb as _ycsb
from repro.core.exec import ShardExecutor
from repro.core.io import overlap_time
from repro.core.lifetime import LifetimeConfig
from repro.core.range_shard import RangeShardedStore
from repro.core.shard import ShardedStore
from repro.core.store import ParallaxStore, StoreConfig


# --------------------------------------------------------------------- errors
class EngineError(Exception):
    """Base class for every error raised by the :mod:`repro.api` surface."""


class ClosedError(EngineError):
    """An operation was attempted on a closed :class:`Engine`."""


class ConfigError(EngineError, ValueError):
    """An :class:`EngineConfig` (or a driver override) is invalid.

    Also a :class:`ValueError` so call-sites written against the legacy
    constructors' error contract keep catching it.
    """


_PARTITIONING_FORMS = "'none', 'hash:<N>', 'range:<N>'"
_EXECUTION_FORMS = "'serial', 'async'"


# --------------------------------------------------------------------- config
@dataclasses.dataclass(frozen=True)
class PartitioningConfig:
    """How the keyspace is partitioned, plus the range-scheme policy knobs.

    ``scheme`` is ``"none"`` (one bare store), ``"hash"`` (crc32 routing over
    ``shards`` stores) or ``"range"`` (contiguous key ranges; ``boundaries``
    pre-splits explicitly, otherwise ``shards`` uniform byte-prefix ranges).
    The remaining fields mirror :class:`~repro.core.range_shard.RangeShardedStore`'s
    rebalance/migration knobs and are ignored by the other schemes;
    ``migrate_budget`` is the driver-paced migration tick budget per batch
    (``repro.api.execute``'s default for this engine); ``rescale_budget`` is
    the default :meth:`Engine.rescale` admission budget — device bytes per
    migration tick shared across all concurrent rescale legs (0 =
    unthrottled) — and applies to both sharded schemes.
    """

    scheme: str = "none"
    shards: int = 1
    boundaries: tuple[bytes, ...] | None = None
    rebalance_window: int = 1024
    split_factor: float = 2.0
    merge_factor: float = 0.25
    min_split_keys: int = 32
    max_shards: int = 64
    auto_rebalance: bool = True
    migration_batch_keys: int = 128
    migrate_budget: int = 0
    rescale_budget: int = 0

    @classmethod
    def parse(cls, spec: "PartitioningConfig | str", **kw) -> "PartitioningConfig":
        """Coerce a shorthand string (``"none"``, ``"hash:4"``, ``"range:8"``)
        into a config; extra kwargs become field overrides."""
        if isinstance(spec, cls):
            return dataclasses.replace(spec, **kw) if kw else spec
        if not isinstance(spec, str):
            raise ConfigError(
                f"partitioning must be a PartitioningConfig or one of "
                f"{_PARTITIONING_FORMS}, got {type(spec).__name__}"
            )
        s = spec.strip()
        if s == "none":
            return cls(scheme="none", shards=1, **kw)
        scheme, sep, count = s.partition(":")
        if scheme in ("hash", "range"):
            if not sep:
                raise ConfigError(
                    f"partitioning {spec!r} is missing its shard count; "
                    f"expected one of {_PARTITIONING_FORMS}"
                )
            try:
                shards = int(count)
            except ValueError:
                raise ConfigError(
                    f"partitioning {spec!r} has a non-integer shard count "
                    f"{count!r}; expected one of {_PARTITIONING_FORMS}"
                ) from None
            return cls(scheme=scheme, shards=shards, **kw)
        raise ConfigError(
            f"unknown partitioning {spec!r}; expected one of {_PARTITIONING_FORMS}"
        )

    @classmethod
    def range_for_keys(cls, keys: Iterable[bytes], shards: int, **kw) -> "PartitioningConfig":
        """Range scheme pre-split on a key sample (equal-population quantiles,
        the declarative form of ``RangeShardedStore.for_keys``)."""
        bounds = tuple(RangeShardedStore.boundaries_for_keys(keys, shards))
        return cls(scheme="range", shards=len(bounds), boundaries=bounds, **kw)

    def validate(self) -> None:
        if self.scheme not in ("none", "hash", "range"):
            raise ConfigError(
                f"unknown partitioning scheme {self.scheme!r}; "
                f"expected one of {_PARTITIONING_FORMS}"
            )
        if self.shards < 1:
            raise ConfigError(
                f"partitioning needs a positive shard count, got {self.shards} "
                f"(scheme {self.scheme!r})"
            )
        if self.scheme == "none" and self.shards != 1:
            raise ConfigError(
                f"partitioning 'none' is a single store; got shards={self.shards} "
                f"— use 'hash:{self.shards}' or 'range:{self.shards}'"
            )
        if self.boundaries is not None:
            if self.scheme != "range":
                raise ConfigError(
                    f"boundaries only apply to range partitioning, not {self.scheme!r}"
                )
            if not self.boundaries or self.boundaries[0] != b"":
                raise ConfigError(
                    "range boundaries must start with b'' (shard 0 owns the keyspace head)"
                )
            if any(a >= b for a, b in zip(self.boundaries, self.boundaries[1:])):
                raise ConfigError("range boundaries must be strictly increasing")
        for field, minimum in (("rebalance_window", 1), ("min_split_keys", 1),
                               ("max_shards", 1), ("migration_batch_keys", 1),
                               ("migrate_budget", 0), ("rescale_budget", 0)):
            if getattr(self, field) < minimum:
                raise ConfigError(
                    f"partitioning.{field} must be >= {minimum}, got {getattr(self, field)}"
                )

    @property
    def num_shards(self) -> int:
        return len(self.boundaries) if self.boundaries is not None else self.shards

    def tag(self) -> str:
        return "none" if self.scheme == "none" else f"{self.scheme}{self.num_shards}"


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """How batches execute: serial (the historical inline path) or async
    (:class:`~repro.core.exec.ShardExecutor` per-shard queues).  ``overlap``
    is the default device-overlap policy for :meth:`Engine.device_time`
    (``"serial"`` / ``"ideal"`` / ``"channels:<k>"``); ``pace`` converts
    modeled device time into real sleeps and is async-only."""

    mode: str = "serial"
    workers: int = 4
    pipeline: bool = True
    pace: float = 0.0
    max_pending: int = 8
    overlap: str = "ideal"

    @classmethod
    def parse(cls, spec: "ExecutionConfig | str", **kw) -> "ExecutionConfig":
        if isinstance(spec, cls):
            return dataclasses.replace(spec, **kw) if kw else spec
        if not isinstance(spec, str):
            raise ConfigError(
                f"execution must be an ExecutionConfig or one of "
                f"{_EXECUTION_FORMS}, got {type(spec).__name__}"
            )
        s = spec.strip()
        if s in ("serial", "async"):
            return cls(mode=s, **kw)
        raise ConfigError(
            f"unknown execution mode {spec!r}; expected one of {_EXECUTION_FORMS}"
        )

    def validate(self) -> None:
        if self.mode not in ("serial", "async"):
            raise ConfigError(
                f"unknown execution mode {self.mode!r}; expected one of {_EXECUTION_FORMS}"
            )
        if self.workers < 1:
            raise ConfigError(f"execution.workers must be >= 1, got {self.workers}")
        if self.max_pending < 1:
            raise ConfigError(f"execution.max_pending must be >= 1, got {self.max_pending}")
        if self.pace < 0:
            raise ConfigError(f"execution.pace must be >= 0, got {self.pace}")
        if self.pace > 0 and self.mode == "serial":
            raise ConfigError(
                f"execution.pace={self.pace} requires mode 'async': the serial "
                "driver never sleeps modeled device time"
            )
        try:
            overlap_time([1.0], self.overlap)
        except ValueError as e:
            raise ConfigError(f"bad execution.overlap policy: {e}") from None

    def tag(self) -> str:
        if self.mode == "serial":
            return "serial"
        return f"async{self.workers}" + ("" if self.pipeline else "np")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """The declarative engine description :func:`open` consumes.

    One validated tree: ``store`` places bytes (mode/thresholds/blooms/GC —
    taken as-is, including ``bloom_bits_per_key``), ``partitioning`` shapes
    the fleet, ``execution`` schedules it, and ``batch_size``/``gc_every``
    are the driver defaults :func:`execute` falls back to.  ``partitioning``
    and ``execution`` accept shorthand strings (``"hash:4"``, ``"async"``).
    ``batch_size=None`` means auto: per-op for a bare serial store (the
    legacy single-store path), 64 otherwise.

    ``debug_checks=True`` attaches the :mod:`repro.analysis.racecheck`
    lockset race detector to the engine (also switchable fleet-wide with the
    ``REPRO_DEBUG_CHECKS`` env var); results and stats stay byte-identical,
    and a clean :meth:`Engine.close` raises
    :class:`~repro.analysis.racecheck.RaceViolation` if any access raced.
    When off (the default) the detector module is never even imported.

    ``snapshot_dir`` is the default home for :meth:`Engine.snapshot`
    manifests (``snapshot-<n>.json``; an explicit ``path`` argument always
    wins).  ``truncate_on_snapshot`` controls whether a snapshot of a
    range-partitioned engine also truncates the shard-metadata WAL down to
    the snapshot record (the default — recovery then replays O(delta)
    records); set it ``False`` to keep the full record history.
    """

    store: StoreConfig = dataclasses.field(default_factory=StoreConfig)
    partitioning: PartitioningConfig | str = dataclasses.field(default_factory=PartitioningConfig)
    execution: ExecutionConfig | str = dataclasses.field(default_factory=ExecutionConfig)
    batch_size: int | None = None
    gc_every: int = 0
    debug_checks: bool = False
    snapshot_dir: str | None = None
    truncate_on_snapshot: bool = True

    def __post_init__(self):
        object.__setattr__(self, "partitioning", PartitioningConfig.parse(self.partitioning))
        object.__setattr__(self, "execution", ExecutionConfig.parse(self.execution))

    def validate(self) -> "EngineConfig":
        if not isinstance(self.store, StoreConfig):
            raise ConfigError(
                f"store must be a repro.core.StoreConfig, got {type(self.store).__name__}"
            )
        if self.store.lifetime is not None and self.store.mode != "parallax":
            raise ConfigError(
                f"store.lifetime requires mode 'parallax' (lifetime-aware "
                f"placement splits the hybrid layout's value log), got "
                f"mode {self.store.mode!r}"
            )
        self.partitioning.validate()
        self.execution.validate()
        if self.batch_size is not None and self.batch_size < 0:
            raise ConfigError(f"batch_size must be >= 0, got {self.batch_size}")
        if self.execution.mode == "async" and self.batch_size == 0:
            raise ConfigError(
                "async execution needs batch_size >= 1 "
                "(per-op dispatch is serial-only); leave batch_size=None for auto"
            )
        if self.gc_every < 0:
            raise ConfigError(f"gc_every must be >= 0, got {self.gc_every}")
        if self.snapshot_dir is not None and not isinstance(self.snapshot_dir, str):
            raise ConfigError(
                f"snapshot_dir must be a path string or None, "
                f"got {type(self.snapshot_dir).__name__}"
            )
        return self

    def default_batch_size(self) -> int:
        if self.batch_size is not None:
            return self.batch_size
        if self.execution.mode == "serial" and self.partitioning.scheme == "none":
            return 0  # the legacy bare-store per-op path
        return 64

    def tag(self) -> str:
        """Compact engine-config id carried in benchmark row ids
        (``scripts/check_bench.py`` keys baseline rows on it)."""
        return f"{self.partitioning.tag()}+{self.execution.tag()}"


# --------------------------------------------------------------------- writes
class WriteBatch:
    """Buffered writes, applied as one unit at a sequence point.

    Collect with :meth:`put` / :meth:`update` / :meth:`delete` (chainable),
    then apply with :meth:`Engine.write` — or use the batch as a context
    manager, which commits on clean exit and discards on exception.  Ops
    apply in insertion order; consecutive same-kind runs dispatch through the
    back-end's batched APIs (the policy hook fires once per run, exactly like
    the legacy ``put_many``/``update_many``/``delete_many`` call patterns
    this class replaces).  On an async engine the whole batch is drained
    before :meth:`Engine.write` returns, so its effects are visible to the
    caller.  A committed batch is cleared and may be refilled.
    """

    __slots__ = ("_engine", "_ops")

    def __init__(self, engine: "Engine"):
        self._engine = engine
        self._ops: list[tuple[str, bytes, bytes]] = []

    def put(self, key: bytes, value: bytes) -> "WriteBatch":
        self._ops.append(("put", key, value))
        return self

    def update(self, key: bytes, value: bytes) -> "WriteBatch":
        self._ops.append(("update", key, value))
        return self

    def delete(self, key: bytes) -> "WriteBatch":
        self._ops.append(("delete", key, b""))
        return self

    def clear(self) -> None:
        self._ops.clear()

    def __len__(self) -> int:
        return len(self._ops)

    def __enter__(self) -> "WriteBatch":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self._engine.write(self)
        else:
            self.clear()  # discard: an aborted batch must not commit on reuse


def _op_runs(ops: list[tuple[str, bytes, bytes]]):
    """Maximal consecutive same-kind runs, in insertion order."""
    run_kind: str | None = None
    run: list[tuple[bytes, bytes]] = []
    for kind, key, value in ops:
        if run_kind is not None and kind != run_kind:
            yield run_kind, run
            run = []
        run_kind = kind
        run.append((key, value))
    if run_kind is not None:
        yield run_kind, run


# ------------------------------------------------------------------ iterator
class Iterator:
    """Lazy RocksDB-style cursor over the engine's sorted live rows.

    ``seek(key)`` positions at the first row ``>= key``; ``valid()`` says
    whether the cursor is on a row; ``key()``/``value()`` read it; ``next()``
    advances.  Rows are produced on demand from the back-end's lazy stream
    (:meth:`ParallaxStore.iter_range` / the front-ends' ``iter_rows``) —
    the range back-end streams shard-by-shard, the hash back-end k-way merges
    incrementally — so rows never visited are never read or charged, unlike
    the eager ``scan(start, count)`` this replaces.

    Creating or re-seeking the iterator is a sequence point on an async
    engine (the pipeline drains first).  The cursor is *unpinned*: writing
    through the engine, or a topology change (rebalance/migration tick),
    invalidates it — re-``seek`` after mutating.  Reading an invalid position
    raises :class:`EngineError`.
    """

    __slots__ = ("_engine", "_rows", "_key", "_value", "_valid")

    def __init__(self, engine: "Engine", start: bytes = b""):
        self._engine = engine
        self._rows: _TypingIterator[tuple[bytes, bytes]] = iter(())
        self._key: bytes | None = None
        self._value: bytes | None = None
        self._valid = False
        self.seek(start)

    def seek(self, key: bytes) -> "Iterator":
        """Position at the first live row with ``row_key >= key``."""
        eng = self._engine
        eng._check_open()
        eng._drain()
        store = eng._store
        if isinstance(store, ParallaxStore):
            self._rows = store.iter_range(key)
        else:
            self._rows = store.iter_rows(key)
        self._advance()
        return self

    def seek_to_first(self) -> "Iterator":
        return self.seek(b"")

    def _advance(self) -> None:
        nxt = next(self._rows, None)
        if nxt is None:
            self._valid, self._key, self._value = False, None, None
        else:
            self._valid = True
            self._key, self._value = nxt

    def valid(self) -> bool:
        return self._valid

    def key(self) -> bytes:
        self._require_valid()
        return self._key  # type: ignore[return-value]

    def value(self) -> bytes:
        self._require_valid()
        return self._value  # type: ignore[return-value]

    def next(self) -> None:
        self._require_valid()
        self._advance()

    def _require_valid(self) -> None:
        if not self._valid:
            raise EngineError(
                "iterator is not positioned on a row (exhausted or never sought; "
                "check valid() / seek first)"
            )

    def __iter__(self) -> _TypingIterator[tuple[bytes, bytes]]:
        """Consume from the current position as ``(key, value)`` pairs.

        The cursor advances on resumption, not ahead of it: a consumer that
        stops early (``itertools.islice``, ``break``) leaves the cursor
        positioned on the last yielded row and never pays for a lookahead
        row — pulling ``k`` rows charges exactly ``k`` rows.
        """
        while self._valid:
            yield (self._key, self._value)  # type: ignore[misc]
            self._advance()


def _debug_checks_env() -> bool:
    """Fleet-wide race-detector switch: any value of ``REPRO_DEBUG_CHECKS``
    other than empty / ``0`` / ``false`` / ``off`` enables it (CI's nightly
    slow sweep exports ``REPRO_DEBUG_CHECKS=1``)."""
    return os.environ.get("REPRO_DEBUG_CHECKS", "").strip().lower() not in (
        "", "0", "false", "off")


# -------------------------------------------------------------------- engine
class Engine:
    """A uniform KV surface over any partitioning × execution combination.

    Built by :func:`open`; do not construct front-ends directly in new code.
    All operations raise :class:`ClosedError` after :meth:`close`.  See the
    module docstring for the surface and ``docs/api.md`` for the config tree
    and the old→new migration table.
    """

    def __init__(self, config: EngineConfig):
        config.validate()
        self.config = config
        self._closed = False
        self._snapshot_seq = 0
        self._store = self._build_store(config)
        self._executor: ShardExecutor | None = None
        if config.execution.mode == "async":
            e = config.execution
            self._executor = ShardExecutor(
                self._store, e.workers, pipeline=e.pipeline, pace=e.pace,
                max_pending=e.max_pending,
            )
        # the race detector is opt-in and imported lazily: with debug checks
        # off, nothing of repro.analysis ever loads (zero-overhead contract,
        # held by tests/test_analysis_racecheck.py)
        self.race_checker = None
        self.protocol_monitor = None
        if config.debug_checks or _debug_checks_env():
            from repro.analysis.racecheck import attach_engine
            from repro.analysis.protocol.monitor import (
                attach_engine as attach_protocol_monitor,
            )

            self.race_checker = attach_engine(self)
            self.protocol_monitor = attach_protocol_monitor(self)

    @staticmethod
    def _build_store(cfg: EngineConfig):
        p = cfg.partitioning
        store_cfg = dataclasses.replace(cfg.store)
        if p.scheme == "none":
            if cfg.execution.mode == "serial":
                return ParallaxStore(store_cfg)
            # the executor needs the batched front-end plumbing; a 1-shard
            # hash store is op-for-op identical to the bare store
            return ShardedStore(1, store_cfg)
        if p.scheme == "hash":
            return ShardedStore(p.shards, store_cfg,
                                migration_batch_keys=p.migration_batch_keys,
                                rescale_budget=p.rescale_budget)
        kw = dict(
            rebalance_window=p.rebalance_window, split_factor=p.split_factor,
            merge_factor=p.merge_factor, min_split_keys=p.min_split_keys,
            max_shards=p.max_shards, auto_rebalance=p.auto_rebalance,
            migration_batch_keys=p.migration_batch_keys,
            rescale_budget=p.rescale_budget,
        )
        if p.boundaries is not None:
            return RangeShardedStore(config=store_cfg, boundaries=list(p.boundaries), **kw)
        return RangeShardedStore(p.shards, store_cfg, **kw)

    # ------------------------------------------------------------- lifecycle
    @property
    def store(self):
        """The backing front-end (escape hatch for maintenance/test surfaces
        the uniform API does not wrap).  With async execution, touch it only
        while no driver call is in flight."""
        return self._store

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self, wait: bool = True) -> None:
        """Close the engine (idempotent).  With async execution the executor
        shuts down — draining in-flight work first unless ``wait=False``.
        On a clean close (``wait=True``) of a ``debug_checks`` engine, any
        lockset violation the race detector recorded is raised as
        :class:`~repro.analysis.racecheck.RaceViolation`."""
        if self._closed:
            return
        self._closed = True
        if self._executor is not None:
            self._executor.close(wait=wait)
        if wait and self.race_checker is not None:
            self.race_checker.raise_if_violations()

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(wait=exc_type is None)

    def _check_open(self) -> None:
        if self._closed:
            raise ClosedError("engine is closed")

    # contract: coordinator-only
    def _drain(self) -> None:
        if self._executor is not None:
            self._executor.drain()

    # contract: coordinator-only
    def _sequence(self, fn):
        """Run ``fn`` with nothing in flight (coordinator-only)."""
        if self._executor is None:
            return fn()
        return self._executor.exclusive(fn)

    # ------------------------------------------------------------- point ops
    def put(self, key: bytes, value: bytes) -> None:
        self._check_open()
        if self._executor is None:
            self._store.put(key, value)
        else:
            self._executor.put_many([(key, value)])

    def update(self, key: bytes, value: bytes) -> None:
        self._check_open()
        if self._executor is None:
            self._store.update(key, value)
        else:
            self._executor.update_many([(key, value)])

    def delete(self, key: bytes) -> None:
        self._check_open()
        if self._executor is None:
            self._store.delete(key)
        else:
            self._executor.delete_many([key])

    def get(self, key: bytes) -> bytes | None:
        self._check_open()
        if self._executor is None:
            return self._store.get(key)
        return self._executor.get_many([key]).result()[0]

    # ---------------------------------------------------------------- writes
    def write_batch(self) -> WriteBatch:
        self._check_open()
        return WriteBatch(self)

    def write(self, batch: WriteBatch) -> None:
        """Apply a :class:`WriteBatch` (see its docstring for semantics)."""
        self._check_open()
        store, ex = self._store, self._executor
        for kind, items in _op_runs(batch._ops):
            if kind == "put":
                if ex is not None:
                    ex.put_many(items)
                    ex.after_batch()
                elif hasattr(store, "put_many"):
                    store.put_many(items)
                else:
                    for k, v in items:
                        store.put(k, v)
            elif kind == "update":
                if ex is not None:
                    ex.update_many(items)
                    ex.after_batch()
                elif hasattr(store, "update_many"):
                    store.update_many(items)
                else:
                    for k, v in items:
                        store.update(k, v)
            else:
                keys = [k for k, _ in items]
                if ex is not None:
                    ex.delete_many(keys)
                    ex.after_batch()
                elif hasattr(store, "delete_many"):
                    store.delete_many(keys)
                else:
                    for k in keys:
                        store.delete(k)
        self._drain()
        batch.clear()

    # ----------------------------------------------------------------- reads
    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Eager sorted scan (the legacy surface; prefer :meth:`iterator`)."""
        self._check_open()
        return self._sequence(lambda: self._store.scan(start, count))

    def iterator(self, start: bytes = b"") -> Iterator:
        """A lazy cursor positioned at the first row ``>= start``."""
        self._check_open()
        return Iterator(self, start)

    # ----------------------------------------------------------- maintenance
    def gc_tick(self, force: bool = False):
        """Value-log GC tick (per-shard background tasks on an async hash
        engine — returns ``None`` there; the segment count otherwise)."""
        self._check_open()
        if self._executor is None:
            return self._store.gc_tick(force=force)
        return self._executor.gc_tick(force=force)

    def migration_tick(self, budget: int | None = None) -> int:
        """Advance in-flight migrations — a range rebalance leg or any
        scheme's rescale legs (no-op on a bare store)."""
        self._check_open()
        if self._executor is not None:
            return self._executor.migration_tick(budget)
        tick = getattr(self._store, "migration_tick", None)
        return tick(budget) if tick is not None else 0

    def rescale(self, shards: int, *, budget: int | None = None) -> dict:
        """Start an online rescale of the fleet to ``shards`` shards.

        Plans a minimal-movement remap (hash: mod-routing compatible sizes
        only — a multiple or divisor of the current count; range:
        quantile-driven boundary re-splits), journals it to the shard
        metadata WAL, and flips routing immediately: reads and writes keep
        serving while the legs drain in the background via
        :meth:`migration_tick` (driver-paced; ``repro.api.execute`` paces it
        for you).  ``budget`` caps device bytes per tick across *all*
        concurrent legs (default ``partitioning.rescale_budget``; 0 =
        unthrottled).  Returns :meth:`topology`.  Raises
        :class:`ConfigError` on a non-sharded engine, a non-positive or
        unreachable shard count, or a rescale already in flight.
        """
        self._check_open()
        if self.config.partitioning.scheme == "none":
            raise ConfigError(
                "rescale() needs a sharded engine; partitioning 'none' is a "
                "single store — open with 'hash:N' or 'range:N'"
            )
        if shards < 1:
            raise ConfigError(
                f"rescale() needs a positive shard count, got {shards}"
            )
        try:
            self._sequence(lambda: self._store.rescale(shards, budget=budget))
        except ValueError as e:
            raise ConfigError(str(e)) from None
        return self.topology()

    def topology(self) -> dict:
        """The fleet shape: ``scheme``, ``shards``, range ``boundaries``
        (``None`` elsewhere), and ``rescale`` — in-flight rescale progress
        counters, or ``None`` when the fleet is quiescent.  Usable after
        :meth:`close` (post-run reporting)."""
        if not self._closed:
            self._drain()
        store = self._store
        if isinstance(store, ParallaxStore):
            return {"scheme": "none", "shards": 1, "boundaries": None,
                    "rescale": None}
        return {
            "scheme": self.config.partitioning.scheme,
            "shards": store.num_shards,
            "boundaries": (list(store.boundaries)
                           if isinstance(store, RangeShardedStore) else None),
            "rescale": store.rescale_progress(),
        }

    def flush_all(self) -> None:
        self._check_open()
        self._sequence(self._store.flush_all)

    def crash(self):
        """Drop volatile state at a sequence point (test hook); returns the
        recovery cutoff (per-store list on sharded back-ends)."""
        self._check_open()
        return self._sequence(self._store.crash)

    def recover(self) -> None:
        self._check_open()
        self._sequence(self._store.recover)

    # ------------------------------------------------------------------ stats
    def stats(self) -> dict:
        """Namespaced counters: ``engine`` (config identity), ``store``
        (aggregate :class:`StoreStats`), ``device`` (aggregate
        :class:`DeviceStats`), plus ``frontend`` (routing counters) on
        sharded back-ends, ``topology`` on the range scheme, and
        ``lifetime`` (sketch state + per-class log/GC counters; per-shard
        under ``"shards"`` on sharded back-ends) when
        ``store.lifetime`` is configured.  Usable after :meth:`close`
        (post-run reporting)."""
        if not self._closed:
            self._drain()
        store = self._store
        out: dict = {
            "engine": {
                "config": self.config.tag(),
                "partitioning": self.config.partitioning.scheme,
                "execution": self.config.execution.mode,
                "closed": self._closed,
            },
        }
        if isinstance(store, ParallaxStore):
            out["store"] = dataclasses.asdict(store.stats)
            out["device"] = dataclasses.asdict(store.device.stats)
            lt = store.lifetime_state()
            if lt is not None:
                out["lifetime"] = lt
            return out
        out["engine"]["num_shards"] = store.num_shards
        out["store"] = dataclasses.asdict(store.aggregate_stats())
        out["device"] = dataclasses.asdict(store.device_stats())
        lts = store.lifetime_states()
        if lts is not None:
            out["lifetime"] = {"shards": lts}
        out["frontend"] = {
            "scans": store.scans, "scan_probes": store.scan_probes,
            "gets": store.gets, "get_probes": store.get_probes,
        }
        if isinstance(store, RangeShardedStore):
            r = store.rescale_progress()
            m = store.migration if r is None else None
            out["topology"] = {
                "boundaries": list(store.boundaries),
                "splits": store.splits, "merges": store.merges,
                "migrated_keys": store.migrated_keys,
                "migration_ticks": store.migration_ticks,
                "get_fallbacks": store.get_fallbacks,
                "migration": None if m is None else dataclasses.asdict(m),
                "rescale": r,
                "meta_records": store.metalog.n_records,
                "meta_bytes": store.metalog.bytes_appended,
            }
        return out

    def device_time(self, policy: str | None = None) -> float:
        """Modeled completion time of the engine's device traffic under an
        overlap policy (default: the config's ``execution.overlap``)."""
        if not self._closed:
            self._drain()  # like stats(): never read counters mid-flight
        if isinstance(self._store, ParallaxStore):
            return self._store.device.device_time()
        return self._store.device_time(policy or self.config.execution.overlap)

    def amplification(self) -> float:
        if not self._closed:
            self._drain()
        return self._store.amplification()

    def space_bytes(self) -> int:
        if not self._closed:
            self._drain()
        return self._store.space_bytes()

    # ------------------------------------------------------------- snapshots
    def snapshot(self, path: str | None = None) -> str:
        """Write a restartable snapshot manifest and return its path.

        The manifest is a JSON document (``format`` 1) holding the engine's
        config and full logical state — every live row with its LSN, plus
        range topology and any in-flight migration — captured at a sequence
        point and published atomically (write-temp/fsync/rename; a crash
        mid-snapshot leaves the previous manifest intact).  On a
        range-partitioned engine the capture also appends a ``snapshot``
        record to the shard-metadata WAL and, when
        ``config.truncate_on_snapshot`` (the default), truncates the WAL
        down to that record so recovery replays O(delta) records.

        ``path`` defaults to ``snapshot-<n>.json`` under
        ``config.snapshot_dir``; with neither set this raises
        :class:`ConfigError`.  Load with :meth:`restore` (into a live,
        compatible engine) or module-level :func:`restore` (a fresh engine).
        """
        self._check_open()
        if path is None:
            if self.config.snapshot_dir is None:
                raise ConfigError(
                    "snapshot() needs a destination: pass a path or set "
                    "EngineConfig.snapshot_dir"
                )
            os.makedirs(self.config.snapshot_dir, exist_ok=True)
            path = os.path.join(
                self.config.snapshot_dir, f"snapshot-{self._snapshot_seq}.json"
            )
            self._snapshot_seq += 1
        state = self._sequence(self._capture_state)
        doc = {
            "format": 1,
            "config": _jsonable(dataclasses.asdict(self.config)),
            "state": _jsonable(state),
        }
        atomic_write_bytes(path, json.dumps(doc).encode("utf-8"))
        return path

    def restore(self, path: str) -> None:
        """Replace this engine's contents with a snapshot manifest's state.

        The snapshot's partitioning scheme must be compatible with this
        engine's (``range`` only restores into ``range``; a bare store and a
        1-shard hash fleet interconvert) — :class:`ConfigError` otherwise.
        Restoring re-roots a range engine's metadata WAL at a fresh snapshot
        record.  To restore into a *new* engine, use module-level
        :func:`restore`.
        """
        self._check_open()
        with io.open(path, "rb") as f:
            doc = json.loads(f.read())
        if doc.get("format") != 1:
            raise ConfigError(
                f"unsupported snapshot format {doc.get('format')!r} in {path}"
            )
        state = _from_jsonable(doc["state"])
        self._sequence(lambda: self._install_state(state))

    def clone(self, **overrides) -> "Engine":
        """Open an independent engine with this engine's current contents.

        State is captured in memory at a sequence point (no file is
        written) and installed into a fresh engine built from this config
        plus ``overrides`` — any :class:`EngineConfig` field except
        ``partitioning``, which the captured state is keyed to
        (:class:`ConfigError`; snapshot and reload a fresh fleet to
        repartition).  The clone shares nothing with the source: subsequent
        writes on either side are invisible to the other.
        """
        self._check_open()
        if "partitioning" in overrides:
            raise ConfigError(
                "clone() cannot change partitioning: the captured state is "
                "keyed to the source scheme — snapshot() and open a fresh "
                "engine instead"
            )
        state = self._sequence(self._capture_state)
        eng = Engine(
            dataclasses.replace(self.config, **overrides) if overrides else self.config
        )
        try:
            eng._sequence(lambda: eng._install_state(state))
        except BaseException:
            eng.close(wait=False)
            raise
        return eng

    # contract: coordinator-only
    def _capture_state(self) -> dict:
        """Capture full logical state (call at a sequence point only)."""
        store = self._store
        if isinstance(store, RangeShardedStore):
            store.snapshot_metadata(truncate=self.config.truncate_on_snapshot)
            return store.state_snapshot()
        if isinstance(store, ParallaxStore):
            return {"kind": "bare", "rows": store.snapshot_rows(), "lsn": store.lsn}
        return store.state_snapshot()

    # contract: coordinator-only
    def _install_state(self, state: dict) -> None:
        """Replace store contents with a captured state (sequence point only)."""
        store, kind = self._store, state.get("kind")
        if isinstance(store, RangeShardedStore):
            if kind != "range":
                raise ConfigError(
                    f"cannot restore a {kind!r} snapshot into a "
                    f"range-partitioned engine"
                )
            store.load_state(state)
            return
        if isinstance(store, ParallaxStore):
            # a 1-shard hash capture is op-for-op a bare store
            if kind == "hash" and len(state["shards"]) == 1:
                snap = state["shards"][0]
                state = {"kind": "bare", "rows": snap["rows"], "lsn": snap["lsn"]}
                kind = "bare"
            if kind != "bare":
                raise ConfigError(
                    f"cannot restore a {kind!r} snapshot into an unpartitioned "
                    f"serial engine"
                )
            fresh = ParallaxStore(dataclasses.replace(self.config.store))
            fresh.load_rows(state["rows"], state["lsn"])
            self._store = fresh
            return
        # hash fleet (including the 1-shard wrapper behind scheme 'none'+async)
        if kind == "bare":
            state = {"kind": "hash",
                     "shards": [{"rows": state["rows"], "lsn": state["lsn"]}]}
            kind = "hash"
        if kind != "hash":
            raise ConfigError(
                f"cannot restore a {kind!r} snapshot into a hash-partitioned engine"
            )
        try:
            store.load_state(state)
        except ValueError as e:
            raise ConfigError(str(e)) from None


# ------------------------------------------------------- snapshot (de)coding
def _jsonable(obj):
    """Recursively JSON-encode captured state: ``bytes`` become
    ``{"__bytes__": <hex>}`` and tuples become lists (state dicts only ever
    use ``str`` keys, so the bytes marker cannot collide with a real key)."""
    if isinstance(obj, bytes):
        return {"__bytes__": obj.hex()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(x) for x in obj]
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    return obj


def _from_jsonable(obj):
    if isinstance(obj, dict):
        if set(obj) == {"__bytes__"}:
            return bytes.fromhex(obj["__bytes__"])
        return {k: _from_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_from_jsonable(x) for x in obj]
    return obj


def _config_from_jsonable(d: dict) -> EngineConfig:
    """Rebuild an :class:`EngineConfig` from a decoded snapshot manifest."""
    part = dict(d["partitioning"])
    if part.get("boundaries") is not None:
        part["boundaries"] = tuple(part["boundaries"])
    store = dict(d["store"])
    if store.get("lifetime") is not None:
        store["lifetime"] = LifetimeConfig(**store["lifetime"])
    return EngineConfig(
        store=StoreConfig(**store),
        partitioning=PartitioningConfig(**part),
        execution=ExecutionConfig(**d["execution"]),
        **{k: d[k] for k in ("batch_size", "gc_every", "debug_checks",
                             "snapshot_dir", "truncate_on_snapshot")},
    )


# -------------------------------------------------------------------- driver
def open(config: EngineConfig | None = None, **overrides) -> Engine:
    """Open an :class:`Engine` from a declarative :class:`EngineConfig`.

    Field overrides may be passed as keywords, with or without a base config:
    ``open(partitioning="hash:4", execution="async")``.  Raises
    :class:`ConfigError` on any invalid combination.
    """
    if config is None:
        config = EngineConfig(**overrides)
    elif overrides:
        config = dataclasses.replace(config, **overrides)
    if not isinstance(config, EngineConfig):
        raise ConfigError(
            f"open() takes an EngineConfig (or field overrides), got {type(config).__name__}"
        )
    return Engine(config)


def restore(path: str, **overrides) -> Engine:
    """Open a fresh :class:`Engine` from a snapshot manifest.

    The engine is built from the config recorded in the manifest, with
    keyword ``overrides`` applied on top — any :class:`EngineConfig` field
    except ``partitioning``, which the snapshot state is keyed to
    (:class:`ConfigError`).  The state then installs exactly as
    :meth:`Engine.restore` would.
    """
    if "partitioning" in overrides:
        raise ConfigError(
            "restore() cannot change partitioning: the snapshot state is "
            "keyed to the source scheme"
        )
    with io.open(path, "rb") as f:
        doc = json.loads(f.read())
    if doc.get("format") != 1:
        raise ConfigError(
            f"unsupported snapshot format {doc.get('format')!r} in {path}"
        )
    cfg = _config_from_jsonable(_from_jsonable(doc["config"]))
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    eng = Engine(cfg)
    try:
        state = _from_jsonable(doc["state"])
        eng._sequence(lambda: eng._install_state(state))
    except BaseException:
        eng.close(wait=False)
        raise
    return eng


def execute(engine: Engine, ops, *, batch_size: int | None = None,
            gc_every: int | None = None, migrate_budget: int | None = None) -> dict:
    """The one YCSB op-stream driver; returns op counts.

    Replaces ``repro.core.ycsb.execute`` *and* ``execute_async``: the
    engine's :class:`ExecutionConfig` decides which path runs, with the
    batching/tick/GC positions of both guaranteed identical by the shared
    batch schedule (``ycsb._batch_events``).  Overrides default to the
    engine config's ``batch_size`` / ``gc_every`` /
    ``partitioning.migrate_budget``.
    """
    if not isinstance(engine, Engine):
        raise TypeError(
            "repro.api.execute drives an Engine; open one with "
            "repro.api.open(EngineConfig(...)) — the legacy store drivers "
            "live on as deprecated shims in repro.core.ycsb"
        )
    engine._check_open()
    cfg = engine.config
    bs = cfg.default_batch_size() if batch_size is None else batch_size
    ge = cfg.gc_every if gc_every is None else gc_every
    mb = cfg.partitioning.migrate_budget if migrate_budget is None else migrate_budget
    if engine._executor is None:
        return _ycsb._execute(engine.store, ops, gc_every=ge, batch_size=bs,
                              migrate_budget=mb)
    if bs < 1:
        raise ConfigError(
            "async execution needs batch_size >= 1 (per-op dispatch is serial-only)"
        )
    return _ycsb._execute_async(engine.store, ops, batch_size=bs, gc_every=ge,
                                migrate_budget=mb, executor=engine._executor)


def reset_deprecation_warnings() -> None:
    """Forget which deprecated shims have warned (the warn-once registry is
    per-process; tests reset it to observe the first-call warning)."""
    _ycsb._DEPRECATED_WARNED.clear()


__all__ = [
    "ClosedError",
    "ConfigError",
    "Engine",
    "EngineConfig",
    "EngineError",
    "ExecutionConfig",
    "Iterator",
    "PartitioningConfig",
    "WriteBatch",
    "execute",
    "open",
    "reset_deprecation_warnings",
    "restore",
]
