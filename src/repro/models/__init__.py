"""Model zoo: uniform pure-function interface over all assigned families.

``get_model(cfg)`` returns a namespace with:
    init_params(cfg, key) / forward(cfg, params, batch) -> (logits, aux)
    loss_fn(cfg, params, batch) -> scalar
    init_cache(cfg, batch, max_len, dtype)
    prefill(cfg, params, batch, max_len) -> (last_logits, cache)
    decode_step(cfg, params, cache, tokens) -> (logits, cache)
"""
from __future__ import annotations

import types

from . import encdec, transformer
from .config import ArchConfig


def get_model(cfg: ArchConfig):
    mod = encdec if cfg.family == "encdec" else transformer
    return types.SimpleNamespace(
        init_params=mod.init_params,
        forward=mod.forward,
        loss_fn=mod.loss_fn,
        init_cache=mod.init_cache,
        prefill=mod.prefill,
        decode_step=mod.decode_step,
    )


__all__ = ["ArchConfig", "get_model", "transformer", "encdec"]
