"""Mamba2 block: state-space duality (SSD) with chunked scan.

Follows the Mamba2 formulation (arXiv:2405.21060): input projections to
(z, x, B, C, dt), short depthwise conv on (x, B, C), SSD chunked scan with
scalar-per-head decay A, gated RMSNorm, output projection.

Distribution note: the projections are kept **separate** (wz/wx/wb/wc/wdt)
rather than fused, so the TP rules can shard the inner dim (d_inner -> heads)
over the ``model`` axis without slicing across concatenated regions; B/C are
small (ngroups * state) and replicated, mirroring GQA kv replication.

The chunked scan lives in ``repro.kernels.ssd_scan`` — ``ops.ssd_scan``
dispatches to the Pallas TPU kernel or the pure-jnp reference.  Decode keeps
a constant-size recurrent state (B, H, P, N) plus a (conv_width-1)-deep conv
cache — this is why SSM/hybrid archs are the ones that run ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import Params, _dtype, rmsnorm, rmsnorm_init


def ssm_init(cfg: ArchConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg.param_dtype)
    d = cfg.d_model
    di = cfg.ssm_d_inner
    h = cfg.ssm_num_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    ks = jax.random.split(key, 6)
    s = d**-0.5
    return {
        "wz": jax.random.normal(ks[0], (d, di), dt) * s,
        "wx": jax.random.normal(ks[1], (d, di), dt) * s,
        "wb": jax.random.normal(ks[2], (d, g * n), dt) * s,
        "wc": jax.random.normal(ks[3], (d, g * n), dt) * s,
        "wdt": jax.random.normal(ks[4], (d, h), dt) * s,
        "conv_x": jax.random.normal(ks[5], (cfg.ssm_conv_width, di), dt) * 0.2,
        "conv_bx": jnp.zeros((di,), dt),
        "conv_b": jax.random.normal(ks[0], (cfg.ssm_conv_width, g * n), dt) * 0.2,
        "conv_bb": jnp.zeros((g * n,), dt),
        "conv_c": jax.random.normal(ks[1], (cfg.ssm_conv_width, g * n), dt) * 0.2,
        "conv_bc": jnp.zeros((g * n,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dt),
        "dt_bias": jnp.zeros((h,), dt),
        "d_skip": jnp.ones((h,), dt),
        "norm": rmsnorm_init(di, dt),
        "out_proj": jax.random.normal(ks[2], (di, d), dt) * di**-0.5,
    }


def _causal_conv(w: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """Depthwise causal conv, width W.  x: (B,S,C); w: (W,C)."""
    wwidth = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (wwidth - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(wwidth):  # W=4: unrolled adds beat conv_general on TPU
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out + b


def ssm_forward(cfg: ArchConfig, p: Params, xin: jax.Array, return_state: bool = False):
    """Full-sequence SSD.  xin: (B,S,D) -> out (B,S,D).

    With ``return_state`` also returns (final_state, conv_tail) where
    ``conv_tail`` holds the last (conv_width-1) *pre-conv* (x|B|C) inputs,
    matching the decode conv-cache layout, so prefill hands off to decode.
    """
    cd = _dtype(cfg.compute_dtype)
    b, s, _ = xin.shape
    h, pdim, n, g = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    xc = xin.astype(cd)

    z = jnp.einsum("bsd,dk->bsk", xc, p["wz"].astype(cd))
    x_raw = jnp.einsum("bsd,dk->bsk", xc, p["wx"].astype(cd))
    b_raw = jnp.einsum("bsd,dk->bsk", xc, p["wb"].astype(cd))
    c_raw = jnp.einsum("bsd,dk->bsk", xc, p["wc"].astype(cd))
    dt_raw = jnp.einsum("bsd,dk->bsk", xc, p["wdt"].astype(cd))

    x = jax.nn.silu(_causal_conv(p["conv_x"].astype(cd), p["conv_bx"].astype(cd), x_raw))
    bmat = jax.nn.silu(_causal_conv(p["conv_b"].astype(cd), p["conv_bb"].astype(cd), b_raw))
    cmat = jax.nn.silu(_causal_conv(p["conv_c"].astype(cd), p["conv_bc"].astype(cd), c_raw))
    x = x.reshape(b, s, h, pdim)
    bmat = bmat.reshape(b, s, g, n)
    cmat = cmat.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    from repro.kernels.ssd_scan import ops as ssd_ops

    y, state = ssd_ops.ssd_scan(x, dt, a, bmat, cmat, chunk=cfg.ssm_chunk)
    y = y.astype(cd) + x * p["d_skip"].astype(cd)[None, None, :, None]
    y = y.reshape(b, s, cfg.ssm_d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(cd))
    if return_state:
        w = cfg.ssm_conv_width - 1
        tail = jnp.concatenate([x_raw, b_raw, c_raw], axis=-1)[:, -w:, :]
        if s < w:
            tail = jnp.pad(tail, ((0, 0), (w - s, 0), (0, 0)))
        return out, state, tail
    return out


def ssm_init_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    h, pdim, n = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, h, pdim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def ssm_decode(cfg: ArchConfig, p: Params, xin: jax.Array, cache: Params):
    """Single-token recurrent step.  xin: (B,1,D)."""
    cd = _dtype(cfg.compute_dtype)
    b = xin.shape[0]
    h, pdim, n, g = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    di = cfg.ssm_d_inner
    xc = xin.astype(cd)

    z = jnp.einsum("bsd,dk->bsk", xc, p["wz"].astype(cd))
    x_raw = jnp.einsum("bsd,dk->bsk", xc, p["wx"].astype(cd))[:, 0]
    b_raw = jnp.einsum("bsd,dk->bsk", xc, p["wb"].astype(cd))[:, 0]
    c_raw = jnp.einsum("bsd,dk->bsk", xc, p["wc"].astype(cd))[:, 0]
    dt_raw = jnp.einsum("bsd,dk->bsk", xc, p["wdt"].astype(cd))[:, 0]

    new_col = jnp.concatenate([x_raw, b_raw, c_raw], axis=-1)  # (B, conv_dim)
    hist = jnp.concatenate([cache["conv"].astype(cd), new_col[:, None, :]], axis=1)  # (B,W,C)
    wfull = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], axis=1).astype(cd)
    bfull = jnp.concatenate([p["conv_bx"], p["conv_bb"], p["conv_bc"]]).astype(cd)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, wfull) + bfull)
    x = conv_out[:, :di].reshape(b, h, pdim)
    bvec = conv_out[:, di : di + g * n].reshape(b, g, n)
    cvec = conv_out[:, di + g * n :].reshape(b, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    decay = jnp.exp(a[None] * dt)  # (B,H)
    rep = h // g
    bvec_h = jnp.repeat(bvec, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    cvec_h = jnp.repeat(cvec, rep, axis=1).astype(jnp.float32)
    state = cache["state"]
    dx = dt[..., None] * x.astype(jnp.float32)  # (B,H,P)
    state = state * decay[..., None, None] + dx[..., None] * bvec_h[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, cvec_h).astype(cd)
    y = y + x * p["d_skip"].astype(cd)[None, :, None]
    y = y.reshape(b, 1, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(cd))
    new_cache = {"state": state, "conv": hist[:, 1:, :].astype(cache["conv"].dtype)}
    return out, new_cache
