"""Shared neural layers: norms, RoPE, GQA attention (with KV cache), SwiGLU.

All layers are pure functions over explicit param pytrees.  Param creation
(`*_init`) and application are separated so the distribution layer can build
abstract params via ``jax.eval_shape`` and shard them with NamedSharding
without ever materializing full-size weights on this host.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .config import ArchConfig

Params = dict[str, Any]


def _dtype(name: str):
    return jnp.dtype(name)


# --------------------------------------------------------------------- norms
def rmsnorm_init(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype), "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm(params: Params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


def gelu_mlp_init(d_model: int, d_ff: int, dtype, key: jax.Array) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "wi": jax.random.normal(ks[0], (d_model, d_ff), dtype) * d_model**-0.5,
        "bi": jnp.zeros((d_ff,), dtype),
        "wo": jax.random.normal(ks[1], (d_ff, d_model), dtype) * d_ff**-0.5,
        "bo": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(p: Params, x: jax.Array, compute_dtype) -> jax.Array:
    cd = _dtype(compute_dtype)
    x = x.astype(cd)
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(cd)) + p["bi"].astype(cd))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(cd)) + p["bo"].astype(cd)


def sinusoid_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (length, dim), float32."""
    half = dim // 2
    scale = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / (half - 1)))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * scale[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------- rope
def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (..., S) int32 -> cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------- attention
def attention_init(cfg: ArchConfig, key: jax.Array) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, k = cfg.num_heads, cfg.num_kv_heads
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p: Params = {
        "wq": jax.random.normal(ks[0], (d, h, hd), dt) * scale,
        "wk": jax.random.normal(ks[1], (d, k, hd), dt) * scale,
        "wv": jax.random.normal(ks[2], (d, k, hd), dt) * scale,
        "wo": jax.random.normal(ks[3], (h, hd, d), dt) * scale,
    }
    if cfg.orig_num_heads and cfg.orig_num_heads < h:
        # TP head padding: padded q heads are exact zeros (contribute nothing)
        mask = (jnp.arange(h) < cfg.orig_num_heads).astype(dt)
        p["wq"] = p["wq"] * mask[None, :, None]
        p["wo"] = p["wo"] * mask[:, None, None]
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dt)
        p["bk"] = jnp.zeros((k, hd), dt)
        p["bv"] = jnp.zeros((k, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dt)
        p["k_norm"] = rmsnorm_init(hd, dt)
    return p


def _project_qkv(cfg: ArchConfig, p: Params, x: jax.Array, positions: jax.Array):
    cd = _dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if positions is not None:
        cos, sin = rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def kv_head_map(num_q_heads: int, num_kv_heads: int, orig_q_heads: int = 0):
    """Constant q-head -> kv-head index map.

    Divisibility-free GQA: instead of the (H -> K, group) reshape (which
    requires H % K == 0 and breaks under TP head padding), each q head gathers
    its kv head through this map.  Padded q heads (>= orig_q_heads, added for
    16-way TP divisibility with zeroed wq/wo) map to kv head 0.
    """
    import numpy as np

    oq = orig_q_heads or num_q_heads
    group = max(1, oq // num_kv_heads)
    m = np.minimum(np.arange(num_q_heads) // group, num_kv_heads - 1)
    return jnp.asarray(m, jnp.int32)


def _sdpa(cfg: ArchConfig, q, k, v, *, causal: bool, q_offset=0, window: int = 0):
    """Grouped-query scaled dot-product attention (XLA path).

    q: (B,Sq,H,D), k/v: (B,Skv,K,D).  ``q_offset`` is the absolute position of
    q[...,0] for causal masking against a longer k/v (decode).
    """
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    kvm = kv_head_map(h, kh, getattr(cfg, "orig_num_heads", 0))
    kr = k[:, :, kvm, :]  # (B,Skv,H,D); gather is sharded on H under SPMD
    vr = v[:, :, kvm, :]
    logits = jnp.einsum("bqhd,bshd->bhqs", q, kr).astype(jnp.float32)
    logits *= d ** -0.5
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, vr)


def attention(cfg: ArchConfig, p: Params, x: jax.Array, positions: jax.Array, *, causal: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    cd = _dtype(cfg.compute_dtype)
    q, k, v = _project_qkv(cfg, p, x.astype(cd), positions)
    if cfg.attention_impl == "flash" and causal:
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, window=cfg.sliding_window)
    else:
        out = _sdpa(cfg, q, k, v, causal=causal, window=cfg.sliding_window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))


def attention_decode(cfg: ArchConfig, p: Params, x: jax.Array, cache: Params, pos: jax.Array) -> tuple[jax.Array, Params]:
    """One-token decode against a KV cache.

    cache = {"k": (B, Smax, K, D), "v": same, } ; pos: scalar int32 current length.
    """
    cd = _dtype(cfg.compute_dtype)
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, p, x.astype(cd), positions)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
    b, smax, kh, d = k_cache.shape
    h = q.shape[2]
    kvm = kv_head_map(h, kh, getattr(cfg, "orig_num_heads", 0))
    # per-q-head logits against the full cache; softmax over the (possibly
    # sequence-sharded) cache axis — SPMD reduces the max/sum collectively
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k_cache.astype(cd)[:, :, kvm, :]).astype(jnp.float32)
    logits *= d ** -0.5
    kpos = jnp.arange(smax)[None, :]
    valid = kpos <= pos
    if cfg.sliding_window:
        valid &= kpos > pos - cfg.sliding_window
    logits = jnp.where(valid[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(cd)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v_cache.astype(cd)[:, :, kvm, :])
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return y, {"k": k_cache, "v": v_cache}


def cross_attention(cfg: ArchConfig, p: Params, x: jax.Array, kv: tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (whisper)."""
    cd = _dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    k, v = kv
    out = _sdpa(cfg, q, k.astype(cd), v.astype(cd), causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))


# ----------------------------------------------------------------------- mlp
def mlp_init(d_model: int, d_ff: int, dtype, key: jax.Array) -> Params:
    ks = jax.random.split(key, 3)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    return {
        "gate": jax.random.normal(ks[0], (d_model, d_ff), dtype) * s_in,
        "up": jax.random.normal(ks[1], (d_model, d_ff), dtype) * s_in,
        "down": jax.random.normal(ks[2], (d_ff, d_model), dtype) * s_out,
    }


def mlp(p: Params, x: jax.Array, compute_dtype) -> jax.Array:
    cd = _dtype(compute_dtype)
    x = x.astype(cd)
    g = jnp.einsum("bsd,df->bsf", x, p["gate"].astype(cd))
    u = jnp.einsum("bsd,df->bsf", x, p["up"].astype(cd))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["down"].astype(cd))


# ----------------------------------------------------------------- embedding
def embedding_init(cfg: ArchConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    v = cfg.vocab_padded  # padded rows are inert (never indexed by tokens)
    p = {"embed": jax.random.normal(ks[0], (v, cfg.d_model), dt) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(ks[1], (cfg.d_model, v), dt) * (cfg.d_model ** -0.5)
    return p


def embed(cfg: ArchConfig, p: Params, tokens: jax.Array) -> jax.Array:
    cd = _dtype(cfg.compute_dtype)
    return jnp.take(p["embed"], tokens, axis=0).astype(cd)


def unembed(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    cd = _dtype(cfg.compute_dtype)
    w = p.get("unembed")
    if w is None:
        w = p["embed"].T
    return jnp.einsum("bsd,dv->bsv", x.astype(cd), w.astype(cd))
