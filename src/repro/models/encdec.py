"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment, the conv/mel frontend is a **stub**: ``input_specs``
provides precomputed frame embeddings (B, frames, d_model).  The rest is the
real architecture: sinusoidal encoder positions, learned decoder positions,
pre-LayerNorm blocks with GELU MLPs, decoder causal self-attention +
cross-attention over the encoder output.  No RoPE (whisper uses absolute
positions).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (
    Params,
    _dtype,
    _project_qkv,
    _sdpa,
    attention_init,
    gelu_mlp,
    gelu_mlp_init,
    layernorm,
    layernorm_init,
    sinusoid_positions,
)

MAX_DECODER_POS = 65536  # learned decoder positions (covers the 32k shapes)


def _enc_layer_init(cfg: ArchConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    return {
        "ln1": layernorm_init(cfg.d_model, dt),
        "ln2": layernorm_init(cfg.d_model, dt),
        "attn": attention_init(cfg, ks[0]),
        "mlp": gelu_mlp_init(cfg.d_model, cfg.d_ff, dt, ks[1]),
    }


def _dec_layer_init(cfg: ArchConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "ln1": layernorm_init(cfg.d_model, dt),
        "lnx": layernorm_init(cfg.d_model, dt),
        "ln2": layernorm_init(cfg.d_model, dt),
        "self_attn": attention_init(cfg, ks[0]),
        "cross_attn": attention_init(cfg, ks[1]),
        "mlp": gelu_mlp_init(cfg.d_model, cfg.d_ff, dt, ks[2]),
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embedding": {
            "embed": jax.random.normal(ks[2], (cfg.vocab_padded, cfg.d_model), dt) * 0.02,
        },
        "dec_pos": jax.random.normal(ks[3], (MAX_DECODER_POS, cfg.d_model), dt) * 0.01,
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(cfg, k))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(cfg, k))(dec_keys),
        "enc_norm": layernorm_init(cfg.d_model, dt),
        "dec_norm": layernorm_init(cfg.d_model, dt),
    }


# ------------------------------------------------------------------ encoder
def encode(cfg: ArchConfig, params: Params, frame_embeds: jax.Array) -> jax.Array:
    cd = _dtype(cfg.compute_dtype)
    f = frame_embeds.shape[1]
    x = frame_embeds.astype(cd) + sinusoid_positions(f, cfg.d_model).astype(cd)[None]

    def body(x, lp):
        xn = layernorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = _project_qkv(cfg, lp["attn"], xn, None)
        h = _sdpa(cfg, q, k, v, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", h, lp["attn"]["wo"].astype(cd))
        x = x + gelu_mlp(lp["mlp"], layernorm(lp["ln2"], x, cfg.norm_eps), cfg.compute_dtype)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


# ------------------------------------------------------------------ decoder
def _dec_body(cfg: ArchConfig, lp: Params, x: jax.Array, enc_kv: tuple[jax.Array, jax.Array]):
    cd = _dtype(cfg.compute_dtype)
    xn = layernorm(lp["ln1"], x, cfg.norm_eps)
    q, k, v = _project_qkv(cfg, lp["self_attn"], xn, None)
    h = _sdpa(cfg, q, k, v, causal=True)
    x = x + jnp.einsum("bshk,hkd->bsd", h, lp["self_attn"]["wo"].astype(cd))
    xn = layernorm(lp["lnx"], x, cfg.norm_eps)
    qx = jnp.einsum("bsd,dhk->bshk", xn, lp["cross_attn"]["wq"].astype(cd))
    hx = _sdpa(cfg, qx, enc_kv[0], enc_kv[1], causal=False)
    x = x + jnp.einsum("bshk,hkd->bsd", hx, lp["cross_attn"]["wo"].astype(cd))
    x = x + gelu_mlp(lp["mlp"], layernorm(lp["ln2"], x, cfg.norm_eps), cfg.compute_dtype)
    return x


def forward(cfg: ArchConfig, params: Params, batch: dict[str, Any]) -> tuple[jax.Array, jax.Array]:
    cd = _dtype(cfg.compute_dtype)
    enc_out = encode(cfg, params, batch["frame_embeds"])
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = jnp.take(params["embedding"]["embed"], tokens, axis=0).astype(cd)
    x = x + params["dec_pos"][:s].astype(cd)[None]

    def body(x, lp):
        kx = jnp.einsum("bfd,dhk->bfhk", enc_out, lp["cross_attn"]["wk"].astype(cd))
        vx = jnp.einsum("bfd,dhk->bfhk", enc_out, lp["cross_attn"]["wv"].astype(cd))
        return _dec_body(cfg, lp, x, (kx, vx)), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"]["embed"].astype(cd))
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ArchConfig, params: Params, batch: dict[str, Any]) -> jax.Array:
    logits, _ = forward(cfg, params, batch)
    labels = batch["labels"]
    lg = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return ((logz - gold) * mask).sum() / jnp.clip(mask.sum(), 1.0)


# -------------------------------------------------------------------- cache
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((L, batch, cfg.encoder_frames, cfg.num_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((L, batch, cfg.encoder_frames, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ArchConfig, params: Params, batch: dict[str, Any], max_len: int):
    cd = _dtype(cfg.compute_dtype)
    enc_out = encode(cfg, params, batch["frame_embeds"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embedding"]["embed"], tokens, axis=0).astype(cd)
    x = x + params["dec_pos"][:s].astype(cd)[None]
    cache = init_cache(cfg, b, max_len, cd)

    def body(x, lp):
        kx = jnp.einsum("bfd,dhk->bfhk", enc_out, lp["cross_attn"]["wk"].astype(cd))
        vx = jnp.einsum("bfd,dhk->bfhk", enc_out, lp["cross_attn"]["wv"].astype(cd))
        xn = layernorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = _project_qkv(cfg, lp["self_attn"], xn, None)
        h = _sdpa(cfg, q, k, v, causal=True)
        x = x + jnp.einsum("bshk,hkd->bsd", h, lp["self_attn"]["wo"].astype(cd))
        xn = layernorm(lp["lnx"], x, cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", xn, lp["cross_attn"]["wq"].astype(cd))
        hx = _sdpa(cfg, qx, kx, vx, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", hx, lp["cross_attn"]["wo"].astype(cd))
        x = x + gelu_mlp(lp["mlp"], layernorm(lp["ln2"], x, cfg.norm_eps), cfg.compute_dtype)
        return x, (k, v, kx, vx)

    x, (ks, vs, kxs, vxs) = jax.lax.scan(body, x, params["dec_layers"])
    pad = max_len - s
    cache["k"] = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(cd)
    cache["v"] = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(cd)
    cache["cross_k"], cache["cross_v"] = kxs, vxs
    cache["pos"] = jnp.asarray(s, jnp.int32)
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:], params["embedding"]["embed"].astype(cd))
    return logits, cache


def decode_step(cfg: ArchConfig, params: Params, cache: Params, tokens: jax.Array):
    """One-token decode; cross K/V comes precomputed from prefill."""
    cd = _dtype(cfg.compute_dtype)
    pos = cache["pos"]
    b = tokens.shape[0]
    x = jnp.take(params["embedding"]["embed"], tokens, axis=0).astype(cd)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0).astype(cd)[None, 0]

    def body(x, inp):
        lp, kci, vci, kx, vx = inp
        xn = layernorm(lp["ln1"], x, cfg.norm_eps)
        q, k_new, v_new = _project_qkv(cfg, lp["self_attn"], xn, None)
        kci = jax.lax.dynamic_update_slice(kci, k_new.astype(kci.dtype), (0, pos, 0, 0))
        vci = jax.lax.dynamic_update_slice(vci, v_new.astype(vci.dtype), (0, pos, 0, 0))
        h = _sdpa(cfg, q, kci.astype(cd), vci.astype(cd), causal=True, q_offset=pos)
        x = x + jnp.einsum("bshk,hkd->bsd", h, lp["self_attn"]["wo"].astype(cd))
        xn = layernorm(lp["lnx"], x, cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", xn, lp["cross_attn"]["wq"].astype(cd))
        hx = _sdpa(cfg, qx, kx.astype(cd), vx.astype(cd), causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", hx, lp["cross_attn"]["wo"].astype(cd))
        x = x + gelu_mlp(lp["mlp"], layernorm(lp["ln2"], x, cfg.norm_eps), cfg.compute_dtype)
        return x, (kci, vci)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"])
    )
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"], new_cache["pos"] = nk, nv, pos + 1
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"]["embed"].astype(cd))
    return logits, new_cache
