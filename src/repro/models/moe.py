"""Fine-grained Mixture-of-Experts layer (DeepSeekMoE / Qwen3-MoE style).

Routing is top-k with per-sequence capacity dropping (GShard-style), but the
dispatch is **scatter/gather based** rather than the classic one-hot einsum:
the (S, E, C) dispatch tensor would be ~100M elements per group at the
assigned scales, while scatter/gather keeps the transient footprint at the
intrinsic (B, E, C, D) expert-input size.

Distribution baseline: expert FFN *hidden* dim is sharded over the ``model``
mesh axis (tensor-parallel experts).  Because combine (a gather + weighted
sum) is linear, the partial sums over the sharded hidden dim flow through
combine, so SPMD places ONE all-reduce of (B, S, D) per MoE layer — the same
collective a dense TP MLP needs.  A shard_map all-to-all expert-parallel
variant lives in ``repro/sharding/ep.py`` and is evaluated in the §Perf
hillclimb (beyond-paper optimization).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import Params, _dtype, mlp, mlp_init


def moe_init(cfg: ArchConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg.param_dtype)
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = d ** -0.5, f ** -0.5
    p: Params = {
        "router": jax.random.normal(ks[0], (d, e), dt) * s_in,
        "gate": jax.random.normal(ks[1], (e, d, f), dt) * s_in,
        "up": jax.random.normal(ks[2], (e, d, f), dt) * s_in,
        "down": jax.random.normal(ks[3], (e, f, d), dt) * s_out,
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(d, f * cfg.num_shared_experts, dt, ks[4])
    return p


def capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_apply(cfg: ArchConfig, p: Params, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).  Dispatch groups = batch rows."""
    cd = _dtype(cfg.compute_dtype)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    c = capacity(cfg, s)
    xt = x.astype(cd)

    logits = jnp.einsum("bsd,de->bse", xt, p["router"].astype(cd)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,S,E)
    topw, topi = jax.lax.top_k(probs, k)                          # (B,S,K)
    topw = (topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)).astype(cd)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=(0, 1))                                  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (b * s * k)
    aux = e * jnp.sum(me * ce)

    # slot assignment: position of each (token, k) within its expert's queue
    flat_e = topi.reshape(b, s * k)                               # (B, S*K)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # (B, S*K, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot                     # (B, S*K, E)
    slot = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]  # (B, S*K)
    keep = slot < c
    slot_c = jnp.where(keep, slot, 0)

    # scatter tokens into per-expert buffers (B, E, C, D)
    tok = jnp.repeat(xt, k, axis=1) if False else jnp.broadcast_to(
        xt[:, :, None, :], (b, s, k, d)
    ).reshape(b, s * k, d)
    w_keep = jnp.where(keep, 1.0, 0.0).astype(cd)
    buf = jnp.zeros((b, e, c, d), cd)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, s * k))
    buf = buf.at[bidx, flat_e, slot_c].add(tok * w_keep[..., None])

    # expert FFN, batched over experts (hidden dim sharded over 'model')
    g = jnp.einsum("becd,edf->becf", buf, p["gate"].astype(cd))
    u = jnp.einsum("becd,edf->becf", buf, p["up"].astype(cd))
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("becf,efd->becd", h, p["down"].astype(cd))    # (B,E,C,D)

    # combine: gather each token's k expert outputs and weight them
    gathered = eo[bidx, flat_e, slot_c]                           # (B,S*K,D)
    gathered = gathered * (topw.reshape(b, s * k)[..., None] * w_keep[..., None])
    out = gathered.reshape(b, s, k, d).sum(axis=2)

    if cfg.num_shared_experts:
        out = out + mlp(p["shared"], xt, cfg.compute_dtype)
    return out.astype(x.dtype), aux
