"""Architecture configuration shared by every model family.

One dataclass covers the whole assigned pool (dense GQA, MoE, SSM, hybrid,
encoder-decoder, VLM backbone).  Family-specific fields are ignored by other
families.  ``reduced()`` derives the small smoke-test variant of the same
family (few layers, narrow width, tiny vocab) used by per-arch CPU tests; the
full configs are only ever lowered via ShapeDtypeStruct in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    vocab_size: int
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # 0 = full attention
    # mlp
    d_ff: int = 0
    # moe
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2-style shared attention block)
    attn_every: int = 0               # apply the shared attn block every k ssm layers
    # encoder-decoder (whisper-style)
    encoder_layers: int = 0
    encoder_frames: int = 1500        # precomputed frame embeddings (stub frontend)
    # vlm (prefix patch embeddings, stub frontend)
    num_patches: int = 0
    # numerics / impl
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    attention_impl: str = "xla"       # xla | flash (Pallas kernel on TPU)
    remat: bool = True
    # distribution adjustments (see sharding.rules.pad_config_for_mesh):
    orig_num_heads: int = 0           # >0 when q heads were padded for TP
    vocab_pad_multiple: int = 1       # pad vocab (embedding rows only) for TP

    # ---------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.num_heads)

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def ssm_d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def ssm_groups(self) -> int:
        return 1

    def has_attention(self) -> bool:
        return self.family != "ssm"

    def subquadratic(self) -> bool:
        """True if long_500k is runnable (SSM / hybrid w/ windowed attention)."""
        return self.family in ("ssm", "hybrid")

    # ---------------------------------------------------------------- params
    def param_count(self) -> int:
        """Approximate parameter count N (used for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.resolved_head_dim
        if self.family in ("dense", "vlm", "moe", "encdec"):
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            if self.family == "moe":
                ffn = 3 * d * self.expert_d_ff * (self.num_experts + self.num_shared_experts)
                ffn += d * self.num_experts  # router
            else:
                ffn = 3 * d * self.d_ff
            per_layer = attn + ffn + 2 * d
            n = self.num_layers * per_layer + emb
            if self.family == "encdec":
                # encoder layers + cross-attention in decoder
                enc = self.encoder_layers * (attn + 3 * d * self.d_ff + 2 * d)
                cross = self.num_layers * attn
                n += enc + cross
            return n
        if self.family == "ssm":
            di, ns = self.ssm_d_inner, self.ssm_state
            per_layer = d * (2 * di + 2 * self.ssm_groups * ns + self.ssm_num_heads) + di * d + di
            return self.num_layers * per_layer + emb
        if self.family == "hybrid":
            di, ns = self.ssm_d_inner, self.ssm_state
            mamba = d * (2 * di + 2 * self.ssm_groups * ns + self.ssm_num_heads) + di * d + di
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            shared = attn + 3 * d * self.d_ff + 2 * d  # ONE shared block
            return self.num_layers * (mamba + 2 * d) + shared + emb
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.resolved_head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        ffn = 3 * d * self.expert_d_ff * (self.top_k + self.num_shared_experts)
        per_layer = attn + ffn + 2 * d + d * self.num_experts
        return self.num_layers * per_layer + self.vocab_size * d * 2

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2 if self.family != "hybrid" else 4),
            d_model=64,
            head_dim=16 if self.num_heads else 0,
            num_heads=max(0, min(self.num_heads, 4)),
            num_kv_heads=max(0, min(self.num_kv_heads, 2)),
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            num_experts=min(self.num_experts, 8),
            num_shared_experts=min(self.num_shared_experts, 1),
            top_k=min(self.top_k, 2),
            expert_d_ff=32 if self.expert_d_ff else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=32,
            num_patches=min(self.num_patches, 8),
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
        )
