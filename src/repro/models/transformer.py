"""Decoder-only LM composition for dense / MoE / SSM / hybrid / VLM families.

Layers are **stacked** (leading axis = num_layers) and consumed with
``jax.lax.scan`` so the traced HLO contains one layer body regardless of
depth — essential for compile times at 48-60 layers on 512 placeholder
devices.  Remat wraps the scan body when ``cfg.remat``.

The hybrid (zamba2-style) family scans homogeneous Mamba2 layers and applies
ONE weight-shared attention block every ``attn_every`` layers via
``lax.cond`` inside the scan body; each application site has its own KV-cache
slice (weights shared, caches not).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (
    Params,
    _dtype,
    attention,
    attention_decode,
    attention_init,
    embed,
    embedding_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)
from .moe import moe_apply, moe_init
from .ssm import ssm_decode, ssm_forward, ssm_init, ssm_init_cache


# ------------------------------------------------------------------- params
def _layer_init(cfg: ArchConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.family == "ssm" or cfg.family == "hybrid":
        return {"norm": rmsnorm_init(cfg.d_model, dt), "ssm": ssm_init(cfg, ks[0])}
    p: Params = {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "attn": attention_init(cfg, ks[0]),
    }
    if cfg.family == "moe":
        p["moe"] = moe_init(cfg, ks[1])
    else:
        p["mlp"] = mlp_init(cfg.d_model, cfg.d_ff, dt, ks[1])
    return p


def _shared_block_init(cfg: ArchConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "attn": attention_init(cfg, ks[0]),
        "mlp": mlp_init(cfg.d_model, cfg.d_ff, dt, ks[1]),
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    layers = jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys)
    params: Params = {
        "embedding": embedding_init(cfg, ks[1]),
        "final_norm": rmsnorm_init(cfg.d_model, _dtype(cfg.param_dtype)),
        "layers": layers,
    }
    if cfg.family == "hybrid":
        params["shared_block"] = _shared_block_init(cfg, ks[2])
    return params


# ------------------------------------------------------------------ forward
def _dense_body(cfg: ArchConfig, lp: Params, x: jax.Array, positions: jax.Array):
    h = attention(cfg, lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), positions)
    x = x + h
    if cfg.family == "moe":
        out, aux = moe_apply(cfg, lp["moe"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x + out, aux
    out = mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg.compute_dtype)
    return x + out, jnp.zeros((), jnp.float32)


def _shared_block_apply(cfg: ArchConfig, sp: Params, x: jax.Array, positions: jax.Array):
    h = attention(cfg, sp["attn"], rmsnorm(sp["ln1"], x, cfg.norm_eps), positions)
    x = x + h
    return x + mlp(sp["mlp"], rmsnorm(sp["ln2"], x, cfg.norm_eps), cfg.compute_dtype)


def _embed_inputs(cfg: ArchConfig, params: Params, batch: dict[str, Any]) -> jax.Array:
    x = embed(cfg, params["embedding"], batch["tokens"])
    if cfg.family == "vlm" and "patch_embeds" in batch:
        cd = _dtype(cfg.compute_dtype)
        x = jnp.concatenate([batch["patch_embeds"].astype(cd), x], axis=1)
    return x


def forward(cfg: ArchConfig, params: Params, batch: dict[str, Any]) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits over token positions, aux_loss)."""
    x = _embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if cfg.family in ("ssm", "hybrid"):
        shared = params.get("shared_block")

        def body(carry, inp):
            x, i = carry
            lp = inp
            h = ssm_forward(cfg, lp["ssm"], rmsnorm(lp["norm"], x, cfg.norm_eps))
            x = x + h
            if cfg.family == "hybrid":
                x = jax.lax.cond(
                    (i + 1) % cfg.attn_every == 0,
                    lambda x: _shared_block_apply(cfg, shared, x, positions),
                    lambda x: x,
                    x,
                )
            return (x, i + 1), jnp.zeros((), jnp.float32)

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, _), _ = jax.lax.scan(body_fn, (x, jnp.int32(0)), params["layers"])
        aux = jnp.zeros((), jnp.float32)
    else:

        def body(x, lp):
            return _dense_body(cfg, lp, x, positions)

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, auxs = jax.lax.scan(body_fn, x, params["layers"])
        aux = auxs.sum()

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = x[:, batch["patch_embeds"].shape[1] :]
    logits = unembed(cfg, params["embedding"], x)
    return logits, aux


def loss_fn(cfg: ArchConfig, params: Params, batch: dict[str, Any]) -> jax.Array:
    """Next-token cross entropy (+ MoE aux), numerically stable in fp32."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    lg = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.clip(mask.sum(), 1.0)
    return loss + 0.01 * aux


# -------------------------------------------------------------------- cache
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        caches = ssm_init_cache(cfg, batch, dtype)
        return {
            "ssm": jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), caches),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        sites = cfg.num_layers // cfg.attn_every
        caches = ssm_init_cache(cfg, batch, dtype)
        return {
            "ssm": jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), caches),
            "k": jnp.zeros((sites, batch, max_len, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((sites, batch, max_len, cfg.num_kv_heads, hd), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ------------------------------------------------------------------- decode
def decode_step(cfg: ArchConfig, params: Params, cache: Params, tokens: jax.Array):
    """One-token decode.  tokens: (B, 1) -> (logits (B,1,V), new cache)."""
    x = embed(cfg, params["embedding"], tokens)
    pos = cache["pos"]

    if cfg.family in ("ssm", "hybrid"):
        shared = params.get("shared_block")
        sites = cfg.num_layers // cfg.attn_every if cfg.family == "hybrid" else 0

        def body(carry, inp):
            x, i, kc, vc = carry
            lp, sc = inp
            h, new_sc = ssm_decode(cfg, lp["ssm"], rmsnorm(lp["norm"], x, cfg.norm_eps), sc)
            x = x + h
            if cfg.family == "hybrid":
                site = (i + 1) // cfg.attn_every - 1

                def apply_attn(args):
                    x, kc, vc = args
                    site_c = jnp.clip(site, 0, sites - 1)
                    kci = jax.lax.dynamic_index_in_dim(kc, site_c, 0, keepdims=False)
                    vci = jax.lax.dynamic_index_in_dim(vc, site_c, 0, keepdims=False)
                    xn = rmsnorm(shared["ln1"], x, cfg.norm_eps)
                    h, upd = attention_decode(cfg, shared["attn"], xn, {"k": kci, "v": vci}, pos)
                    x = x + h
                    x = x + mlp(shared["mlp"], rmsnorm(shared["ln2"], x, cfg.norm_eps), cfg.compute_dtype)
                    kc = jax.lax.dynamic_update_index_in_dim(kc, upd["k"], site_c, 0)
                    vc = jax.lax.dynamic_update_index_in_dim(vc, upd["v"], site_c, 0)
                    return x, kc, vc

                x, kc, vc = jax.lax.cond(
                    (i + 1) % cfg.attn_every == 0, apply_attn, lambda a: a, (x, kc, vc)
                )
            return (x, i + 1, kc, vc), new_sc

        kc = cache.get("k", jnp.zeros((1, 1, 1, 1, 1), x.dtype))
        vc = cache.get("v", jnp.zeros((1, 1, 1, 1, 1), x.dtype))
        (x, _, kc, vc), new_ssm = jax.lax.scan(
            body, (x, jnp.int32(0), kc, vc), (params["layers"], cache["ssm"])
        )
        new_cache = {"ssm": new_ssm, "pos": pos + 1}
        if cfg.family == "hybrid":
            new_cache["k"], new_cache["v"] = kc, vc
    else:

        def body(x, inp):
            lp, kci, vci = inp
            xn = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h, upd = attention_decode(cfg, lp["attn"], xn, {"k": kci, "v": vci}, pos)
            x = x + h
            if cfg.family == "moe":
                out, _ = moe_apply(cfg, lp["moe"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
            else:
                out = mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg.compute_dtype)
            return x + out, (upd["k"], upd["v"])

        x, (new_k, new_v) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": new_k, "v": new_v, "pos": pos + 1}

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(cfg, params["embedding"], x)
    return logits, new_cache


def prefill(cfg: ArchConfig, params: Params, batch: dict[str, Any], max_len: int):
    """Process a full prompt, returning (last-position logits, primed cache).

    For attention families this recomputes K/V per layer into the cache; for
    SSM/hybrid it returns the final recurrent state.  Implemented as forward
    + cache-filling scan to keep one traced layer body.
    """
    x = _embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    # VLM: the patch prefix extends the cached sequence; preserve the caller's
    # decode headroom by growing max_len by the prefix length
    max_len = max(max_len + (s - batch["tokens"].shape[1]), s)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    cache = init_cache(cfg, b, max_len, _dtype(cfg.compute_dtype))
    cd = _dtype(cfg.compute_dtype)
    from .layers import _project_qkv, _sdpa

    def attn_with_kv(p, xn):
        q, k, v = _project_qkv(cfg, p, xn.astype(cd), positions)
        out = _sdpa(cfg, q, k, v, causal=True, window=cfg.sliding_window)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd)), k, v

    if cfg.family in ("ssm", "hybrid"):
        shared = params.get("shared_block")
        sites = cfg.num_layers // cfg.attn_every if cfg.family == "hybrid" else 0

        def body(carry, inp):
            x, i, kc, vc = carry
            lp, sc = inp
            h, state, conv_tail = ssm_forward(
                cfg, lp["ssm"], rmsnorm(lp["norm"], x, cfg.norm_eps), return_state=True
            )
            x = x + h
            new_sc = {"state": state.astype(sc["state"].dtype), "conv": conv_tail.astype(sc["conv"].dtype)}
            if cfg.family == "hybrid":
                site = jnp.clip((i + 1) // cfg.attn_every - 1, 0, max(sites - 1, 0))

                def apply_attn(args):
                    x, kc, vc = args
                    h, k, v = attn_with_kv(shared["attn"], rmsnorm(shared["ln1"], x, cfg.norm_eps))
                    x = x + h
                    x = x + mlp(shared["mlp"], rmsnorm(shared["ln2"], x, cfg.norm_eps), cfg.compute_dtype)
                    pad = max_len - s
                    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(kc.dtype)
                    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(vc.dtype)
                    kc2 = jax.lax.dynamic_update_index_in_dim(kc, kp, site, 0)
                    vc2 = jax.lax.dynamic_update_index_in_dim(vc, vp, site, 0)
                    return x, kc2, vc2

                x, kc, vc = jax.lax.cond(
                    (i + 1) % cfg.attn_every == 0, apply_attn, lambda a: a, (x, kc, vc)
                )
            return (x, i + 1, kc, vc), new_sc

        kc = cache.get("k", jnp.zeros((1, 1, 1, 1, 1), cd))
        vc = cache.get("v", jnp.zeros((1, 1, 1, 1, 1), cd))
        (x, _, kc, vc), new_ssm = jax.lax.scan(
            body, (x, jnp.int32(0), kc, vc), (params["layers"], cache["ssm"])
        )
        cache["ssm"] = new_ssm
        if cfg.family == "hybrid":
            cache["k"], cache["v"] = kc, vc
    else:

        def body(x, lp):
            xn = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h, k, v = attn_with_kv(lp["attn"], xn)
            x = x + h
            if cfg.family == "moe":
                out, _ = moe_apply(cfg, lp["moe"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
            else:
                out = mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg.compute_dtype)
            return x + out, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        pad = max_len - s
        cache["k"] = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["k"].dtype)
        cache["v"] = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["v"].dtype)

    cache["pos"] = jnp.asarray(s, jnp.int32)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(cfg, params["embedding"], x[:, -1:])
    return logits, cache
