"""Deterministic synthetic data pipeline.

Produces per-host token batches with a counter-based PRNG (threefry over the
global step), so every host materializes exactly its shard without
coordination — the property that matters at 1000+ nodes: restart-stable,
order-independent, no shared filesystem in the hot path.  Stub modality
frontends (VLM patches / audio frames) synthesize embeddings the same way.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0


def host_batch(cfg: ArchConfig, dcfg: DataConfig, step: int, host_id: int = 0, num_hosts: int = 1) -> dict:
    """Materialize this host's slice of the global batch for `step` (numpy)."""
    assert dcfg.global_batch % num_hosts == 0
    per_host = dcfg.global_batch // num_hosts
    rng = np.random.default_rng(np.random.SeedSequence([dcfg.seed, step, host_id]))
    tokens = rng.integers(0, cfg.vocab_size, (per_host, dcfg.seq_len + 1), dtype=np.int32)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.family == "vlm":
        batch["patch_embeds"] = rng.standard_normal((per_host, cfg.num_patches, cfg.d_model), dtype=np.float32) * 0.02
    if cfg.family == "encdec":
        batch["frame_embeds"] = rng.standard_normal((per_host, cfg.encoder_frames, cfg.d_model), dtype=np.float32) * 0.02
    return batch


def batch_struct(cfg: ArchConfig, seq_len: int, global_batch: int) -> dict:
    """Abstract global-batch ShapeDtypeStructs (for lowering / dry-run)."""
    b = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.ShapeDtypeStruct((global_batch, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        b["frame_embeds"] = jax.ShapeDtypeStruct((global_batch, cfg.encoder_frames, cfg.d_model), jnp.float32)
    return b
