"""Gradient compression for the DP all-reduce (distributed-opt trick).

Two schemes, both with **error feedback** so compression error accumulates
into the next step instead of biasing the update (Karimireddy et al. 2019):

* ``int8``: per-tensor symmetric quantization.  The all-reduce payload drops
  4x (fp32->int8); on the wire this cuts the collective roofline term of the
  data axis proportionally.
* ``topk``: keep the top 1% |values| per tensor (sparse push).

Because pjit's all-reduce happens inside autodiff, the practical integration
quantizes gradients *before* the optimizer (value semantics); the wire saving
is realized when paired with ``shard_map``-level reductions — benchmarked in
§Perf.  Error-feedback state is carried in a host-side buffer keyed by tree
path (single-controller semantics; per-host in multi-host runs).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

_error_state: dict[int, Any] = {}


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, *, method: str = "int8", topk_frac: float = 0.01, error_state: Any | None = None):
    """Returns compressed-then-decompressed grads (+ optionally new error state).

    When ``error_state`` is given, applies error feedback: g' = g + e;
    e_next = g' - decompress(compress(g')).
    """
    def one(g, e):
        gf = g.astype(jnp.float32)
        if e is not None:
            gf = gf + e
        if method == "int8":
            q, s = quantize_int8(gf)
            out = dequantize_int8(q, s)
        elif method == "topk":
            k = max(1, int(gf.size * topk_frac))
            flat = gf.reshape(-1)
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            out = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(gf.shape)
        else:
            raise ValueError(method)
        err = gf - out
        return out.astype(g.dtype), err

    if error_state is None:
        return jax.tree.map(lambda g: one(g, None)[0], grads)
    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in outs]), td.unflatten([o[1] for o in outs])


def init_error_state(grads_shape: Any) -> Any:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape)


def wire_savings(method: str) -> float:
    """Payload-size ratio vs fp32 all-reduce (for roofline accounting)."""
    return {"int8": 0.25, "topk": 0.02, "none": 1.0}[method]
