"""Sharded train / prefill / decode step builders.

``make_train_step`` returns a jit'd (params, opt_state, batch) -> updated
function with donated params/opt buffers; sharding comes from
``repro.sharding.rules``.  Optional hooks: gradient compression (error
feedback, ``repro.train.compress``) and microbatched gradient accumulation.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.models import get_model
from repro.models.config import ArchConfig
from repro.optim import adamw
from repro.sharding import rules


def abstract_params(cfg: ArchConfig):
    m = get_model(cfg)
    return jax.eval_shape(lambda k: m.init_params(cfg, k), jax.random.PRNGKey(0))


def abstract_opt_state(params_shape):
    return jax.eval_shape(adamw.init, params_shape)


def make_train_fn(cfg: ArchConfig, ocfg: adamw.AdamWConfig | None = None, *, compress: str = "none", accum_steps: int = 1, grad_dtype: str = "float32"):
    """The pure train-step function (un-jitted) — callers add shardings.

    ``grad_dtype='bfloat16'`` differentiates w.r.t. a bf16 copy of the params
    (mixed precision): gradients — and therefore the data-parallel reduction
    on the wire — are bf16, halving the gradient collective.  The fp32 master
    weights still receive the update (adamw casts grads to fp32 internally).
    """
    ocfg = ocfg or adamw.AdamWConfig()
    m = get_model(cfg)

    def loss_of(params, batch):
        return m.loss_fn(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if grad_dtype != "float32":
            dt = jnp.dtype(grad_dtype)
            cast = jax.tree.map(lambda p: p.astype(dt) if p.dtype == jnp.float32 else p, params)
            loss, grads = jax.value_and_grad(loss_of)(cast, batch)
            new_params, new_opt, metrics = adamw.update(ocfg, grads, opt_state, params)
            metrics["loss"] = loss
            return new_params, new_opt, metrics
        if accum_steps > 1:
            def micro(i, acc):
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, i * (x.shape[0] // accum_steps), x.shape[0] // accum_steps, 0),
                    batch,
                )
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g))

            zero = (jnp.zeros(()), jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            loss, grads = jax.lax.fori_loop(0, accum_steps, micro, zero)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        if compress != "none":
            from repro.train.compress import compress_grads

            grads = compress_grads(grads, method=compress)
        new_params, new_opt, metrics = adamw.update(ocfg, grads, opt_state, params)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_train_step(cfg: ArchConfig, mesh, ocfg: adamw.AdamWConfig | None = None, **kw):
    """jit'd train step with full sharding annotations for `mesh`."""
    params_shape = abstract_params(cfg)
    pspecs = rules.param_shardings(cfg, mesh, params_shape)
    opt_shape = abstract_opt_state(params_shape)
    ospecs = {
        "mu": pspecs,
        "nu": pspecs,
        "step": NamedSharding(mesh, PartitionSpec()),
    }
    fn = make_train_fn(cfg, ocfg, **kw)
    repl = NamedSharding(mesh, PartitionSpec())
    step = jax.jit(
        fn,
        in_shardings=(pspecs, ospecs, None),
        out_shardings=(pspecs, ospecs, repl),
        donate_argnums=(0, 1),
    )
    return step, params_shape, pspecs, opt_shape, ospecs


def make_prefill_fn(cfg: ArchConfig, max_len: int):
    m = get_model(cfg)

    def prefill_step(params, batch):
        return m.prefill(cfg, params, batch, max_len)

    return prefill_step


def make_decode_fn(cfg: ArchConfig):
    m = get_model(cfg)

    def serve_step(params, cache, tokens):
        logits, new_cache = m.decode_step(cfg, params, cache, tokens)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_cache

    return serve_step


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = get_model(cfg)
    return jax.eval_shape(functools.partial(m.init_cache, cfg, batch, max_len, dtype))
