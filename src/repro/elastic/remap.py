"""Elastic mesh remapping + straggler policy (1000+-node posture).

Node failure / elastic resize: because checkpoints are keyed by tensor path
(not device), recovery onto a different topology is *metadata only*:

    1. ``shrink_mesh`` picks the largest (data', model') grid that fits the
       surviving device count while keeping the TP (`model`) axis intact when
       possible — TP resharding moves weights, DP resharding doesn't.
    2. ``plan_reshard`` re-derives NamedShardings under the new mesh from the
       same rules, so ``CheckpointManager.restore`` re-places shards.
    3. The data pipeline is counter-based (repro.data), so the new host set
       resumes at the checkpointed step with no data-order coordination.

Straggler mitigation: ``StragglerPolicy`` tracks per-host step latencies
(EWMA) and flags hosts slower than ``threshold`` x median; flagged hosts get
their microbatches redistributed (the runner shrinks their slice of the
global batch — works because the pipeline is counter-addressed).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.sharding import rules


def shrink_mesh(total_devices: int, *, prefer_model: int = 16, devices=None):
    """Largest (data, model) mesh fitting `total_devices` with model<=prefer."""
    model = prefer_model
    while model > 1 and (total_devices % model or total_devices < model):
        model //= 2
    data = total_devices // model
    devs = (devices or jax.devices())[: data * model]
    import numpy as _np

    arr = _np.array(devs).reshape(data, model)
    from jax.sharding import Mesh

    return Mesh(arr, ("data", "model"))


def plan_reshard(cfg, old_mesh, new_mesh, params_shape):
    """New shardings after failure; returns (new_shardings, moved_fraction).

    moved_fraction estimates the fraction of parameter bytes whose placement
    changes (0 when only the data axis shrinks — pure DP elasticity).
    """
    new_shard = rules.param_shardings(cfg, new_mesh, params_shape)
    old_spec = rules.param_specs(cfg, old_mesh, params_shape)
    new_spec = rules.param_specs(cfg, new_mesh, params_shape)
    moved = 0
    total = 0
    for o, n, leaf in zip(
        jax.tree.leaves(old_spec, is_leaf=_is_spec),
        jax.tree.leaves(new_spec, is_leaf=_is_spec),
        jax.tree.leaves(params_shape),
    ):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        total += nbytes
        if _model_part(o) != _model_part(n):
            moved += nbytes
    return new_shard, moved / max(total, 1)


def _is_spec(x):
    from jax.sharding import PartitionSpec

    return isinstance(x, PartitionSpec)


def _model_part(spec):
    return tuple("model" if p == "model" else None for p in spec)


@dataclasses.dataclass
class StragglerPolicy:
    threshold: float = 1.5       # x median latency
    ewma: float = 0.3
    min_samples: int = 3

    def __post_init__(self):
        self._lat: dict[int, float] = {}
        self._n: dict[int, int] = {}

    def observe(self, host: int, seconds: float) -> None:
        prev = self._lat.get(host)
        self._lat[host] = seconds if prev is None else (1 - self.ewma) * prev + self.ewma * seconds
        self._n[host] = self._n.get(host, 0) + 1

    def stragglers(self) -> list[int]:
        ready = {h: l for h, l in self._lat.items() if self._n[h] >= self.min_samples}
        if len(ready) < 2:
            return []
        med = float(np.median(list(ready.values())))
        return [h for h, l in ready.items() if l > self.threshold * med]

    def rebalance(self, global_batch: int, hosts: list[int]) -> dict[int, int]:
        """Per-host microbatch allocation with stragglers down-weighted 2x."""
        slow = set(self.stragglers())
        weights = {h: (0.5 if h in slow else 1.0) for h in hosts}
        wsum = sum(weights.values())
        alloc = {h: max(1, int(global_batch * w / wsum)) for h, w in weights.items()}
        # fix rounding so totals match
        drift = global_batch - sum(alloc.values())
        fast = [h for h in hosts if h not in slow] or hosts
        i = 0
        while drift != 0:
            alloc[fast[i % len(fast)]] += 1 if drift > 0 else -1
            drift += -1 if drift > 0 else 1
            i += 1
        return alloc
