"""Elastic fleet planning: minimal-movement N->M shard rescale plans.

This module is the pure planning half of online fleet rescaling (the
execution half — journaled migrations, double-routing, crash recovery —
lives in ``repro.core.range_shard`` / ``repro.core.shard``):

* :func:`plan_rescale` computes a :class:`RescalePlan` for an N->M shard
  change that moves as few keys as possible.  For hash partitioning it is
  the consistent-hashing-style property of mod routing: growing to a
  multiple ``M = k*N`` relocates exactly ``(M-N)/M`` of the keys (each new
  slot ``j`` pulls only the keys whose hash lands on ``j mod M``, all of
  which currently live on the single source ``j mod N``), and shrinking to
  a divisor relocates ``(N-M)/N`` — never a full reshuffle.  For range
  partitioning the plan is quantile-driven: growing adds ``M-N`` boundary
  cuts at the medians of the most populous ranges (keys outside the cut
  spans never move), shrinking drops the boundaries bounding the lightest
  adjacent pairs.

* :class:`RescaleState` is the coordinator bookkeeping for an in-flight
  rescale: which legs remain, the shared device-byte budget per tick, and
  the progress counters surfaced by ``Engine.topology()``.

Every leg is an ordinary journaled migration (``MigrationState`` with a
``rescale_start``/per-leg ``checkpoint``/``rescale_finish`` record stream);
legs on disjoint shard pairs drain concurrently through the executor's
per-shard FIFO queues, admission-controlled by the plan's global budget.

The planner is deliberately store-agnostic — it consumes a :class:`Topology`
value and an optional key sample, and produces positions, not store objects
— so it is unit-testable without building a fleet.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools


@dataclasses.dataclass(frozen=True)
class Topology:
    """A fleet shape: partitioning scheme, shard count, range boundaries."""

    scheme: str                                # "hash" | "range"
    shards: int
    boundaries: tuple[bytes, ...] | None = None

    def __post_init__(self):
        if self.scheme not in ("hash", "range"):
            raise ValueError(f"unknown scheme {self.scheme!r} (hash|range)")
        if self.shards < 1:
            raise ValueError("shards must be >= 1")
        if self.scheme == "range":
            b = self.boundaries
            if b is None or len(b) != self.shards or b[0] != b"":
                raise ValueError(
                    "range topology needs len(boundaries) == shards with boundaries[0] == b''")
            if any(x >= y for x, y in zip(b, b[1:])):
                raise ValueError("boundaries must be strictly increasing")


@dataclasses.dataclass(frozen=True)
class RescaleLeg:
    """One migration leg of a plan, in pre/post-rescale *positions*.

    ``kind`` is ``"split"``/``"merge"`` (range) or ``"hash"``; ``src`` is a
    position in the old map, ``dst`` a position in the new one.  Range legs
    carry the moved span ``[lo, hi)``; hash legs move the keys whose hash
    routes to ``dst`` under the new modulus (``lo``/``hi`` are ``None``).
    """

    kind: str
    src: int
    dst: int
    lo: bytes | None = None
    hi: bytes | None = None


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    """A minimal-movement N->M remap: the legs to run and the new shape."""

    scheme: str
    old_shards: int
    new_shards: int
    legs: tuple[RescaleLeg, ...]
    boundaries: tuple[bytes, ...] | None       # range: full post-rescale list
    moved_fraction: float                      # estimated fraction of keys relocated


def _range_grow(boundaries: tuple[bytes, ...], new_shards: int,
                key_sample) -> RescalePlan:
    old_n = len(boundaries)
    ks = sorted(set(key_sample or ()))
    if len(ks) < 2 * (new_shards - old_n):
        raise ValueError(
            "range grow needs a key sample (>= 2 keys per new shard) to place "
            "quantile cuts")
    # fragments: (lo, hi, sorted sample keys inside), refined by repeated
    # median cuts of the heaviest fragment — each cut is one new boundary
    frags: list[tuple[bytes, bytes | None, list[bytes]]] = []
    owner: list[int] = []                      # fragment -> original range
    for i, lo in enumerate(boundaries):
        hi = boundaries[i + 1] if i + 1 < old_n else None
        a = bisect.bisect_left(ks, lo)
        b = bisect.bisect_left(ks, hi) if hi is not None else len(ks)
        frags.append((lo, hi, ks[a:b]))
        owner.append(i)
    cuts_in: dict[int, list[bytes]] = {i: [] for i in range(old_n)}
    for _ in range(new_shards - old_n):
        j = max(range(len(frags)), key=lambda f: len(frags[f][2]))
        lo, hi, keys = frags[j]
        if len(keys) < 2:
            raise ValueError("key sample too thin to cut the heaviest range")
        cut = keys[len(keys) // 2]
        if cut <= lo:
            raise ValueError("key sample too skewed to place a distinct cut")
        cuts_in[owner[j]].append(cut)
        at = keys.index(cut)
        frags[j] = (lo, cut, keys[:at])
        frags.insert(j + 1, (cut, hi, keys[at:]))
        owner.insert(j + 1, owner[j])
    new_bounds: list[bytes] = []
    legs: list[RescaleLeg] = []
    moved = 0
    for i, lo in enumerate(boundaries):
        hi = boundaries[i + 1] if i + 1 < old_n else None
        src_pos = len(new_bounds)
        new_bounds.append(lo)
        cuts = sorted(cuts_in[i])
        for j, cut in enumerate(cuts):
            leg_hi = cuts[j + 1] if j + 1 < len(cuts) else hi
            legs.append(RescaleLeg("split", src_pos, len(new_bounds),
                                   lo=cut, hi=leg_hi))
            new_bounds.append(cut)
        if cuts:
            a = bisect.bisect_left(ks, cuts[0])
            b = bisect.bisect_left(ks, hi) if hi is not None else len(ks)
            moved += b - a
    frac = moved / len(ks) if ks else 0.0
    return RescalePlan("range", old_n, new_shards, tuple(legs),
                       tuple(new_bounds), frac)


def _range_shrink(boundaries: tuple[bytes, ...], new_shards: int,
                  key_sample) -> RescalePlan:
    old_n = len(boundaries)
    drops_needed = old_n - new_shards
    # merge legs retire their source, so two chained merges (shard i+1 into i
    # while i+2 merges into i+1) would make one shard both a source and a
    # destination — dropped boundaries must be non-adjacent, which caps a
    # single rescale at floor(N/2) merges; shrink further stepwise
    if drops_needed > old_n // 2:
        raise ValueError(
            f"range shrink {old_n}->{new_shards} needs {drops_needed} "
            f"non-adjacent merges but only {old_n // 2} fit; rescale stepwise")
    ks = sorted(set(key_sample or ()))

    def pair_weight(t: int) -> int:            # sample keys in shards t-1 and t
        lo = boundaries[t - 1]
        hi = boundaries[t + 1] if t + 1 < old_n else None
        a = bisect.bisect_left(ks, lo)
        b = bisect.bisect_left(ks, hi) if hi is not None else len(ks)
        return b - a

    # exact minimum-weight choice of ``drops_needed`` pairwise non-adjacent
    # boundaries (greedy-by-weight can dead-end on feasible inputs: picking a
    # middle boundary first blocks both neighbours); candidate count == shard
    # count, so the path-DP is trivially cheap
    idxs = list(range(1, old_n))

    @functools.lru_cache(maxsize=None)
    def choose(i: int, c: int):
        if c == 0:
            return (0, ())
        if i >= len(idxs):
            return None
        best = choose(i + 1, c)
        rest = choose(i + 2, c - 1)
        if rest is not None:
            taken = (rest[0] + pair_weight(idxs[i]), (idxs[i],) + rest[1])
            if best is None or taken[0] < best[0]:
                best = taken
        return best

    chosen = choose(0, drops_needed)
    if chosen is None:
        raise ValueError("could not choose non-adjacent merge pairs; rescale stepwise")
    dropped = sorted(chosen[1])
    new_bounds = [b for t, b in enumerate(boundaries) if t not in dropped]
    legs: list[RescaleLeg] = []
    moved = 0
    for t in dropped:
        lo = boundaries[t]
        hi = boundaries[t + 1] if t + 1 < old_n else None
        dst_pos = bisect.bisect_right(new_bounds, boundaries[t - 1]) - 1
        legs.append(RescaleLeg("merge", src=t, dst=dst_pos, lo=lo, hi=hi))
        a = bisect.bisect_left(ks, lo)
        b = bisect.bisect_left(ks, hi) if hi is not None else len(ks)
        moved += b - a
    frac = moved / len(ks) if ks else drops_needed / old_n
    return RescalePlan("range", old_n, new_shards, tuple(legs),
                       tuple(new_bounds), frac)


def plan_rescale(topology: Topology, new_shards: int, *,
                 key_sample=None) -> RescalePlan:
    """Plan a minimal-movement rescale of ``topology`` to ``new_shards``.

    Hash fleets rescale between mod-routing-compatible sizes only — ``M`` a
    multiple of ``N`` (grow; moves ``(M-N)/M`` of keys) or a divisor
    (shrink; moves ``(N-M)/N``) — because any other pair reshuffles nearly
    the whole keyspace, defeating the point.  Range fleets grow by quantile
    cuts of the heaviest ranges (``key_sample`` required) and shrink by
    merging the lightest non-adjacent pairs.  ``M == N`` returns an empty
    plan.  Raises ``ValueError`` on shapes the planner cannot reach in one
    rescale.
    """
    if new_shards < 1:
        raise ValueError("new_shards must be >= 1")
    n, m = topology.shards, new_shards
    if m == n:
        return RescalePlan(topology.scheme, n, m, (), topology.boundaries, 0.0)
    if topology.scheme == "hash":
        if m > n and m % n == 0:
            legs = tuple(RescaleLeg("hash", src=j % n, dst=j)
                         for j in range(n, m))
            return RescalePlan("hash", n, m, legs, None, (m - n) / m)
        if m < n and n % m == 0:
            legs = tuple(RescaleLeg("hash", src=s, dst=s % m)
                         for s in range(m, n))
            return RescalePlan("hash", n, m, legs, None, (n - m) / n)
        raise ValueError(
            f"hash rescale {n}->{m}: minimal movement needs the new count to "
            f"be a multiple or divisor of the old one")
    if m > n:
        return _range_grow(topology.boundaries, m, key_sample)
    return _range_shrink(topology.boundaries, m, key_sample)


@dataclasses.dataclass
class RescaleState:
    """Coordinator bookkeeping for one in-flight rescale.

    The owning front-end holds one of these from ``rescale_start`` to
    ``rescale_finish``.  ``budget`` is the *global* device-bytes-per-tick
    admission budget shared by every concurrent leg (0 = unthrottled);
    ``dst_ids`` maps plan legs to the store-assigned shard ids so per-leg
    ``checkpoint``/``finish`` records can name them; the counters feed
    ``Engine.topology()`` progress reporting.
    """

    plan: RescalePlan
    budget: int = 0
    dst_ids: tuple[int, ...] = ()              # shard id of each leg's dst
    legs_done: int = 0
    keys_moved: int = 0
    ticks: int = 0
    next_leg: int = 0                          # round-robin pointer

    @property
    def legs_total(self) -> int:
        return len(self.plan.legs)

    @property
    def done(self) -> bool:
        return self.legs_done >= self.legs_total

    def progress(self) -> dict:
        return {
            "from_shards": self.plan.old_shards,
            "to_shards": self.plan.new_shards,
            "legs_total": self.legs_total,
            "legs_done": self.legs_done,
            "keys_moved": self.keys_moved,
            "ticks": self.ticks,
            "budget": self.budget,
            "moved_fraction_planned": self.plan.moved_fraction,
        }


__all__ = [
    "RescaleLeg",
    "RescalePlan",
    "RescaleState",
    "Topology",
    "plan_rescale",
]
