"""AdamW with decoupled weight decay, fp32 state, cosine LR schedule.

States mirror parameter shardings (ZeRO-friendly: the sharding rules already
2-D shard every large tensor, so optimizer memory scales with 1/chips).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def update(cfg: AdamWConfig, grads: Any, state: dict, params: Any):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu2 / b1c
        nhat = nu2 / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu2, nu2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_state = {
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
