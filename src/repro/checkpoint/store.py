"""Log-structured incremental checkpointing — the paper's technique as a
first-class training substrate.

Checkpoint state is a KV store problem: keys are tensor paths + shard ids,
values are shard bytes, and every training step *updates* every key — the
update-heavy workload where the paper shows naive KV separation drowns in GC
and naive in-place writes drown in write amplification.  We apply Parallax's
hybrid placement verbatim, with ``p = manifest_entry / (manifest_entry +
payload)``:

* **small** tensors (scalars, norm gains; p > T_SM): inlined in the manifest
  ("in place") — a log pointer would cost as much as the data.
* **large** tensors (embeddings, FFN shards; p < T_ML): appended to a value
  log with per-segment garbage accounting and threshold GC, exactly like the
  paper's Large log.
* **medium** tensors: a *transient log* reclaimed wholesale at every
  consolidation ("last-level compaction") — zero GC walks.

Incremental checkpoints append only changed tensors; ``consolidate()`` is the
last-level compaction: it rewrites live state into a fresh generation and
reclaims every transient segment.  Recovery replays manifests by LSN and
tolerates torn tails (paper §3.4 semantics: recover to a consistent,
possibly-not-last, step).

The same byte-accounting Device model used by the reproduction quantifies
write amplification, so EXPERIMENTS.md can compare hybrid placement against
all-inline and all-log checkpointing on real training traces.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct

import numpy as np

from repro.core.io import Device
from repro.core.model import SizePolicy

from .atomic import atomic_write_bytes

MANIFEST_ENTRY = 64  # key path + offset + len + lsn + crc


@dataclasses.dataclass
class _Entry:
    lsn: int
    step: int
    kind: str          # inline | log | transient
    payload: bytes | None = None   # inline
    segment: int = -1              # log/transient
    offset: int = 0
    length: int = 0


class LogStructuredCheckpointer:
    """Single-host checkpoint region (per host-slice in multi-host runs).

    ``directory`` layout:
        MANIFEST            — append-only JSON-lines redo log (LSN ordered)
        seg-<n>.log         — 2 MB-aligned value-log segments (large tensors)
        tseg-<n>.log        — transient segments (medium tensors)
        gen-<n>/            — consolidated generations (last-level)
    """

    def __init__(
        self,
        directory: str,
        *,
        policy: SizePolicy | None = None,
        t_sm: float = 0.2,
        t_ml: float = 0.02,
        gc_threshold: float = 0.10,
        consolidate_every: int = 8,
        mode: str = "hybrid",  # hybrid | inline (RocksDB-like) | log (BlobDB-like)
    ):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.policy = policy or SizePolicy(t_sm=t_sm, t_ml=t_ml, prefix_size=MANIFEST_ENTRY, pointer_size=16)
        self.gc_threshold = gc_threshold
        self.consolidate_every = consolidate_every
        self.mode = mode
        self.device = Device(cache_bytes=0)
        self.lsn = 0
        self.index: dict[str, _Entry] = {}
        self._seg_live: dict[int, int] = {}
        self._seg_dead: dict[int, int] = {}
        self._seg_size: dict[int, int] = {}
        self._next_seg = 0
        self._tseg_entries: dict[int, int] = {}
        self._next_tseg = 0
        self._steps_since_consolidate = 0
        self.app_bytes = 0

    # ---------------------------------------------------------- classification
    def _classify(self, nbytes: int) -> str:
        if self.mode == "inline":
            return "inline"
        if self.mode == "log":
            return "log"
        p = MANIFEST_ENTRY / (MANIFEST_ENTRY + nbytes)
        if p > self.policy.t_sm:
            return "inline"
        if p < self.policy.t_ml:
            return "log"
        return "transient"

    # ----------------------------------------------------------------- writes
    def save(self, step: int, tree: dict[str, np.ndarray], *, changed: set[str] | None = None) -> dict:
        """Incremental checkpoint: write (changed) tensors + manifest record.

        Both new segment files are published atomically (buffered in full,
        then write-temp/fsync/rename) and the manifest append is fsync'd, so
        a crash mid-save leaves either no trace of the step or complete
        payload files — never a torn segment a later restore would trip on
        (a torn manifest *tail* is fine: restore stops at the last durable
        record, and its payload files were renamed into place first).
        """
        manifest_records = []
        seg_buf = bytearray()
        seg_id = None
        tseg_buf = bytearray()
        tseg_id = None
        for key in sorted(tree):
            if changed is not None and key not in changed and key in self.index:
                continue
            arr = np.asarray(tree[key])
            payload = arr.tobytes() + _meta(arr)
            self.app_bytes += len(payload)
            self.lsn += 1
            kind = self._classify(len(payload))
            old = self.index.get(key)
            if old is not None and old.kind == "log":
                self._seg_dead[old.segment] = self._seg_dead.get(old.segment, 0) + old.length
            if kind == "inline":
                e = _Entry(self.lsn, step, "inline", payload=payload)
                self.device.sequential_write(len(payload) + MANIFEST_ENTRY, 1 << 18, kind="log")
            elif kind == "log":
                if seg_id is None:
                    seg_id = self._next_seg
                    self._next_seg += 1
                off = len(seg_buf)
                seg_buf += payload
                e = _Entry(self.lsn, step, "log", segment=seg_id, offset=off, length=len(payload))
                self._seg_live[seg_id] = self._seg_live.get(seg_id, 0) + len(payload)
                self._seg_size[seg_id] = self._seg_size.get(seg_id, 0) + len(payload)
                self.device.sequential_write(len(payload), 1 << 18, kind="log")
            else:  # transient
                if tseg_id is None:
                    tseg_id = self._next_tseg
                    self._next_tseg += 1
                off = len(tseg_buf)
                tseg_buf += payload
                e = _Entry(self.lsn, step, "transient", segment=tseg_id, offset=off, length=len(payload))
                self._tseg_entries[tseg_id] = self._tseg_entries.get(tseg_id, 0) + 1
                self.device.sequential_write(len(payload), 1 << 18, kind="log")
            self.index[key] = e
            manifest_records.append(_manifest_row(key, e))
        # payloads become durable before the manifest records that point at
        # them (flush-before-record, file edition)
        if seg_id is not None:
            atomic_write_bytes(os.path.join(self.dir, f"seg-{seg_id}.log"), bytes(seg_buf))
        if tseg_id is not None:
            atomic_write_bytes(os.path.join(self.dir, f"tseg-{tseg_id}.log"), bytes(tseg_buf))
        self._append_manifest(manifest_records)
        self.device.sequential_write(len(manifest_records) * MANIFEST_ENTRY, 4096, kind="log")
        self._steps_since_consolidate += 1
        stats = {"written": len(manifest_records), "step": step}
        if self._steps_since_consolidate >= self.consolidate_every:
            stats["consolidated"] = True
            self.consolidate(step)
        self.gc_tick()
        return stats

    def _append_manifest(self, rows: list[dict]) -> None:
        """Durably append manifest records (fsync'd group commit).

        A crash can still tear the appended *tail* — that is the torn-tail
        window restore's JSON replay tolerates by design — but an acked save
        is never lost, and the rows land only after their payload files were
        atomically renamed into place.
        """
        if not rows:
            return
        with open(os.path.join(self.dir, "MANIFEST"), "a") as mf:
            for r in rows:
                mf.write(json.dumps(r) + "\n")
            mf.flush()
            os.fsync(mf.fileno())

    # ----------------------------------------------- last-level consolidation
    def consolidate(self, step: int) -> None:
        """The 'last-level compaction': rewrite live state into gen-<step>,
        reclaim ALL transient segments wholesale (no GC walk), and start a
        fresh manifest.

        Rename-before-truncate ordering: the generation file and the rewritten
        MANIFEST are each published atomically (temp/fsync/rename), and only
        after the new MANIFEST is in place are the transient segments and old
        generations it no longer references destroyed.  A crash anywhere
        leaves either the old MANIFEST (pointing at still-present old files)
        or the new one (pointing at the complete new generation).
        """
        gen_dir = os.path.join(self.dir, f"gen-{step}")
        os.makedirs(gen_dir, exist_ok=True)
        rows = []
        data_buf = bytearray()
        for key, e in sorted(self.index.items()):
            payload = self._read_entry(e)
            if e.kind in ("transient", "gen"):
                # merged in place into the (new) generation file; old
                # generations are deleted below, so 'gen' entries move too
                off = len(data_buf)
                data_buf += payload
                self.device.sequential_write(len(payload), 1 << 21, kind="compaction")
                ne = _Entry(e.lsn, e.step, "gen", segment=step, offset=off, length=len(payload))
            else:
                # inline stays in the manifest; large stays in the value
                # log (its GC handles reclamation)
                ne = e
            self.index[key] = ne
            rows.append(_manifest_row(key, ne))
        atomic_write_bytes(os.path.join(gen_dir, "data.bin"), bytes(data_buf))
        manifest = [json.dumps({"consolidated": step})]
        manifest.extend(json.dumps(r) for r in rows)
        atomic_write_bytes(os.path.join(self.dir, "MANIFEST"),
                           ("\n".join(manifest) + "\n").encode())
        # wholesale transient reclaim — the paper's zero-GC medium path
        for t in list(self._tseg_entries):
            path = os.path.join(self.dir, f"tseg-{t}.log")
            if os.path.exists(path):
                os.unlink(path)
        self._tseg_entries.clear()
        # drop superseded generations
        for d in os.listdir(self.dir):
            if d.startswith("gen-") and d != f"gen-{step}":
                for f in os.listdir(os.path.join(self.dir, d)):
                    os.unlink(os.path.join(self.dir, d, f))
                os.rmdir(os.path.join(self.dir, d))
        self._steps_since_consolidate = 0

    # --------------------------------------------------------------------- GC
    def gc_tick(self) -> int:
        """Threshold GC for the large-tensor value log (paper §3.2).

        Relocation is rename-before-truncate: each surviving payload is
        published in a fresh atomically-written segment and its new location
        durably appended to the MANIFEST *before* the victim segment is
        unlinked — previously the on-disk manifest kept pointing at the
        unlinked file, so any restore after a GC of a mixed live/dead
        segment failed with a missing payload.
        """
        reclaimed = 0
        live_by_seg: dict[int, list[str]] = {}
        for k, e in self.index.items():
            if e.kind == "log":
                live_by_seg.setdefault(e.segment, []).append(k)
        for seg, size in list(self._seg_size.items()):
            dead = self._seg_dead.get(seg, 0)
            if size == 0 or dead / size < self.gc_threshold:
                continue
            self.device.sequential_read(size, 1 << 21, kind="gc")
            moved_rows = []
            for k in live_by_seg.get(seg, []):
                e = self.index[k]
                payload = self._read_entry(e)
                nseg = self._next_seg
                self._next_seg += 1
                atomic_write_bytes(os.path.join(self.dir, f"seg-{nseg}.log"), payload)
                self.device.sequential_write(len(payload), 1 << 18, kind="gc")
                ne = _Entry(e.lsn, e.step, "log", segment=nseg, offset=0, length=len(payload))
                self.index[k] = ne
                self._seg_live[nseg] = len(payload)
                self._seg_size[nseg] = len(payload)
                moved_rows.append(_manifest_row(k, ne))
            self._append_manifest(moved_rows)
            self.device.sequential_write(len(moved_rows) * MANIFEST_ENTRY, 4096, kind="gc")
            path = os.path.join(self.dir, f"seg-{seg}.log")
            if os.path.exists(path):
                os.unlink(path)
            self._seg_size.pop(seg, None)
            self._seg_live.pop(seg, None)
            self._seg_dead.pop(seg, None)
            reclaimed += 1
        return reclaimed

    # ----------------------------------------------------------------- reads
    def _read_entry(self, e: _Entry) -> bytes:
        if e.kind == "inline":
            return e.payload or b""
        if e.kind == "gen":
            path = os.path.join(self.dir, f"gen-{e.segment}", "data.bin")
        elif e.kind == "transient":
            path = os.path.join(self.dir, f"tseg-{e.segment}.log")
        else:
            path = os.path.join(self.dir, f"seg-{e.segment}.log")
        with open(path, "rb") as f:
            f.seek(e.offset)
            return f.read(e.length)

    def restore(self) -> tuple[dict[str, np.ndarray], int]:
        """Replay the manifest (LSN order), falling back step by step.

        Two corruption classes are survivable by construction (paper §3.4:
        recover to a consistent, possibly-not-last, step):

        * a torn manifest *tail* — the JSON replay stops at the last durable
          record;
        * a torn or missing *payload file* (e.g. a segment truncated by a
          crash that predates the atomic-rename discipline) — the replay is
          retried at descending step cutoffs, dropping the newest step's
          records each time, until every referenced payload reads back
          intact.  Earlier rows for the same keys (the previous consistent
          step) win again, exactly as if the bad step had never been saved.

        Raises ``RuntimeError`` only when no cutoff yields a fully readable
        tree — e.g. a shard payload deleted outright, which must fail loudly
        rather than restore zeros.
        """
        self.index.clear()
        path = os.path.join(self.dir, "MANIFEST")
        rows = []
        if os.path.exists(path):
            with open(path) as f:
                for line in f:
                    try:
                        rows.append(json.loads(line))
                    except json.JSONDecodeError:
                        break  # torn tail: stop at the last durable record
        data_rows = [r for r in rows if "consolidated" not in r]
        cutoffs = sorted({r["step"] for r in data_rows}, reverse=True) or [0]
        first_err: tuple[str, Exception] | None = None
        for cutoff in cutoffs:
            index: dict[str, _Entry] = {}
            step = 0
            for r in data_rows:
                if r["step"] > cutoff:
                    continue
                e = _Entry(r["lsn"], r["step"], r["kind"], segment=r.get("segment", -1),
                           offset=r.get("offset", 0), length=r.get("length", 0))
                if r["kind"] == "inline":
                    e.payload = bytes.fromhex(r["payload"])
                index[r["key"]] = e
                step = max(step, r["step"])
            out = {}
            try:
                for k, e in index.items():
                    out[k] = _unmeta(self._read_entry(e))
            except (FileNotFoundError, ValueError, struct.error) as err:
                if first_err is None:
                    first_err = (k, err)
                continue  # torn/missing payload at this step: fall back one
            self.index = index
            return out, step
        bad = f" for {first_err[0]} ({first_err[1]})" if first_err else ""
        raise RuntimeError(f"checkpoint corrupt: missing payload{bad}")

    # ------------------------------------------------------------------ stats
    def write_amplification(self) -> float:
        return self.device.stats.total / max(self.app_bytes, 1)

    def space_bytes(self) -> int:
        total = 0
        for f in os.listdir(self.dir):
            p = os.path.join(self.dir, f)
            if os.path.isfile(p):
                total += os.path.getsize(p)
            else:
                total += sum(os.path.getsize(os.path.join(p, g)) for g in os.listdir(p))
        return total


def _meta(arr: np.ndarray) -> bytes:
    h = json.dumps({"dtype": str(arr.dtype), "shape": list(arr.shape)}).encode()
    return h + struct.pack("<I", len(h))


def _unmeta(payload: bytes) -> np.ndarray:
    (hlen,) = struct.unpack("<I", payload[-4:])
    h = json.loads(payload[-4 - hlen : -4])
    data = payload[: -4 - hlen]
    return np.frombuffer(data, dtype=np.dtype(h["dtype"])).reshape(h["shape"]).copy()


def _manifest_row(key: str, e: _Entry) -> dict:
    row = {"key": key, "lsn": e.lsn, "step": e.step, "kind": e.kind,
           "segment": e.segment, "offset": e.offset, "length": e.length}
    if e.kind == "inline":
        row["payload"] = (e.payload or b"").hex()
    return row
