"""Atomic file replacement: the write-temp/fsync/rename idiom.

The one durable-publication primitive every on-disk artifact in this repo
shares (checkpoint segments, consolidated generations, manifest rewrites,
``repro.api.Engine.snapshot`` manifests): the complete new contents are
written to a temp file *in the same directory*, fsync'd, and then renamed
over the destination.  ``os.replace`` is atomic on POSIX, so a reader (or a
crash) sees either the old file or the complete new one — never a torn
in-place write.

Deliberately dependency-free (``os``/``tempfile`` only) so non-numpy callers
like ``repro.api`` can import it without pulling the checkpoint stack.
"""
from __future__ import annotations

import os
import tempfile


def atomic_write_bytes(path: str, data: bytes) -> None:
    """Atomically replace ``path`` with ``data`` (write-temp/fsync/rename)."""
    directory = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=os.path.basename(path) + ".", suffix=".tmp")
    try:
        os.write(fd, data)
        os.fsync(fd)
    except BaseException:
        os.close(fd)
        os.unlink(tmp)
        raise
    os.close(fd)
    try:
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


__all__ = ["atomic_write_bytes"]
