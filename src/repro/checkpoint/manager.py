"""Sharded checkpoint manager: pytree <-> LogStructuredCheckpointer.

Each host saves only the array shards it owns (``addressable_shards``); keys
are ``<tensor path>@<slice spec>`` (:func:`_idx` — per-dim ``start-stop``
joined by ``_``, or ``scalar``/``full``).  Restore re-applies NamedShardings via
``jax.device_put`` — which makes restoring onto a *different* mesh (elastic
resize, node loss) pure metadata: the same keys are loaded and re-placed
under the new mesh's shardings (see repro.elastic).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

from .store import LogStructuredCheckpointer


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return "/".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, *, mode: str = "hybrid", consolidate_every: int = 8):
        self.host_id = jax.process_index()
        self.store = LogStructuredCheckpointer(
            os.path.join(directory, f"host-{self.host_id}"),
            mode=mode,
            consolidate_every=consolidate_every,
        )

    def save(self, step: int, tree: Any, *, changed: set[str] | None = None) -> dict:
        flat: dict[str, np.ndarray] = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            key = _path_str(path)
            if hasattr(leaf, "addressable_shards"):
                # key on the canonical slice spec alone: it identifies the
                # shard's region exactly, whereas the old replica_id prefix
                # collapsed distinct tuple-indexed shards onto one key
                for sh in leaf.addressable_shards:
                    flat[f"{key}@{_idx(sh)}"] = np.asarray(sh.data)
            else:
                flat[f"{key}@full"] = np.asarray(leaf)
        return self.store.save(step, flat, changed=changed)

    def restore(self, like: Any, shardings: Any | None = None) -> tuple[Any, int]:
        """Rebuild a pytree shaped like ``like`` (abstract ok) from disk."""
        flat, step = self.store.restore()
        grouped: dict[str, dict[str, np.ndarray]] = {}
        for k, v in flat.items():
            base, _, shard = k.rpartition("@")
            grouped.setdefault(base, {})[shard] = v
        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        out = []
        flat_shardings = jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves_with_path)
        for (path, leaf), shard in zip(leaves_with_path, flat_shardings):
            key = _path_str(path)
            parts = grouped.get(key)
            if parts is None:
                raise KeyError(f"checkpoint missing {key}")
            arr = _assemble(parts, leaf.shape, leaf.dtype)
            if shard is not None:
                arr = jax.device_put(arr, shard)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), step

    def stats(self) -> dict:
        return {
            "write_amplification": self.store.write_amplification(),
            "space_bytes": self.store.space_bytes(),
            "device": self.store.device.stats.__dict__,
        }


def _idx(shard) -> str:
    idx = shard.index
    out = []
    for s in idx:
        out.append(f"{s.start or 0}-{s.stop if s.stop is not None else 'end'}")
    return "_".join(out) or "scalar"


def _assemble(parts: dict[str, np.ndarray], shape, dtype) -> np.ndarray:
    """Reassemble one tensor from its shard parts, verifying full coverage.

    Part keys are the canonical slice specs from :func:`_idx` (the whole
    post-``@`` token — per-dim ``start-stop`` specs joined by ``_``, or
    ``scalar`` for 0-d).  Every element must be covered by some part:
    zero-filling a gap would silently restore a missing shard as zeros, so
    incomplete coverage raises instead.
    """
    if "full" in parts:
        return parts["full"].astype(dtype).reshape(shape)
    out = np.zeros(shape, dtype)
    covered = np.zeros(shape, dtype=bool)
    for key, chunk in parts.items():
        slices = []
        for dim, spec in zip(range(len(shape)), key.split("_")):
            start_s, _, stop_s = spec.partition("-")
            start = int(start_s)
            stop = shape[dim] if stop_s == "end" else int(stop_s)
            slices.append(slice(start, stop))
        out[tuple(slices)] = chunk.reshape(out[tuple(slices)].shape)
        covered[tuple(slices)] = True
    if not covered.all():
        missing = int(covered.size - covered.sum())
        raise RuntimeError(
            f"checkpoint incomplete: shard parts {sorted(parts)} leave "
            f"{missing} of {covered.size} elements uncovered for shape {tuple(shape)}"
        )
    return out
