"""Analytical I/O-amplification model from the paper (Section 2).

Implements Equations 1-4 plus the level-capacity ratio R(i) used to bound the
transient-log space amplification (Section 3.3).  These are the quantitative
basis for the hybrid-placement thresholds ``T_SM``/``T_ML`` and are validated
against closed forms in tests and reproduced as paper Fig. 2 in
``benchmarks/bench_model.py``.

All functions are pure and operate on python scalars or jnp arrays so the
curves can be evaluated vectorized.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

# Paper Section 2.2 / Section 4: thresholds on p = key(prefix) / KV size.
T_ML = 0.02  # below this: "large" KV pairs   (log always; GC affordable)
T_SM = 0.20  # above this: "small" KV pairs   (in place; log not worth GC)


def amplification_inplace_sum(levels: int, growth_factor: int, s0: float) -> float:
    """Equation 1 evaluated literally (the explicit per-level double sum).

    ``levels`` is ``l`` (the index of the last level; levels are L0..Ll), so
    there are ``l`` merge boundaries.  ``s0`` is the L0 (memory) size and the
    dataset is ``S_l = s0 * f**l``.  Returns total device traffic D.
    """
    f = growth_factor
    sl = s0 * f**levels
    total = 0.0
    for i in range(levels):  # sub-expression for level i -> i+1
        si = s0 * f**i
        merges = int(round(sl / si))
        read_write_upper = (1 if i == 0 else 2) * si * merges
        lower = 2 * sum(((j - 1) % f) * si for j in range(1, merges + 1))
        total += read_write_upper + lower
    return total


def amplification_inplace(levels: int, growth_factor: int, sl: float = 1.0) -> float:
    """Equation 2 closed form: D = S_l * (l - 1 + f*l)."""
    return sl * (levels - 1 + growth_factor * levels)


def amplification_separated(levels: int, growth_factor: int, p: float, sl: float = 1.0) -> float:
    """Equation 3 closed form: D' = K_l*(l-1+f*l) + S_l with K_l = p*S_l."""
    return p * sl * (levels - 1 + growth_factor * levels) + sl


def separation_benefit(levels: int, growth_factor: int, p):
    """Equation 4: D/D' = (l-1+f*l) / (p*(l-1+f*l) + 1).

    ``p`` may be a scalar or an array; returns the same shape.
    """
    a = levels - 1 + growth_factor * levels
    p = jnp.asarray(p, dtype=jnp.float64 if jnp.array(0.0).dtype == jnp.float64 else jnp.float32)
    return a / (p * a + 1.0)


def capacity_ratio(num_levels: int, growth_factor: int, i: int) -> float:
    """R(i) = (1 - f^(N-i)) / (1 - f^N): fraction of total LSM capacity held by
    the first N-i levels (paper Section 3.3, Fig. 2b).  This bounds the space
    amplification of keeping medium KVs in the transient log until level N-i.
    """
    f = float(growth_factor)
    n = num_levels
    return (1.0 - f ** (n - i)) / (1.0 - f**n)


@dataclasses.dataclass(frozen=True)
class SizePolicy:
    """The paper's size classifier (Section 3.1).

    ``p`` is computed with the *index entry* size as numerator: Parallax stores
    a fixed prefix (12 B) + a log pointer in the index, so the classifier uses
    ``prefix_size`` rather than the full (variable) key, per Section 2.2.
    """

    t_sm: float = T_SM
    t_ml: float = T_ML
    prefix_size: int = 12
    pointer_size: int = 8

    def p_of(self, key_size, value_size):
        """Ratio p for a KV pair; sizes may be scalars or arrays."""
        kv = jnp.asarray(key_size) + jnp.asarray(value_size)
        return jnp.minimum(jnp.asarray(key_size), self.prefix_size) / kv

    def classify(self, key_size, value_size):
        """0 = small (in place), 1 = medium (transient log), 2 = large (log).

        Vectorized: accepts arrays, returns int32 array of categories.
        """
        p = self.p_of(key_size, value_size)
        return jnp.where(p > self.t_sm, 0, jnp.where(p < self.t_ml, 2, 1)).astype(jnp.int32)

    def classify_scalar(self, key_size: int, value_size: int) -> int:
        # pure-python fast path (the store calls this per op; no jnp dispatch)
        p = min(key_size, self.prefix_size) / (key_size + value_size)
        if p > self.t_sm:
            return 0
        if p < self.t_ml:
            return 2
        return 1


def levels_for_dataset(dataset_bytes: float, l0_bytes: float, growth_factor: int) -> int:
    """Number of levels l such that S_l = l0 * f**l >= dataset (min 1)."""
    l = 1
    cap = l0_bytes * growth_factor
    while cap < dataset_bytes:
        l += 1
        cap *= growth_factor
    return l


def expected_benefit_table(levels: int, growth_factor: int, ps: Sequence[float]) -> np.ndarray:
    """Convenience for benchmarks: rows of (p, D/D')."""
    out = []
    for p in ps:
        out.append((p, float(separation_benefit(levels, growth_factor, p))))
    return np.asarray(out)
