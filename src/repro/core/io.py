"""Byte-accounted storage model.

The paper measures I/O amplification as *device traffic (reads+writes) over
application traffic* on an NVMe device.  This container has no block device,
so we model the device as a byte-accounting object that enforces the paper's
access granularities:

* reads from index/log during gets & GC lookups: 4 KB random blocks (§3.4)
* log appends: 256 KB chunks of 2 MB segments (§3.4)
* compaction reads/writes: 2 MB segment granularity (§3.4)
* transient-log fetch during last-level merge: 8 KB sequential sub-reads of
  each segment when sorted, 4 KB random per-KV reads when unsorted (§3.3/§5)

A small block cache models the user-space/mmap cache of Table 1 so that read
traffic (Run A-E, GC lookups) behaves like the paper's: hits are free,
misses cost a 4 KB block read.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

KB = 1024
MB = 1024 * KB

BLOCK = 4 * KB          # random-read granularity
CHUNK = 256 * KB        # log append chunk
SEGMENT = 2 * MB        # allocation / compaction granularity
MERGE_FETCH = 8 * KB    # sorted transient-log fetch granularity


@dataclasses.dataclass
class DeviceStats:
    bytes_read: int = 0
    bytes_written: int = 0
    read_ops: int = 0
    write_ops: int = 0
    # attributed sub-counters (all already included in the totals above)
    gc_read: int = 0
    gc_written: int = 0
    compaction_read: int = 0
    compaction_written: int = 0
    log_written: int = 0
    meta_written: int = 0       # shard-metadata WAL records (boundary/migration)
    get_read: int = 0
    # lifetime-class breakdown (repro.core.lifetime): the short-lived value
    # log's traffic, *also* included in gc_read/log_written above so the
    # aggregate counters keep their meaning with lifetime on or off
    gc_short_read: int = 0      # GC identification reads over short-class logs
    short_log_written: int = 0  # appends (writes + relocations) to short logs

    @property
    def total(self) -> int:
        return self.bytes_read + self.bytes_written

    def snapshot(self) -> "DeviceStats":
        return dataclasses.replace(self)

    def delta(self, since: "DeviceStats") -> "DeviceStats":
        return DeviceStats(
            **{
                f.name: getattr(self, f.name) - getattr(since, f.name)
                for f in dataclasses.fields(self)
            }
        )


class BlockCache:
    """LRU cache of 4 KB block ids (models Table 1 cache / mmap DRAM)."""

    def __init__(self, capacity_bytes: int):
        self.capacity_blocks = max(0, capacity_bytes // BLOCK)
        self._lru: OrderedDict[int, None] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def access(self, block_id: int) -> bool:
        """Touch a block; returns True on hit."""
        if self.capacity_blocks == 0:
            self.misses += 1
            return False
        if block_id in self._lru:
            self._lru.move_to_end(block_id)
            self.hits += 1
            return True
        self.misses += 1
        self._lru[block_id] = None
        if len(self._lru) > self.capacity_blocks:
            self._lru.popitem(last=False)
        return False

    def invalidate_range(self, first_block: int, nblocks: int) -> None:
        for b in range(first_block, first_block + nblocks):
            self._lru.pop(b, None)


class Device:
    """Byte-accounting device with granularity rounding and a block cache.

    Offsets are virtual: the allocator hands out segment-aligned extents and
    the device only tracks traffic, not contents (contents live in the store's
    functional state).  ``bandwidth`` numbers are used by benchmarks to turn
    byte counts into a device-time proxy (Intel P4800X-like: ~2.4/2.0 GB/s).
    """

    def __init__(
        self,
        cache_bytes: int = 0,
        read_bw: float = 2.4e9,
        write_bw: float = 2.0e9,
        segment_bytes: int = SEGMENT,
        chunk_bytes: int = CHUNK,
    ):
        self.stats = DeviceStats()
        self.cache = BlockCache(cache_bytes)
        self.read_bw = read_bw
        self.write_bw = write_bw
        self.segment_bytes = segment_bytes
        self.chunk_bytes = chunk_bytes
        self._next_segment = 0
        self._free_segments: list[int] = []

    # -- allocation ---------------------------------------------------------
    def alloc_segment(self) -> int:
        """Returns the segment-aligned device offset of a fresh segment."""
        if self._free_segments:
            return self._free_segments.pop()
        off = self._next_segment * self.segment_bytes
        self._next_segment += 1
        return off

    def free_segment(self, offset: int) -> None:
        assert offset % self.segment_bytes == 0, offset
        self.cache.invalidate_range(offset // BLOCK, self.segment_bytes // BLOCK)
        self._free_segments.append(offset)

    @property
    def allocated_segments(self) -> int:
        return self._next_segment - len(self._free_segments)

    # -- raw accounting -----------------------------------------------------
    def _read(self, nbytes: int, ops: int, kind: str) -> None:
        self.stats.bytes_read += nbytes
        self.stats.read_ops += ops
        if kind == "gc":
            self.stats.gc_read += nbytes
        elif kind == "gc_short":
            self.stats.gc_read += nbytes
            self.stats.gc_short_read += nbytes
        elif kind == "compaction":
            self.stats.compaction_read += nbytes
        elif kind == "get":
            self.stats.get_read += nbytes

    def _write(self, nbytes: int, ops: int, kind: str) -> None:
        self.stats.bytes_written += nbytes
        self.stats.write_ops += ops
        if kind == "gc":
            self.stats.gc_written += nbytes
        elif kind == "compaction":
            self.stats.compaction_written += nbytes
        elif kind == "log":
            self.stats.log_written += nbytes
        elif kind == "short_log":
            self.stats.log_written += nbytes
            self.stats.short_log_written += nbytes
        elif kind == "meta":
            self.stats.meta_written += nbytes

    # -- modeled operations --------------------------------------------------
    # contract: single-threaded
    def random_read(self, offset: int, nbytes: int, kind: str = "get") -> None:
        """4 KB-granular random read through the block cache."""
        first = offset // BLOCK
        last = (offset + max(1, nbytes) - 1) // BLOCK
        miss_blocks = sum(0 if self.cache.access(b) else 1 for b in range(first, last + 1))
        if miss_blocks:
            self._read(miss_blocks * BLOCK, miss_blocks, kind)

    def sequential_read(self, nbytes: int, granularity: int = SEGMENT, kind: str = "compaction") -> None:
        """Direct-I/O sequential read (bypasses cache, like compaction reads)."""
        if nbytes <= 0:
            return
        ops = -(-nbytes // granularity)
        self._read(ops * min(granularity, max(nbytes, 1)) if ops == 1 else nbytes, ops, kind)

    # contract: single-threaded
    def sequential_write(self, nbytes: int, granularity: int = CHUNK, kind: str = "log") -> None:
        """Direct-I/O append/compaction write at chunk/segment granularity."""
        if nbytes <= 0:
            return
        ops = -(-nbytes // granularity)
        self._write(nbytes, ops, kind)

    def device_time(self, stats: DeviceStats | None = None) -> float:
        s = stats or self.stats
        return s.bytes_read / self.read_bw + s.bytes_written / self.write_bw


# ---------------------------------------------------------------- overlap
# Sharded front-ends own one Device per shard; turning N per-device times
# into one completion time is a *policy*, and the paper's headline wins come
# precisely from which policy the execution engine can realize (overlapped,
# mostly-sequential I/O keeping the NVMe device busy).  Three are modeled:
#
# * ``serial``       — no overlap: the batch waits for every device in turn
#                      (one channel; what shard-by-shard execution realizes)
# * ``ideal``        — perfect overlap: the slowest device bounds the batch
#                      (infinite channels; the former ``device_time = max``)
# * ``channels:k``   — k parallel NVMe channels: per-shard times are packed
#                      onto k channels LPT-first (longest processing time on
#                      the least-loaded channel) and the makespan is the
#                      completion time.  ``channels:1 == serial``;
#                      ``channels:k >= N == ideal``.
#
# ``repro.core.exec.ShardExecutor``'s paced mode turns the same per-shard
# times into *measured* wall-clock so model and measurement can be compared
# per benchmark (see docs/execution.md).

OVERLAP_POLICIES = ("serial", "ideal", "channels:<k>")


def overlap_time(times: "list[float]", policy: str = "ideal") -> float:
    """Combine per-device busy times into one completion time under a policy.

    ``policy`` is ``"serial"``, ``"ideal"``, or ``"channels:k"`` (k >= 1).
    LPT packing is deterministic: ties go to the lowest-indexed channel, and
    equal times keep their input order (Python's sort is stable).
    """
    ts = [t for t in times if t > 0.0]
    if not ts:
        return 0.0
    if policy == "serial":
        return float(sum(ts))
    if policy == "ideal":
        return float(max(ts))
    if policy.startswith("channels:"):
        k = int(policy.split(":", 1)[1])
        if k < 1:
            raise ValueError(f"channels policy needs k >= 1, got {k}")
        loads = [0.0] * min(k, len(ts))
        for t in sorted(ts, reverse=True):
            i = min(range(len(loads)), key=loads.__getitem__)
            loads[i] += t
        return max(loads)
    raise ValueError(f"unknown overlap policy {policy!r}; expected one of {OVERLAP_POLICIES}")
