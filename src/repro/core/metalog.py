"""Persistent shard-metadata WAL with crash-point fault injection.

PR 2 modeled the range-sharded boundary map as "a tiny WAL'd metadata record"
— an in-memory atomic flip that was asserted, never exercised.  This module
makes it real: every boundary change, shard create/retire, and migration
checkpoint is a :class:`MetadataLog` record, appended through the same
redo-record idiom the store uses (``Log`` append + flush, charged to the
device) and replayed by ``RangeShardedStore.recover()`` to rebuild the
topology — including an in-flight incremental migration, which resumes from
its last durable checkpoint instead of relying on a modeled atomic flip.

Durability model: metadata records are *synchronous* — each ``append`` flushes
before returning (a group commit per record, like the store's redo record),
so a crash never loses an acknowledged record.  The interesting crash windows
are therefore exactly the record *sites*: the instants just before each record
becomes durable, where the protocol has done some data-path work (copies,
flushes, tombstones) that the next record would cover.  The
:meth:`crash_after` hook enumerates them for the fault-injection harness
(``tests/test_crashpoints.py``): with ``crash_after(n)`` armed, the append
that would write record ``n`` (0-based: the ``n+1``-th overall) raises
:class:`CrashPoint` instead — exactly ``n`` records are durable, and the
caller's in-memory state is whatever the protocol had built up to that
un-acknowledged append (the protocol is record-then-apply, so replay of the
``n`` durable records reconstructs a consistent topology).

Record payload bytes are charged to the device with ``kind="meta"`` so the
metadata WAL shows up in amplification stats (``DeviceStats.meta_written``).
"""
from __future__ import annotations

import threading

from .io import Device
from .logs import Log, LogEntry, Pointer
from .lsm import CAT_SMALL


class CrashPoint(RuntimeError):
    """Injected crash at a metadata-WAL record site (see ``crash_after``)."""

    def __init__(self, site: int):
        super().__init__(f"injected crash at metadata-WAL record site {site}")
        self.site = site


def _encode(record: dict) -> bytes:
    """Deterministic record serialization (modeled: size is what matters)."""
    return repr(sorted(record.items())).encode()


class MetadataLog:
    """Append-only, synchronously-committed log of shard-metadata records.

    Records are plain dicts with a ``"kind"`` field; the log keeps them in
    append order for replay and charges their encoded size to the device
    (``kind="meta"``).  ``replay()`` reconstructs from the oldest retained
    record — the ``init`` record at genesis, or a ``snapshot`` record once
    :meth:`truncate` has dropped the prefix it replaces (PR 7): recovery then
    replays O(delta) records instead of O(history).  Truncation is pure
    bookkeeping surgery — dropped records are marked dead in their segments
    and fully-dead non-tail segments are reclaimed; no device traffic is
    charged (``bytes_appended`` stays monotonic, ``log_bytes`` shrinks).

    Background-checkpoint ordering (PR 4): the WAL's correctness rests on
    record order matching protocol-apply order — a ``checkpoint`` committed
    before its batch's destination flush (or two interleaved appends) would
    break the record-then-apply replay.  With the async engine, migration
    ticks run only at executor *sequence points* (no foreground tasks in
    flight), so appends stay totally ordered even when migration runs "in the
    background"; :meth:`append` asserts the single-writer invariant with a
    non-blocking lock and raises on concurrent entry rather than interleave.
    """

    # contract: coordinator-only
    def __init__(self, device: Device):
        self.device = device
        self._log = Log(device, "meta", kind="meta")
        self.records: list[dict] = []
        self._ptrs: list[Pointer] = []  # device slot of each retained record
        self.total_appended = 0  # monotonic: crash sites survive truncation
        self._crash_after: int | None = None
        self._append_lock = threading.Lock()

    @property
    def n_records(self) -> int:
        return len(self.records)

    @property
    def bytes_appended(self) -> int:
        return self._log.appended_bytes

    @property
    def log_bytes(self) -> int:
        """Bytes of retained (non-reclaimed) segments — shrinks on truncate."""
        return self._log.total_bytes

    # ---------------------------------------------------------------- append
    def append(self, record: dict) -> int:
        """Durably append one record; returns its index.

        Raises :class:`CrashPoint` instead of appending when an injected
        crash is armed at this site (``crash_after``) — the record is *not*
        written, modeling a power cut between the protocol action and its
        metadata commit.
        """
        if not self._append_lock.acquire(blocking=False):
            raise RuntimeError(
                f"concurrent MetadataLog.append of kind="
                f"{record.get('kind') if isinstance(record, dict) else record!r}"
                f" at LSN {self.total_appended}: metadata records must be "
                "totally ordered (append only from executor sequence points)"
            )
        try:
            # crash sites count *appends since genesis* (total_appended), not
            # retained records — truncation must not renumber armed sites
            if self._crash_after is not None and self.total_appended >= self._crash_after:
                raise CrashPoint(self.total_appended)
            payload = _encode(record)
            ptr = self._log.append(LogEntry(self.total_appended + 1, b"", payload, CAT_SMALL))
            self._log.flush()  # synchronous commit: an acked record is never lost
            self.records.append(dict(record))
            self._ptrs.append(ptr)
            self.total_appended += 1
            return len(self.records) - 1
        finally:
            self._append_lock.release()

    # -------------------------------------------------------------- truncate
    def truncate(self, upto: int) -> int:
        """Drop the first ``upto`` retained records; returns how many dropped.

        The caller must have made the remaining stream self-contained first —
        i.e. ``records[upto]`` is a ``snapshot`` record that replaces the
        dropped prefix (rename-before-truncate: the replacement is durable
        *before* the prefix is destroyed; see ``docs/durability.md``).  The
        surgery is segment bookkeeping only: dropped records are marked dead
        and segments that end up fully dead (except the append tail) are
        reclaimed.  No device I/O is charged — crash sites
        (``total_appended``) and ``bytes_appended`` are unaffected.
        """
        if not 0 <= upto <= len(self.records):
            raise ValueError(
                f"truncate({upto}) out of range: {len(self.records)} records retained"
            )
        if upto == 0:
            return 0
        for ptr in self._ptrs[:upto]:
            self._log.mark_dead(ptr)
        del self.records[:upto]
        del self._ptrs[:upto]
        for seg in self._log.iter_segments():
            if seg.live_bytes == 0 and seg is not self._log._tail:
                self._log.reclaim(seg.segment_id)
        return upto

    def replay(self) -> list[dict]:
        """The durable record stream, oldest first (for recovery replay)."""
        return list(self.records)

    # -------------------------------------------------------- fault injection
    def crash_after(self, n_records: int) -> None:
        """Arm an injected crash: the append of record ``n_records`` raises.

        ``n_records`` counts *all appends since genesis* (``total_appended``,
        which truncation never rewinds), so a harness that wants to crash at
        the ``k``-th site of a scenario arms
        ``crash_after(log.total_appended + k)`` before driving it.  Appends below
        the armed site proceed normally; the log stays readable (recovery
        replays the durable prefix).  Disarm with :meth:`disarm`.
        """
        if n_records < 0:
            raise ValueError(f"crash site must be >= 0, got {n_records}")
        self._crash_after = n_records

    def disarm(self) -> None:
        self._crash_after = None


__all__ = ["CrashPoint", "MetadataLog"]
