"""YCSB workload generator (paper §4, Table 1).

Key/value sizes follow the paper exactly: keys average 24 B; values are 9 B
(small), 104 B (medium), 1004 B (large), giving p = 0.72 / 0.19 / 0.02 with a
12 B prefix.  Mixes: S/M/L are single-size, SD/MD/LD are 60-20-20 dominant
mixes.  Operation mixes follow standard YCSB:

* Load A/E : 100% insert
* Run A    : 50% update / 50% read
* Run B    : 5% update / 95% read
* Run C    : 100% read
* Run D    : 5% insert / 95% read (latest distribution)
* Run E    : 5% insert / 95% scan (short ranges)

Key popularity is zipfian (theta 0.99) like YCSB's default.  The generator is
deterministic given a seed and yields batched numpy arrays so benchmarks can
drive millions of ops without Python-loop overhead in generation.

``execute`` drives any store through an op stream; with ``batch_size > 0`` it
groups consecutive same-kind ops and dispatches them through the batched
``put_many``/``update_many``/``get_many`` APIs of
:class:`repro.core.shard.ShardedStore` (falling back to per-op calls for
stores without them), preserving stream order and visible state.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Iterator

import numpy as np

# warn-once registry for the legacy driver shims (PR 5): the canonical entry
# point is repro.api.execute on a repro.api.open() engine; these module-level
# drivers keep working for one release but nag exactly once per process.
# repro.api.reset_deprecation_warnings() clears this (tests/test_deprecations).
_DEPRECATED_WARNED: set[str] = set()


def _warn_deprecated(symbol: str, replacement: str) -> None:
    if symbol in _DEPRECATED_WARNED:
        return
    _DEPRECATED_WARNED.add(symbol)
    warnings.warn(
        f"{symbol} is deprecated; use {replacement}",
        DeprecationWarning,
        stacklevel=3,
    )

KEY_SIZE = 24
VALUE_SIZES = {"small": 9, "medium": 104, "large": 1004}

MIXES = {  # name -> (small%, medium%, large%)
    "S": (100, 0, 0),
    "M": (0, 100, 0),
    "L": (0, 0, 100),
    "SD": (60, 20, 20),
    "MD": (20, 60, 20),
    "LD": (20, 20, 60),
}

OP_MIXES = {  # name -> dict(op -> fraction)
    "load_a": {"insert": 1.0},
    "load_e": {"insert": 1.0},
    "run_a": {"update": 0.5, "read": 0.5},
    "run_b": {"update": 0.05, "read": 0.95},
    "run_c": {"read": 1.0},
    "run_d": {"insert": 0.05, "read": 0.95},
    "run_e": {"insert": 0.05, "scan": 0.95},
}


@dataclasses.dataclass(frozen=True)
class Op:
    kind: str            # insert | update | read | scan
    key: bytes
    value_size: int = 0  # bytes (payload synthesized on demand)
    scan_len: int = 0


class ZipfGenerator:
    """Bounded zipfian over [0, n) with YCSB's theta=0.99 (rejection-free CDF)."""

    def __init__(self, n: int, theta: float = 0.99, seed: int = 0):
        self.n = n
        ranks = np.arange(1, n + 1, dtype=np.float64)
        weights = 1.0 / np.power(ranks, theta)
        self.cdf = np.cumsum(weights / weights.sum())
        self.rng = np.random.default_rng(seed)
        # shuffle rank->key mapping so hot keys are spread over the keyspace
        self.perm = self.rng.permutation(n)

    def sample(self, count: int) -> np.ndarray:
        u = self.rng.random(count)
        ranks = np.searchsorted(self.cdf, u)
        return self.perm[ranks]


def make_key(i: int) -> bytes:
    return b"user" + str(i).zfill(KEY_SIZE - 4).encode()


def _sizes_for(mix: str, rng: np.random.Generator, count: int) -> np.ndarray:
    s, m, l = MIXES[mix]
    cats = rng.choice(3, size=count, p=np.array([s, m, l]) / 100.0)
    sizes = np.array([VALUE_SIZES["small"], VALUE_SIZES["medium"], VALUE_SIZES["large"]])
    return sizes[cats]


@dataclasses.dataclass
class Workload:
    """A YCSB phase.  ``hot_update_frac``/``hot_update_keys`` add an
    update-distance skew on top of the zipfian key popularity: that fraction
    of the update ops is redirected to a small working set drawn from the
    zipf *head* (the already-popular keys), so their inter-update distances
    collapse — the short-lifetime population the lifetime sketch
    (:mod:`repro.core.lifetime`) is built to catch.  At the default ``0.0``
    no extra randomness is drawn and op streams are byte-identical to
    pre-knob workloads."""

    name: str            # e.g. 'load_a'
    mix: str             # e.g. 'SD'
    num_keys: int        # loaded keyspace size
    num_ops: int         # operations to run (for run_* phases)
    seed: int = 7
    scan_len: int = 50
    hot_update_frac: float = 0.0   # fraction of updates redirected to the hot set
    hot_update_keys: int = 64      # hot working-set size (clamped to num_keys)

    def __post_init__(self):
        if not 0.0 <= self.hot_update_frac <= 1.0:
            raise ValueError(f"hot_update_frac must be in [0, 1], got {self.hot_update_frac}")
        if self.hot_update_keys < 1:
            raise ValueError(f"hot_update_keys must be >= 1, got {self.hot_update_keys}")

    def load_ops(self) -> Iterator[Op]:
        """The load phase: insert every key once, sizes drawn from the mix."""
        rng = np.random.default_rng(self.seed)
        sizes = _sizes_for(self.mix, rng, self.num_keys)
        order = rng.permutation(self.num_keys)
        for i in order:
            yield Op("insert", make_key(int(i)), int(sizes[i]))

    def run_ops(self) -> Iterator[Op]:
        rng = np.random.default_rng(self.seed + 1)
        zipf = ZipfGenerator(self.num_keys, seed=self.seed + 2)
        opmix = OP_MIXES[self.name]
        kinds = list(opmix.keys())
        probs = np.array([opmix[k] for k in kinds])
        choices = rng.choice(len(kinds), size=self.num_ops, p=probs)
        keys = zipf.sample(self.num_ops)
        sizes = _sizes_for(self.mix, rng, self.num_ops)
        # the hot-update stream uses its own generator, drawn ONLY when the
        # knob is on: the base streams above stay byte-identical regardless
        hot_u = hot_pick = None
        if self.hot_update_frac > 0.0:
            hot_rng = np.random.default_rng(self.seed + 3)
            hot_u = hot_rng.random(self.num_ops)
            hot_pick = hot_rng.integers(
                0, min(self.hot_update_keys, self.num_keys), size=self.num_ops
            )
        next_insert = self.num_keys
        for i, (c, k, sz) in enumerate(zip(choices, keys, sizes)):
            kind = kinds[c]
            if kind == "insert":
                yield Op("insert", make_key(next_insert), int(sz))
                next_insert += 1
            elif kind == "update":
                if hot_u is not None and hot_u[i] < self.hot_update_frac:
                    # hot set = the zipf head ranks, mapped through the same
                    # rank->key shuffle the zipf sampler uses
                    k = zipf.perm[hot_pick[i]]
                yield Op("update", make_key(int(k)), int(sz))
            elif kind == "read":
                yield Op("read", make_key(int(k)))
            else:
                yield Op("scan", make_key(int(k)), scan_len=self.scan_len)


_PAYLOAD = bytes(range(256)) * 8  # 2 KB of deterministic filler


def payload(size: int) -> bytes:
    return _PAYLOAD[:size]


def _flush_batch(store, kind: str, batch: list[Op]) -> None:
    """Dispatch one same-kind batch, batched API when the store has one."""
    if not batch:
        return
    if kind == "insert":
        items = [(op.key, payload(op.value_size)) for op in batch]
        if hasattr(store, "put_many"):
            store.put_many(items)
        else:
            for k, v in items:
                store.put(k, v)
    elif kind == "update":
        items = [(op.key, payload(op.value_size)) for op in batch]
        if hasattr(store, "update_many"):
            store.update_many(items)
        else:
            for k, v in items:
                store.update(k, v)
    elif kind == "read":
        keys = [op.key for op in batch]
        if hasattr(store, "get_many"):
            store.get_many(keys)
        else:
            for k in keys:
                store.get(k)
    else:
        for op in batch:
            store.scan(op.key, op.scan_len)


def execute(store, ops: Iterator[Op], gc_every: int = 0, batch_size: int = 0,
            migrate_budget: int = 0) -> dict:
    """Deprecated shim for :func:`_execute` — the serial op-stream driver.

    Use :func:`repro.api.execute` on an engine from :func:`repro.api.open`
    instead: one driver covers every partitioning × execution combination.
    Warns :class:`DeprecationWarning` once per process, then delegates
    unchanged (the differential oracle still replays legacy paths through it).
    """
    _warn_deprecated("repro.core.ycsb.execute",
                     "repro.api.execute(engine, ops, ...) on a repro.api.open() engine")
    return _execute(store, ops, gc_every=gc_every, batch_size=batch_size,
                    migrate_budget=migrate_budget)


def _execute(store, ops: Iterator[Op], gc_every: int = 0, batch_size: int = 0,
             migrate_budget: int = 0) -> dict:
    """Drive a store through an op stream; returns op counts.

    ``batch_size == 0`` (the default) issues one call per op — the original
    single-store path.  With ``batch_size > 0``, consecutive ops of the same
    kind are grouped and dispatched through the store's batched APIs
    (``put_many``/``update_many``/``get_many``, e.g.
    :class:`repro.core.shard.ShardedStore`) when present, falling back to
    per-op calls otherwise.  Batches never cross a kind boundary and apply in
    stream order, so visible state is identical to the sequential path.

    ``migrate_budget > 0`` gives the driver explicit control of incremental
    rebalancing: after every dispatched batch (every op in per-op mode), a
    store exposing ``migration_tick``
    (:class:`repro.core.range_shard.RangeShardedStore`)
    advances its in-flight migration by at most that many keys — the tick
    budget that amortizes shard migration against foreground batches.  Stores
    without the hook ignore it.  (Such stores also self-tick one
    ``migration_batch_keys`` batch at each batch boundary; the explicit
    budget adds driver-paced ticks on top, e.g. to throttle or accelerate.)
    """
    counts = {"insert": 0, "update": 0, "read": 0, "scan": 0}
    tickable = migrate_budget > 0 and hasattr(store, "migration_tick")

    def _tick() -> None:
        if tickable:
            store.migration_tick(migrate_budget)

    if batch_size <= 0:
        # per-op mode: every op is its own "batch", so the driver-paced tick
        # fires after each one
        for n, op in enumerate(ops, 1):
            if op.kind == "insert":
                store.put(op.key, payload(op.value_size))
            elif op.kind == "update":
                store.update(op.key, payload(op.value_size))
            elif op.kind == "read":
                store.get(op.key)
            else:
                store.scan(op.key, op.scan_len)
            counts[op.kind] += 1
            _tick()
            if gc_every and n % gc_every == 0:
                store.gc_tick()
        store.gc_tick()
        return counts

    for ev, kind, batch in _batch_events(ops, batch_size, gc_every, counts):
        if ev == "flush":
            _flush_batch(store, kind, batch)
            _tick()
        else:
            store.gc_tick()
    store.gc_tick()
    return counts


def _batch_events(ops: Iterator[Op], batch_size: int, gc_every: int,
                  counts: dict) -> Iterator[tuple[str, str | None, list[Op]]]:
    """The batch-mode schedule shared by :func:`execute` and
    :func:`execute_async`: yields ``("flush", kind, batch)`` at every batch
    boundary (kind change, full batch, gc position, stream tail) and
    ``("gc", ...)`` at every ``gc_every`` position.  Both drivers consume this
    one generator, so their flush/tick/gc *positions* are identical by
    construction — the async path's byte-identical-to-serial contract cannot
    drift out from under the differential oracle via a one-sided edit.
    ``counts`` is mutated in place (per-op, as ops are consumed)."""
    batch: list[Op] = []
    kind: str | None = None
    n = 0
    for op in ops:
        if kind is not None and (op.kind != kind or len(batch) >= batch_size):
            yield ("flush", kind, batch)
            batch = []
        kind = op.kind
        batch.append(op)
        counts[op.kind] += 1
        n += 1
        if gc_every and n % gc_every == 0:
            yield ("flush", kind, batch)
            yield ("gc", None, [])
            batch, kind = [], None
    if kind is not None:
        yield ("flush", kind, batch)


def _flush_batch_async(ex, kind: str, batch: list[Op]) -> None:
    """Async mirror of :func:`_flush_batch`: shard sub-batches go to the
    executor's queues; the per-batch policy hook (which the store's batched
    ops run inline on the serial path) becomes an executor sequence point.
    Scans run *as* sequence points — :meth:`ShardExecutor.scan` delegates to
    the store's own ``scan``, which feeds the skew window / ticks the policy
    internally, exactly like the serial path."""
    if not batch:
        return
    if kind == "insert":
        ex.put_many([(op.key, payload(op.value_size)) for op in batch])
        ex.after_batch()
    elif kind == "update":
        ex.update_many([(op.key, payload(op.value_size)) for op in batch])
        ex.after_batch()
    elif kind == "read":
        ex.get_many([op.key for op in batch])
        ex.after_batch()
    else:
        for op in batch:
            ex.scan(op.key, op.scan_len)


def execute_async(store, ops: Iterator[Op], *, batch_size: int = 64,
                  workers: int = 4, pipeline: bool = True, gc_every: int = 0,
                  migrate_budget: int = 0, pace: float = 0.0,
                  executor=None) -> dict:
    """Deprecated shim for :func:`_execute_async` — the async-engine driver.

    Use :func:`repro.api.execute` on an engine opened with
    ``execution="async"`` instead.  Warns :class:`DeprecationWarning` once per
    process, then delegates unchanged.
    """
    _warn_deprecated("repro.core.ycsb.execute_async",
                     "repro.api.execute(engine, ops, ...) on an engine opened "
                     "with execution='async'")
    return _execute_async(store, ops, batch_size=batch_size, workers=workers,
                          pipeline=pipeline, gc_every=gc_every,
                          migrate_budget=migrate_budget, pace=pace,
                          executor=executor)


def _execute_async(store, ops: Iterator[Op], *, batch_size: int = 64,
                   workers: int = 4, pipeline: bool = True, gc_every: int = 0,
                   migrate_budget: int = 0, pace: float = 0.0,
                   executor=None) -> dict:
    """Drive a sharded store through an op stream on the async engine.

    Same batching semantics as :func:`execute` with ``batch_size > 0`` —
    consecutive same-kind ops group into batches, policy hooks and the
    optional ``migrate_budget`` tick fire at the same batch boundaries, GC at
    the same ``gc_every`` positions — but batches are routed on the calling
    thread and drained by :class:`repro.core.exec.ShardExecutor`'s per-shard
    queues, pipelined ``pipeline`` deep with ``workers`` pool threads.  The
    scheduling discipline makes results, stats and per-shard device traffic
    byte-identical to ``execute(store, ops, batch_size=batch_size,
    gc_every=gc_every, migrate_budget=migrate_budget)``
    (``tests/test_exec.py``); only wall-clock changes.  ``pace`` converts
    modeled device time into real (GIL-releasing) sleeps so the overlap is
    measurable — see the executor's module docstring.

    Pass ``executor`` to reuse a caller-managed :class:`ShardExecutor`
    (left open on return); otherwise one is created and closed here.
    """
    from .exec import ShardExecutor  # late import: exec builds on this module's peers

    if batch_size < 1:
        raise ValueError("execute_async needs batch_size >= 1 (per-op mode is serial-only)")
    ex = executor or ShardExecutor(store, workers, pipeline=pipeline, pace=pace)
    counts = {"insert": 0, "update": 0, "read": 0, "scan": 0}
    tickable = migrate_budget > 0 and hasattr(store, "migration_tick")

    def _tick() -> None:
        if tickable:
            ex.migration_tick(migrate_budget)

    try:
        for ev, kind, batch in _batch_events(ops, batch_size, gc_every, counts):
            if ev == "flush":
                _flush_batch_async(ex, kind, batch)
                _tick()
            else:
                ex.gc_tick()
        ex.gc_tick()
        ex.drain()
    finally:
        if executor is None:
            ex.close(wait=False)
    return counts
