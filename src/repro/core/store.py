"""Parallax: an LSM KV store with hybrid key-value placement (paper §3).

One class implements all four system modes evaluated in the paper:

* ``parallax`` — hybrid placement: small in place, large in the Large log
  (with segment GC), medium in the transient log merged in place at the last
  ``merge_depth`` level(s)  (§3.1–§3.3).
* ``rocksdb``  — everything in place (the RocksDB baseline).
* ``blobdb``   — full KV separation: everything in the value log, periodic
  scan-30% GC after compactions (the BlobDB baseline).
* ``nomerge``  — Fig. 8's non-achievable ideal: mediums stay in the log
  forever, no GC and no in-place merge.

Parallax-MS / Parallax-ML (Fig. 7) are the ``parallax`` mode with collapsed
thresholds (``t_sm == t_ml``).

The store is functionally correct (put/get/update/delete/scan with LSN
ordering, tombstones, crash/recover) and every byte that would touch the
device flows through :class:`repro.core.io.Device`, which is how the
benchmarks reproduce the paper's amplification numbers.

Read path: point lookups consult a per-level bloom filter (rebuilt with each
compaction, ``StoreConfig.bloom_bits_per_key``; 0/off by default so the bare
store reproduces the paper's filterless index) before paying the leaf probe;
skipped levels are counted in ``StoreStats.bloom_skips``.  All hashing on the
read path (cache-block choice, bloom probes) uses ``zlib.crc32`` so traffic
and stats are bit-identical across processes — ``hash()`` is randomized by
``PYTHONHASHSEED`` and must not be used here.

For the sharded batch front-end layered on top of this class see
:class:`repro.core.shard.ShardedStore`.

Thread-safety audit (PR 4, see docs/execution.md): a ``ParallaxStore`` is
**single-threaded by contract** — nothing in here takes a lock.  ``StoreStats``
counter bumps, ``BlockCache``'s ``OrderedDict`` LRU moves, ``Device`` byte
accounting, L0 dict mutation, level rebuilds and log segment lists are all
plain mutations that would race under concurrent callers.  The async engine
(:class:`repro.core.exec.ShardExecutor`) therefore never lets two tasks touch
one store: every task runs on its shard's FIFO queue (a migration's src/dst
pair shares one queue, since double-routed reads touch both), and each task
additionally asserts exclusivity with a non-blocking per-store lock acquire —
a failed acquire means the shard-independence invariant broke, and the
executor raises rather than silently corrupting stats.  ``flush_all``/
``crash``/``recover`` and topology mutations run only at executor sequence
points (no tasks in flight).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import zlib
from typing import Iterable, Iterator

from .io import BLOCK, SEGMENT, Device
from .lifetime import CLASS_LONG, CLASS_SHORT, LifetimeConfig, LifetimeSketch, propose_cutoffs
from .logs import Log, LogEntry, Pointer, TransientLog
from .lsm import CAT_LARGE, CAT_MEDIUM, CAT_SMALL, IndexEntry, Level, merge_runs
from .model import SizePolicy

# virtual address regions so leaf probes of different levels hit different
# cache blocks (logs get their own offsets from the allocator)
_LEVEL_REGION = 1 << 40


@dataclasses.dataclass
class StoreStats:
    inserts: int = 0
    updates: int = 0
    deletes: int = 0
    gets: int = 0
    scans: int = 0
    found: int = 0
    app_bytes: int = 0          # application traffic (user KV bytes in+out)
    index_probes: int = 0       # binary-search leaf probes
    bloom_skips: int = 0        # levels skipped by a negative bloom answer
    entries_merged: int = 0     # compaction merge work
    gc_lookups: int = 0         # GC validity lookups (paper 'lookup cost')
    gc_relocations: int = 0     # GC relocations (paper 'cleanup cost')
    compactions: int = 0
    # lifetime-aware placement (repro.core.lifetime; all zero when disabled)
    gc_short_lookups: int = 0   # lookup cost paid sweeping short-class logs
    gc_short_relocations: int = 0   # relocations out of short-class segments
    class_migrations: int = 0   # GC relocations that changed lifetime class
    cutoff_adaptations: int = 0  # adaptive t_ml cutovers applied


@dataclasses.dataclass
class StoreConfig:
    mode: str = "parallax"               # parallax | rocksdb | blobdb | nomerge
    t_sm: float = 0.20
    t_ml: float = 0.02
    l0_capacity: int = 1 << 20           # bytes of L0 before flush
    growth_factor: int = 8
    merge_depth: int = 1                 # mediums in place at the last k levels
    sorted_segments: bool = True         # eager L0 sorting of transient segments
    gc_threshold: float = 0.10           # parallax large-log GC trigger (§4)
    blobdb_scan_fraction: float = 0.30   # BlobDB GC scan fraction (§4)
    cache_bytes: int = 4 << 20
    auto_gc: bool = True                 # run GC after compactions (blobdb) / ticks
    blobdb_gc_every_flushes: int = 4     # GC wake frequency (scales the paper's
                                         # 'after a compaction' to our small L0)
    prefix_size: int = 12
    segment_bytes: int = 2 << 20         # log/level allocation granularity (§3.4)
    chunk_bytes: int = 256 << 10         # log append group-commit chunk (§3.4)
    bloom_bits_per_key: int = 0          # per-level bloom filters (0 = off, the
                                         # paper's index has none; ShardedStore
                                         # and bench_shard enable 10 bits/key)
    lifetime: LifetimeConfig | None = None   # lifetime-aware value placement
                                         # (parallax mode only): short/long
                                         # value logs + adaptive t_ml cutoff

    def policy(self) -> SizePolicy:
        return SizePolicy(t_sm=self.t_sm, t_ml=self.t_ml, prefix_size=self.prefix_size)


class ParallaxStore:
    def __init__(self, config: StoreConfig | None = None):
        self.config = config or StoreConfig()
        self.device = Device(
            cache_bytes=self.config.cache_bytes,
            segment_bytes=self.config.segment_bytes,
            chunk_bytes=self.config.chunk_bytes,
        )
        self.policy = self.config.policy()
        self.stats = StoreStats()
        self.lsn = 0
        self.l0: dict[bytes, IndexEntry] = {}
        self.l0_bytes = 0
        self.levels: list[Level] = []
        self.small_log = Log(self.device, "small")     # WAL for small+medium
        self.medium_log = TransientLog(self.device, "medium")
        self.large_log = Log(self.device, "large")
        # short-lived value log (lifetime-aware placement, HashKV-style class
        # grouping): allocation is lazy, so this is free when lifetime is off
        self.short_log = Log(self.device, "short", kind="short_log")
        self.compacted_lsn = 0                          # catalog high-water mark
        self._durable: dict[str, int] = {"small": 0, "medium": 0, "large": 0, "short": 0}
        # lifetime sketch + adaptive-cutoff state.  ``cutoff_autonomous``
        # stores apply their own proposals (bare store, hash shards:
        # adaptation is volatile and re-learned after a crash); the
        # range-sharded front-end flips it off and drains proposals through
        # its metadata WAL (record-then-apply) so cutovers replay on recovery.
        self.lifetime = (
            LifetimeSketch(self.config.lifetime)
            if self.config.lifetime is not None and self.config.mode == "parallax"
            else None
        )
        self.cutoff_autonomous = True
        self._cutoff_pending: tuple[float, float] | None = None
        # optional durability fence between GC's relocation flush and segment
        # reclaim (the range front-end journals reclaims through it so the
        # crash-point harness can enumerate the copy->reclaim window)
        self.gc_fence = None
        self._gc_region: dict[int, int] = {}            # seg offset -> dead bytes (info)
        self._in_gc = False                             # reentrancy guard
        # tombstone fence: while True, last-level compactions keep tombstones
        # instead of dropping them.  The range-sharded front-end pins the
        # destination of an in-flight migration: its tombstones are the only
        # evidence that a key was deleted after the ownership flip, and the
        # double-routing read path / copy-skip rule must keep seeing them
        # until the draining source is gone (like a sequence-number fence
        # pinning tombstone GC under a snapshot in a real LSM).
        self.pin_tombstones = False

    # ------------------------------------------------------------------ sizes
    def _classify(self, key: bytes, value: bytes) -> int:
        mode = self.config.mode
        if mode == "rocksdb":
            return CAT_SMALL
        if mode == "blobdb":
            return CAT_LARGE
        return int(self.policy.classify_scalar(len(key), len(value)))

    def num_levels(self) -> int:
        return len(self.levels)

    def _capacity(self, level_idx: int) -> int:
        return self.config.l0_capacity * self.config.growth_factor ** (level_idx + 1)

    def _in_place_zone(self, level_idx: int) -> bool:
        if self.config.mode in ("nomerge", "blobdb"):
            return False
        if self.config.mode == "rocksdb":
            return True
        return level_idx >= len(self.levels) - self.config.merge_depth

    # ------------------------------------------------------------------- puts
    def put(self, key: bytes, value: bytes) -> None:
        self._write(key, value, tombstone=False)

    def update(self, key: bytes, value: bytes) -> None:
        self.stats.updates += 1
        self._write(key, value, tombstone=False, counted=True)

    def delete(self, key: bytes) -> None:
        self.stats.deletes += 1
        self._write(key, b"", tombstone=True, counted=True)

    # contract: single-threaded
    def _write(self, key: bytes, value: bytes, *, tombstone: bool, counted: bool = False, internal: bool = False) -> None:
        if not internal:
            if not counted:
                self.stats.inserts += 1
            self.stats.app_bytes += len(key) + len(value)
        self.lsn += 1
        cat = CAT_SMALL if tombstone else self._classify(key, value)
        entry = IndexEntry(
            key=key, lsn=self.lsn, category=cat, tombstone=tombstone,
            kv_size=len(key) + len(value),
            slot_bytes=0 if self.config.mode == "rocksdb" else 4,
        )
        log_entry = LogEntry(self.lsn, key, value, cat, tombstone=tombstone)
        if cat == CAT_LARGE and not tombstone:
            # lifetime-aware class grouping: hot (short-lived) values go to
            # the aggressively-GC'd short log, everything else to the large
            # (long-lived) log.  Internal writes (GC relocation, migration)
            # re-classify with the *current* sketch — that is the class
            # migration path: a decayed key demotes to long on relocation.
            if self.lifetime is not None and self.lifetime.classify(key) == CLASS_SHORT:
                ptr = self.short_log.append(log_entry)
                entry.ptr, entry.log = ptr, "short"
            else:
                ptr = self.large_log.append(log_entry)
                entry.ptr, entry.log = ptr, "large"
        else:
            # small / medium / tombstone: WAL to Small log, value rides in L0
            self.small_log.append(log_entry)
            entry.value = value if not tombstone else None
        old = self.l0.get(key)
        if old is not None:
            self._mark_superseded(old)
            self.l0_bytes -= old.logical_size()
        self.l0[key] = entry
        self.l0_bytes += entry.logical_size()
        if self.lifetime is not None and not internal and not tombstone:
            # feed the sketch with application writes only — GC relocations
            # and migration copies are system work and must not look like
            # user updates (a relocated cold key is still cold)
            self.lifetime.observe(key, self.lsn)
            cfg = self.config.lifetime
            if cfg.adaptive and self.lsn % cfg.adapt_every == 0:
                self._propose_cutoffs()
        if self.l0_bytes >= self.config.l0_capacity:
            self.flush_l0()

    def _log_of(self, name: str | None) -> Log:
        if name == "large":
            return self.large_log
        if name == "short":
            return self.short_log
        return self.medium_log

    def _mark_superseded(self, entry: IndexEntry) -> None:
        if entry.ptr is None:
            return
        log = self._log_of(entry.log)
        log.mark_dead(entry.ptr)
        if entry.log in ("large", "short"):
            seg = log.segments.get(entry.ptr.segment_id)
            if seg is not None:
                # GC-region bookkeeping: free-space counter keyed by segment
                # start offset (16 B KV put into the private GC region, §3.2)
                self._gc_region[seg.offset] = seg.dead_bytes
                self.device.sequential_write(16, BLOCK, kind="log")

    # ------------------------------------------------------------ compactions
    def flush_l0(self) -> None:
        if not self.l0:
            return
        run = [self.l0[k] for k in sorted(self.l0)]
        max_lsn = max(e.lsn for e in run)
        self.l0.clear()
        self.l0_bytes = 0
        # the compacted level will reference log offsets, so logs must be
        # durable up to here (paper §3.4: the redo record logs the log offsets
        # covered by the L0->L1 compaction) — both value-log classes
        self.large_log.flush()
        self.short_log.flush()
        self._merge_into(0, run, from_l0=True, src_segments=[])
        self.compacted_lsn = max(self.compacted_lsn, max_lsn)
        # WAL reclaim: everything in the Small log is now durable in L1+
        self.small_log.flush()
        for seg in list(self.small_log.iter_segments()):
            self.small_log.reclaim(seg.segment_id)
        self._write_redo_record()
        self._cascade(0)
        self._flushes = getattr(self, "_flushes", 0) + 1
        if (
            self.config.mode == "blobdb"
            and self.config.auto_gc
            and self._flushes % self.config.blobdb_gc_every_flushes == 0
        ):
            self.gc_tick(force=True)

    def _cascade(self, start_idx: int) -> None:
        j = start_idx
        while j < len(self.levels):
            lvl = self.levels[j]
            if lvl.index_bytes <= self._capacity(j):
                j += 1
                continue
            run = lvl.entries
            src_segs = lvl.clear()
            # reading the upper level for the merge (direct I/O, §3.4)
            self.device.sequential_read(sum(e.index_size() for e in run), self.device.segment_bytes, kind="compaction")
            self._merge_into(j + 1, run, from_l0=False, src_segments=src_segs)
            self._write_redo_record()
            j += 1

    def _merge_into(self, dst_idx: int, run: list[IndexEntry], *, from_l0: bool, src_segments: list[int]) -> None:
        """Merge a sorted run (from L0 or level dst_idx-1) into levels[dst_idx]."""
        cfg = self.config
        while len(self.levels) <= dst_idx:
            self.levels.append(Level(len(self.levels), cfg.bloom_bits_per_key))
        dst = self.levels[dst_idx]
        self.stats.compactions += 1
        # read the lower (larger) level in full (paper Eq. 1 assumption / §3.4)
        self.device.sequential_read(dst.index_bytes, self.device.segment_bytes, kind="compaction")

        is_last = dst_idx == len(self.levels) - 1
        merged, dead = merge_runs(
            run, dst.entries, drop_tombstones=is_last and not self.pin_tombstones
        )
        self.stats.entries_merged += len(merged)
        for d in dead:
            self._mark_superseded(d)

        in_place = self._in_place_zone(dst_idx)
        pre_segment_ids = set(self.medium_log.segments.keys())
        new_segments: list[int] = []
        consumed_segments: set[int] = set()
        if in_place:
            # fetch every transient segment attached to src+dst exactly once
            for sid in {*src_segments, *dst.transient_segments}:
                if sid in self.medium_log.segments:
                    self.medium_log.merge_read(sid)
                    consumed_segments.add(sid)
        out: list[IndexEntry] = []
        for e in merged:
            if e.category == CAT_MEDIUM and not e.tombstone and cfg.mode in ("parallax", "nomerge"):
                if in_place:
                    if e.ptr is not None:
                        val = self.medium_log.get(e.ptr).value
                        e = dataclasses.replace(e, ptr=None, log=None, value=val)
                else:
                    if e.ptr is None:
                        # L0 medium: append (merge-sorted order) to transient log
                        ptr = self.medium_log.append(LogEntry(e.lsn, e.key, e.value or b"", CAT_MEDIUM))
                        e = dataclasses.replace(e, ptr=ptr, log="medium", value=None)
            out.append(e)
        # seal + attach transient segments produced by this merge
        self.medium_log.seal_tail(cfg.sorted_segments)
        if not in_place:
            survivors = [
                sid for sid in {*src_segments, *dst.transient_segments}
                if sid in self.medium_log.segments
            ]
            created = [
                sid for sid in self.medium_log.segments if sid not in pre_segment_ids
            ]
            new_segments = survivors + created
        else:
            for sid in consumed_segments:
                self.medium_log.reclaim(sid)
        dst.rebuild(out)
        dst.transient_segments = sorted(set(new_segments))
        # write the merged level (2 MB segment granularity direct I/O)
        self.device.sequential_write(dst.index_bytes, self.device.segment_bytes, kind="compaction")

    # contract: flush-before-record
    def _write_redo_record(self) -> None:
        # The redo record must not precede the data it covers (§3.4): mediums
        # the merge spilled to the transient log become durable first, else a
        # crash after the record would leave durable levels with dangling
        # medium pointers.
        self.medium_log.flush()
        # allocation/free lists + catalog entry (§3.4) — one small append
        self.device.sequential_write(512, BLOCK, kind="log")

    # ------------------------------------------------------------------- gets
    def _probe_level(self, lvl: Level, key: bytes, kind: str = "get") -> IndexEntry | None:
        if lvl.entries and not lvl.maybe_contains(key):
            self.stats.bloom_skips += 1
            return None
        self.stats.index_probes += 1
        if not lvl.entries:
            return None
        base = _LEVEL_REGION * (lvl.index + 1)
        # crc32, not hash(): the modeled cache block must be stable across
        # processes (PYTHONHASHSEED randomizes hash() for bytes)
        block = base + (zlib.crc32(key) % max(1, lvl.index_bytes)) // BLOCK * BLOCK
        self.device.random_read(block, 1, kind=kind)  # leaf block through cache
        return lvl.find(key)

    def _locate(self, key: bytes, *, kind: str = "get") -> IndexEntry | None:
        entry = self.l0.get(key)
        if entry is not None:
            return entry
        for lvl in self.levels:
            e = self._probe_level(lvl, key, kind=kind)
            if e is not None:
                return e
        return None

    # contract: single-threaded
    def get(self, key: bytes) -> bytes | None:
        self.stats.gets += 1
        entry = self._locate(key)
        if entry is None or entry.tombstone:
            return None
        self.stats.found += 1
        value = self._value_of(entry)
        self.stats.app_bytes += len(key) + len(value)
        return value

    def _value_of(self, entry: IndexEntry, kind: str = "get") -> bytes:
        if entry.in_place:
            return entry.value or b""
        return self._log_of(entry.log).read(entry.ptr, kind=kind).value

    # ------------------------------------------------------------------- scan
    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Merge per-level scanners (newest LSN wins), return up to count pairs."""
        return self._scan(start, None, count)

    def scan_range(self, start: bytes, end: bytes | None, *, internal: bool = False) -> list[tuple[bytes, bytes]]:
        """All live pairs with ``start <= key < end`` (``end=None`` = no bound).

        Same merged read path (and device charges) as :meth:`scan`; used by the
        range-sharded front-end to migrate a key range during a split/merge.
        ``internal=True`` marks it as system work (like GC lookups): the device
        pays, but application op/byte stats are untouched.
        """
        return self._scan(start, end, None, internal=internal)

    def _scan(self, start: bytes, end: bytes | None, count: int | None, *, internal: bool = False) -> list[tuple[bytes, bytes]]:
        limit = count if count is not None else (1 << 62)
        return list(itertools.islice(self.iter_range(start, end, internal=internal), limit))

    def iter_range(self, start: bytes, end: bytes | None = None, *,
                   internal: bool = False) -> Iterator[tuple[bytes, bytes]]:
        """Lazy sorted stream of live ``(key, value)`` pairs from ``start``.

        The merged read path behind :meth:`scan` / :meth:`scan_range` (both are
        ``islice`` over this): sources are snapshotted at the call (L0 sorted
        once, one cursor per level) and every device/app-byte charge is paid
        when the row is *yielded*, so consuming ``k`` rows costs exactly what
        ``scan(start, k)`` does — rows never pulled are never charged.  The
        stream is only valid while the store is not written to or compacted;
        interleaving writes with iteration is undefined (take a fresh iterator
        after mutating, like a RocksDB iterator without a snapshot pin).
        """
        if not internal:
            self.stats.scans += 1
        iters: list[Iterable[IndexEntry]] = []
        l0_items = [self.l0[k] for k in sorted(self.l0) if self.l0[k].key >= start]
        iters.append(iter(l0_items))
        for lvl in self.levels:
            iters.append(lvl.iter_from(start))
        heap: list[tuple[bytes, int, int, IndexEntry]] = []
        for src, it in enumerate(iters):
            e = next(it, None)
            if e is not None:
                heapq.heappush(heap, (e.key, -e.lsn, src, e))
        return self._merge_rows(iters, heap, end, internal)

    def _merge_rows(self, its: list[Iterable[IndexEntry]],
                    heap: list[tuple[bytes, int, int, IndexEntry]],
                    end: bytes | None, internal: bool) -> Iterator[tuple[bytes, bytes]]:
        last_key: bytes | None = None
        scanned_bytes = [0] * len(its)
        while heap:
            key, _, src, e = heapq.heappop(heap)
            if end is not None and key >= end:
                # sources are sorted, so this source is exhausted for the range
                continue
            nxt = next(its[src], None)
            if nxt is not None:
                heapq.heappush(heap, (nxt.key, -nxt.lsn, src, nxt))
            if key == last_key:
                continue
            last_key = key
            if e.tombstone:
                continue
            # leaf bytes stream sequentially per level; log values are random
            if src > 0:
                lvl = self.levels[src - 1]
                base = _LEVEL_REGION * lvl.index + scanned_bytes[src]
                self.device.random_read(base, e.index_size(), kind="get")
                scanned_bytes[src] += e.index_size()
            value = self._value_of(e)
            if not internal:
                self.stats.app_bytes += len(key) + len(value)
            yield (key, value)

    # ---------------------------------------------------------- ranged delete
    def newest_entries(self, start: bytes, end: bytes | None) -> dict[bytes, IndexEntry]:
        """Newest entry per key in ``[start, end)``, tombstones included.

        Pure index walk — no device traffic is charged (same discipline as
        :meth:`live_keys_in`, which is built on it).  The migration read path
        uses the tombstone visibility to decide which keys the new owner
        already answers for.
        """
        best: dict[bytes, IndexEntry] = {}
        sources: list[Iterable[IndexEntry]] = [
            iter([self.l0[k] for k in sorted(self.l0)])
        ]
        sources.extend(lvl.iter_from(start) for lvl in self.levels)
        for src in sources:
            for e in src:
                if e.key < start:
                    continue
                if end is not None and e.key >= end:
                    break
                cur = best.get(e.key)
                if cur is None or e.lsn > cur.lsn:
                    best[e.key] = e
        return best

    def index_entry(self, key: bytes) -> IndexEntry | None:
        """Newest entry for one key (tombstones included), pure index walk.

        No device traffic or stat accounting — the migration copy path uses
        it to skip keys the destination already holds a newer write for.
        """
        e = self.l0.get(key)
        if e is not None:
            return e
        for lvl in self.levels:
            found = lvl.find(key)
            if found is not None:
                return found
        return None

    def live_keys_in(self, start: bytes, end: bytes | None) -> list[bytes]:
        """Sorted live (non-tombstone, newest-LSN) keys in ``[start, end)``.

        Pure index walk — no device traffic is charged; callers that read the
        values pay through :meth:`scan_range`, callers that delete pay through
        the normal write path.
        """
        return sorted(
            k for k, e in self.newest_entries(start, end).items() if not e.tombstone
        )

    def delete_range(self, start: bytes, end: bytes | None, *, internal: bool = False,
                     keys: list[bytes] | None = None) -> int:
        """Tombstone every live key in ``[start, end)``; returns keys deleted.

        Each delete flows through the normal write path (WAL append, L0,
        flush/compaction), so a ranged delete obeys the same durability
        ordering as individual deletes — this is the migration hook the
        range-sharded front-end uses when a shard drops part of its range.
        ``internal=True`` marks the tombstones as system work (migration/GC
        style): charged to the device but not to application op/byte stats.
        A caller that already materialized the range (e.g. the scan side of a
        migration) passes ``keys`` to skip the index walk.
        """
        if keys is None:
            keys = self.live_keys_in(start, end)
        for k in keys:
            if internal:
                self._write(k, b"", tombstone=True, internal=True)
            else:
                self.delete(k)
        return len(keys)

    # ------------------------------------------------------ adaptive cutoffs
    def _propose_cutoffs(self) -> None:
        """Turn the sketch's distance ring into a t_ml cutover proposal.

        Autonomous stores (bare, hash shards) apply immediately — the adapted
        policy is volatile and re-learned after recovery.  Under a range
        front-end (``cutoff_autonomous=False``) the proposal parks in
        ``_cutoff_pending`` until the coordinator drains it through the
        shard-metadata WAL (record-then-apply) at a sequence point.
        """
        cfg = self.config.lifetime
        proposal = propose_cutoffs(
            self.config.policy(), self.lifetime.ring, cfg.window,
            min_ring=cfg.min_ring, max_shift=cfg.max_shift,
        )
        if proposal is None or proposal == (self.policy.t_sm, self.policy.t_ml):
            return
        if self.cutoff_autonomous:
            self.apply_cutoffs(*proposal)
        else:
            self._cutoff_pending = proposal

    def apply_cutoffs(self, t_sm: float, t_ml: float) -> None:
        """Install adapted size cutoffs (instance policy only — the shared
        ``StoreConfig`` stays the static anchor the controller reasons from)."""
        self.policy = dataclasses.replace(self.policy, t_sm=t_sm, t_ml=t_ml)
        self._cutoff_pending = None
        self.stats.cutoff_adaptations += 1

    def take_cutoff_proposal(self) -> tuple[float, float] | None:
        proposal, self._cutoff_pending = self._cutoff_pending, None
        return proposal

    def lifetime_state(self) -> dict | None:
        """Observability snapshot for the engine's ``lifetime`` stats namespace."""
        if self.lifetime is None:
            return None
        state = self.lifetime.state()
        state.update(
            t_sm=self.policy.t_sm,
            t_ml=self.policy.t_ml,
            short_log_segments=len(self.short_log.segments),
            long_log_segments=len(self.large_log.segments),
            short_log_bytes=self.short_log.total_bytes,
            long_log_bytes=self.large_log.total_bytes,
            class_migrations=self.stats.class_migrations,
            cutoff_adaptations=self.stats.cutoff_adaptations,
        )
        return state

    # --------------------------------------------------------------------- GC
    def gc_tick(self, force: bool = False) -> int:
        """Large-log GC (parallax, §3.2) or scan-fraction GC (blobdb).

        Returns the number of segments reclaimed.  With ``auto_gc=False`` the
        periodic ticks are disabled unless forced (the Fig. 1 no-GC variant).
        """
        cfg = self.config
        if cfg.mode in ("rocksdb", "nomerge") or self._in_gc:
            return 0
        if not cfg.auto_gc and not force:
            return 0
        # victims carry their owning log: with lifetime-aware placement the
        # short-lived class is swept aggressively (segments mostly dead by
        # the time they fill — relocation is nearly free) while the long
        # class rides to a much lazier threshold; without it, the single
        # large log uses the paper's static threshold
        victims: list[tuple[Log, object]] = []
        segs = [s for s in self.large_log.iter_segments() if s is not self.large_log._tail]
        if cfg.mode == "parallax":
            if self.lifetime is not None:
                lt = cfg.lifetime
                victims += [(self.large_log, s) for s in segs
                            if s.invalid_fraction() >= lt.long_gc_threshold]
                victims += [
                    (self.short_log, s)
                    for s in self.short_log.iter_segments()
                    if s is not self.short_log._tail
                    and s.invalid_fraction() >= lt.short_gc_threshold
                ]
            else:
                victims = [(self.large_log, s) for s in segs
                           if s.invalid_fraction() >= cfg.gc_threshold]
        else:  # blobdb: scan the oldest fraction of the log after compaction
            segs.sort(key=lambda s: s.segment_id)
            n = max(1, int(len(segs) * cfg.blobdb_scan_fraction)) if segs else 0
            victims = [(self.large_log, s) for s in segs[:n]]
        reclaimed = 0
        self._in_gc = True
        try:
            for log, seg in victims:
                short = log is self.short_log
                # (1) identify: scan the segment + one index lookup per KV
                self.device.sequential_read(seg.used_bytes, self.device.segment_bytes,
                                            kind="gc_short" if short else "gc")
                live: list[LogEntry] = []
                for slot, le in enumerate(seg.entries):
                    if le is None:
                        continue
                    self.stats.gc_lookups += 1
                    if short:
                        self.stats.gc_short_lookups += 1
                    cur = self._lookup_for_gc(le.key)
                    if (
                        cur is not None
                        and cur.ptr is not None
                        and cur.log == log.name
                        and cur.ptr.segment_id == seg.segment_id
                        and cur.ptr.slot == slot
                        and not cur.tombstone
                    ):
                        live.append(le)
                if cfg.mode == "blobdb" and seg.dead_bytes == 0:
                    # nothing to clean: identification cost only (paper Fig. 1 —
                    # pure-insert loads pay lookups but relocate nothing)
                    continue
                # (2) relocate: re-put valid pairs (paper: 'via a put operation').
                # The re-put reclassifies against the *current* sketch/policy,
                # so this is also the class-migration path (demotion of decayed
                # short keys, promotion of heated-up long keys).
                for le in live:
                    self.stats.gc_relocations += 1
                    if short:
                        self.stats.gc_short_relocations += 1
                    self._write(le.key, le.value, tombstone=False, internal=True)
                    if self.lifetime is not None:
                        moved = self.l0.get(le.key)
                        if moved is not None and moved.log != log.name:
                            self.stats.class_migrations += 1
                if live:
                    # durability barrier: relocations must be durable before
                    # the victim segment is freed, else a crash would expose
                    # the shadowed level entries whose pointers dangle into
                    # the reclaimed segment.  A relocation may land in any
                    # class log, so all of them flush.
                    self.small_log.flush()
                    self.large_log.flush()
                    self.short_log.flush()
                if self.gc_fence is not None:
                    # front-end fence between copy-durable and reclaim (the
                    # range store journals the reclaim here; a crash at the
                    # fence leaves both copies and recovery keeps newest-LSN)
                    self.gc_fence(log.name, seg.segment_id)
                log.reclaim(seg.segment_id)
                self._gc_region.pop(seg.offset, None)
                reclaimed += 1
        finally:
            self._in_gc = False
        return reclaimed

    def _lookup_for_gc(self, key: bytes) -> IndexEntry | None:
        e = self.l0.get(key)
        if e is not None:
            return e
        for lvl in self.levels:
            found = self._probe_level(lvl, key, kind="gc")
            if found is not None:
                return found
        return None

    # --------------------------------------------------------- crash/recovery
    def flush_all(self) -> None:
        self.small_log.flush()
        self.large_log.flush()
        self.short_log.flush()
        self.medium_log.flush()
        for log in (self.small_log, self.large_log, self.short_log, self.medium_log):
            if log.segments:
                mx = max(
                    (e.lsn for s in log.segments.values() for e in s.entries if e is not None),
                    default=0,
                )
                self._durable[log.name] = mx

    def crash(self) -> int:
        """Drop volatile state: L0 and any log entries past the last group commit.

        Returns the recovery cutoff LSN: the store recovers to the prefix of
        writes with ``lsn <= cutoff`` (paper §3.4: a previous — not necessarily
        the last — consistent point).  The cutoff is the largest LSN such that
        *every* write at or below it survives in some durable location, which
        with per-log group commit is ``min(first lost lsn per log) - 1``.
        """
        self.l0.clear()
        self.l0_bytes = 0
        first_lost = None
        for log in (self.small_log, self.large_log, self.short_log):
            cutoff = self._durable_lsn(log)
            for seg in log.iter_segments():
                for slot, e in enumerate(seg.entries):
                    if e is not None and e.lsn > cutoff:
                        if first_lost is None or e.lsn < first_lost:
                            first_lost = e.lsn
                        seg.entries[slot] = None
                        seg.live_bytes -= e.size
            log._unflushed = 0
        # The transient log is only ever referenced by compacted levels, and
        # the redo record flushes it first, so the durable prefix is exactly
        # the flushed bytes: drop the unflushed tail (it covers no level).
        med = self.medium_log
        durable_bytes = med.appended_bytes - med._unflushed
        for seg in med.iter_segments():
            for slot, e in enumerate(seg.entries):
                if e is not None and e.end_off > durable_bytes:
                    seg.entries[slot] = None
                    seg.live_bytes -= e.size
        med._unflushed = 0
        self._recovery_cutoff = (first_lost - 1) if first_lost is not None else self.lsn
        return self._recovery_cutoff

    def _durable_lsn(self, log: Log) -> int:
        """Entries beyond the last 256 KB chunk boundary are lost on crash."""
        durable_bytes = log.appended_bytes - log._unflushed
        last = 0
        for seg in log.segments.values():
            for e in seg.entries:
                if e is not None and e.end_off <= durable_bytes:
                    last = max(last, e.lsn)
        return max(last, self._durable.get(log.name, 0))

    def recover(self) -> None:
        """Replay Small + Large logs in LSN order to rebuild L0 (paper §3.4).

        Only LSNs up to the recovery cutoff are applied so the recovered state
        is a consistent prefix of the write history.
        """
        cutoff = getattr(self, "_recovery_cutoff", self.lsn)
        replay: list[tuple[int, LogEntry, tuple[str, Pointer] | None]] = []
        for seg in self.small_log.iter_segments():
            for e in seg.entries:
                if e is not None and self.compacted_lsn < e.lsn <= cutoff:
                    replay.append((e.lsn, e, None))
        for logname, vlog in (("large", self.large_log), ("short", self.short_log)):
            for seg in vlog.iter_segments():
                for slot, e in enumerate(seg.entries):
                    if e is not None and self.compacted_lsn < e.lsn <= cutoff:
                        replay.append((e.lsn, e, (logname, Pointer(seg.segment_id, slot))))
        replay.sort(key=lambda t: t[0])
        self.l0.clear()
        self.l0_bytes = 0
        for lsn, le, located in replay:
            self.device.random_read(lsn % (1 << 30), le.size, kind="get")
            entry = IndexEntry(
                key=le.key, lsn=lsn, category=le.category, tombstone=le.tombstone,
                kv_size=len(le.key) + len(le.value),
            )
            if located is not None:
                entry.log, entry.ptr = located
            elif not le.tombstone:
                entry.value = le.value
            old = self.l0.get(le.key)
            if old is not None:
                self.l0_bytes -= old.logical_size()
            self.l0[le.key] = entry
            self.l0_bytes += entry.logical_size()
            self.lsn = max(self.lsn, lsn)

    # ------------------------------------------------------------- snapshots
    def snapshot_rows(self) -> list[tuple[bytes, bytes, int, bool]]:
        """Newest row per key — ``(key, value, lsn, tombstone)``, sorted by key.

        The store's logical content for :meth:`load_rows`: tombstones and
        original LSNs are preserved because a migration destination's
        post-epoch tombstones (and the epoch fence itself) are part of the
        state a snapshot must carry.  Values resident in a log are read
        through the normal charged path (a backup pays to read its data);
        the index walk itself is free, like :meth:`newest_entries`.
        """
        rows: list[tuple[bytes, bytes, int, bool]] = []
        for key, e in sorted(self.newest_entries(b"", None).items()):
            value = b"" if e.tombstone else self._value_of(e)
            rows.append((key, value, e.lsn, e.tombstone))
        return rows

    def load_rows(self, rows: list[tuple[bytes, bytes, int, bool]], lsn: int = 0) -> None:
        """Load a :meth:`snapshot_rows` capture into this (fresh) store.

        Rows are written in ascending-LSN order and each write is pinned to
        its original LSN.  Ordering is load-bearing: a flush mid-load sets
        ``compacted_lsn`` to the run's max LSN, and :meth:`recover` skips
        entries at or below it — loading out of LSN order would silently
        drop rows after a later crash/recover.  Everything is flushed at the
        end, and the LSN counter lands at ``max(row lsns, lsn)`` so epoch
        fences and future writes behave exactly as in the source store.
        """
        for key, value, row_lsn, tombstone in sorted(rows, key=lambda r: r[2]):
            self.lsn = row_lsn - 1
            self._write(key, value, tombstone=tombstone, internal=True)
        self.flush_all()
        self.lsn = max(self.lsn, lsn)

    # ------------------------------------------------------------------ misc
    def amplification(self) -> float:
        app = max(1, self.stats.app_bytes)
        return self.device.stats.total / app

    def space_bytes(self) -> int:
        level_bytes = sum(l.index_bytes for l in self.levels)
        log_bytes = (self.small_log.total_bytes + self.medium_log.total_bytes
                     + self.large_log.total_bytes + self.short_log.total_bytes)
        return level_bytes + log_bytes

    def checkpoint_stats(self) -> dict:
        return {
            "amplification": self.amplification(),
            "device_read": self.device.stats.bytes_read,
            "device_written": self.device.stats.bytes_written,
            "levels": [len(l) for l in self.levels],
            "l0": len(self.l0),
            "medium_log_segments": len(self.medium_log.segments),
            "large_log_segments": len(self.large_log.segments),
            "short_log_segments": len(self.short_log.segments),
        }
