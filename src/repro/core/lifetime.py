"""Lifetime-aware value placement: a windowed per-key update-distance sketch.

The paper's small/medium/large triage is *static*: thresholds are fixed at
config time and the Large log pays full §4 GC regardless of how hot its keys
are.  Related work (HashKV's hotness-grouped value logs, DumpKV's
update-lifetime-driven placement, Scavenger's space/GC trade — see PAPERS.md)
shows the remaining GC/amplification headroom comes from *update-lifetime*
signals: values that die young should live together in logs that are cheap to
clean (mostly-dead segments), values that live long should ride untouched.

This module is the signal side of that design:

* :class:`LifetimeSketch` — a paired-epoch count-min sketch over update
  counts plus a per-cell last-update-LSN table and a ring of recent
  inter-update distances.  ``classify`` maps a key to :data:`CLASS_SHORT`
  (updated ≥ ``hot_updates`` times inside the sliding two-epoch window — it
  will die young) or :data:`CLASS_LONG` (everything else, including keys
  never seen: fresh inserts must prove themselves hot).  The store keeps one
  sketch per instance and routes Large values to a per-class value log
  (``ParallaxStore.short_log`` vs ``large_log``).
* :func:`propose_cutoffs` — the adaptive-threshold controller: turns the
  observed distance ring into a medium/large cutoff (``t_ml``) proposal, so
  update-heavy stores push hot mediums into the aggressively-GC'd short log
  instead of paying in-place merge I/O for values that die young.
* :class:`LifetimeOracle` — an exact reference twin (per-key update lists,
  brute-force collision mass) used by the property tests: the sketch's
  estimate must equal ``true_count + min-over-rows collision mass`` exactly,
  and may never underestimate.

Determinism contract: everything here is keyed with ``zlib.crc32`` under
fixed seeds — builtin ``hash()`` is ``PYTHONHASHSEED``-randomized and banned
from modeled paths (lint rule ``no-nondeterminism``).  Two processes feeding
the same ``(key, lsn)`` stream hold bit-identical sketch state, which is what
lets the differential oracle replay lifetime-enabled engines across serial
and async front-ends.

Windowing: epochs are ``lsn // window``.  The sketch holds the current and
previous epoch's counters; ``estimate`` sums both, so a key's visibility
decays to zero after two epoch rotations without an update — window eviction
can never resurrect a decayed key because rotation only ever zeroes
counters.  The last-LSN table is deliberately not rotated: a stale cell only
*overestimates* recency for colliding keys, which biases toward
:data:`CLASS_SHORT` — the conservative direction (a wrongly-short value costs
one extra relocation; a wrongly-long value pollutes the lazy log).
"""
from __future__ import annotations

import dataclasses
import zlib
from collections import deque

CLASS_SHORT = "short"
CLASS_LONG = "long"

_SEED_BASE = zlib.crc32(b"repro.core.lifetime")


@dataclasses.dataclass(frozen=True)
class LifetimeConfig:
    """Knobs for the sketch and the per-class GC/placement policy.

    Frozen so one config can safely be shared across the shards of a
    front-end (``StoreConfig`` instances are shared the same way).
    """

    window: int = 2048          # LSNs per sketch epoch (sliding pair = 2x this)
    rows: int = 4               # count-min rows
    width: int = 256            # counters per row
    hot_updates: int = 2        # windowed estimate >= this => CLASS_SHORT
    ring_size: int = 128        # recent inter-update distances kept
    adaptive: bool = True       # adapt t_ml from the observed distance ring
    adapt_every: int = 2048     # LSNs between cutoff proposals
    min_ring: int = 32          # distance samples needed before proposing
    max_shift: float = 0.5      # t_ml may move this fraction of (t_sm - t_ml)
    # Per-class GC thresholds, replacing the single static gc_threshold.
    # The short log waits for a segment to be half dead — hot churn gets it
    # there within about one update cycle, so sweeps fire constantly but
    # relocate little (sweeping hot segments while mostly live is the
    # classic hot/cold-mixing tax this split exists to avoid).  The long
    # log is lazier than the static 0.10 anchor: its live values are cold,
    # so relocating them buys nothing until real garbage accumulates.
    short_gc_threshold: float = 0.5
    long_gc_threshold: float = 0.30

    def __post_init__(self):
        if self.window < 2 or self.rows < 1 or self.width < 1:
            raise ValueError(f"degenerate sketch geometry {self!r}")
        if self.hot_updates < 1:
            raise ValueError("hot_updates must be >= 1")
        if not 0.0 < self.short_gc_threshold <= 1.0 or not 0.0 < self.long_gc_threshold <= 1.0:
            raise ValueError("per-class GC thresholds must be in (0, 1]")


class LifetimeSketch:
    """Paired-epoch count-min over update counts, crc32-keyed.

    ``observe(key, lsn)`` must be fed application writes in LSN order (the
    store's write path does); ``estimate``/``classify`` are read-only.
    """

    def __init__(self, config: LifetimeConfig):
        self.config = config
        self._seeds = [zlib.crc32(b"row-%d" % r, _SEED_BASE) for r in range(config.rows)]
        w = config.width
        self.epoch = 0
        self._cur = [[0] * w for _ in range(config.rows)]
        self._prev = [[0] * w for _ in range(config.rows)]
        self._last = [[0] * w for _ in range(config.rows)]   # cell last-update LSN
        self.ring: deque[int] = deque(maxlen=config.ring_size)
        self.observed = 0
        self.rotations = 0

    # ------------------------------------------------------------- internals
    def _cells(self, key: bytes) -> list[int]:
        w = self.config.width
        return [zlib.crc32(key, seed) % w for seed in self._seeds]

    def _rotate_to(self, epoch: int) -> None:
        if epoch <= self.epoch:
            return
        w = self.config.width
        if epoch == self.epoch + 1:
            self._prev = self._cur
        else:
            # jumped >= 2 epochs: both windows decayed
            self._prev = [[0] * w for _ in range(self.config.rows)]
        self._cur = [[0] * w for _ in range(self.config.rows)]
        self.rotations += 1
        self.epoch = epoch

    # ----------------------------------------------------------------- feed
    def observe(self, key: bytes, lsn: int) -> None:
        cfg = self.config
        self._rotate_to(lsn // cfg.window)
        cells = self._cells(key)
        # distance sample: only when the key is visible in the paired window,
        # so first touches (and decayed keys) don't pollute the ring.  The
        # cell last-LSN is a max over colliding keys, so the sampled distance
        # is <= the key's true distance — conservative toward CLASS_SHORT.
        if all(self._cur[r][c] + self._prev[r][c] > 0 for r, c in enumerate(cells)):
            dist = lsn - min(self._last[r][c] for r, c in enumerate(cells))
            if dist > 0:
                self.ring.append(dist)
        for r, c in enumerate(cells):
            self._cur[r][c] += 1
            if lsn > self._last[r][c]:
                self._last[r][c] = lsn
        self.observed += 1

    # ---------------------------------------------------------------- reads
    def estimate(self, key: bytes) -> int:
        """Windowed update-count estimate: never underestimates the true
        count inside the current+previous epoch window."""
        return min(
            self._cur[r][c] + self._prev[r][c] for r, c in enumerate(self._cells(key))
        )

    def classify(self, key: bytes) -> str:
        return CLASS_SHORT if self.estimate(key) >= self.config.hot_updates else CLASS_LONG

    def state(self) -> dict:
        """Cheap observability snapshot for the stats namespace."""
        ring = sorted(self.ring)
        return {
            "epoch": self.epoch,
            "observed": self.observed,
            "rotations": self.rotations,
            "ring_len": len(ring),
            "median_distance": ring[len(ring) // 2] if ring else None,
        }


def propose_cutoffs(base, distances, window: int, *,
                    min_ring: int = 32, max_shift: float = 0.5) -> tuple[float, float] | None:
    """Adaptive medium/large cutoff from the observed distance distribution.

    ``base`` is the store's *static* :class:`~repro.core.model.SizePolicy`
    (the anchor the controller interpolates from — adaptation is stateless in
    the sense that the same ring always yields the same proposal, so replaying
    a cutover WAL record reproduces the applied policy exactly).

    The rule: the hot fraction of the ring (distances within ``window // 4``
    LSNs — updates arriving well inside one epoch) moves ``t_ml`` up toward
    ``t_sm`` by at most ``max_shift`` of the gap.  A hot, update-heavy store
    therefore reclassifies its mediums as Large — they land in the short-lived
    value log where GC is nearly free (mostly-dead segments) instead of being
    repeatedly rewritten by in-place merges; a cold store keeps the paper's
    static triage.  Returns ``(t_sm, t_ml)`` rounded to 6 decimals (stable
    WAL-record encoding), or None with too few samples.
    """
    distances = list(distances)
    if len(distances) < min_ring:
        return None
    hot_cut = max(1, window // 4)
    hot_frac = sum(1 for d in distances if d <= hot_cut) / len(distances)
    t_ml = round(base.t_ml + (base.t_sm - base.t_ml) * max_shift * hot_frac, 6)
    return (base.t_sm, t_ml)


class LifetimeOracle:
    """Exact reference twin for the sketch (test-only, O(keys) memory).

    Tracks every key's update LSNs and recomputes, by brute force, precisely
    what a collision-aware count-min must report: for each row the cell value
    is the sum of windowed true counts of *all* keys mapping there, and the
    estimate is the min over rows.  ``expected_estimate`` is therefore not a
    bound but an equality the sketch must hit exactly.
    """

    def __init__(self, config: LifetimeConfig):
        self.config = config
        self._seeds = [zlib.crc32(b"row-%d" % r, _SEED_BASE) for r in range(config.rows)]
        self.updates: dict[bytes, list[int]] = {}
        self.epoch = 0

    def observe(self, key: bytes, lsn: int) -> None:
        self.updates.setdefault(key, []).append(lsn)
        self.epoch = max(self.epoch, lsn // self.config.window)

    def true_count(self, key: bytes) -> int:
        """Updates inside the current+previous epoch window."""
        lo = (self.epoch - 1) * self.config.window
        return sum(1 for lsn in self.updates.get(key, ()) if lsn >= lo)

    def _cell(self, key: bytes, row: int) -> int:
        return zlib.crc32(key, self._seeds[row]) % self.config.width

    def expected_estimate(self, key: bytes) -> int:
        per_row = []
        for r in range(self.config.rows):
            cell = self._cell(key, r)
            mass = sum(
                self.true_count(other)
                for other in self.updates
                if self._cell(other, r) == cell
            )
            per_row.append(mass)
        return min(per_row) if per_row else 0

    def classify(self, key: bytes) -> str:
        short = self.expected_estimate(key) >= self.config.hot_updates
        return CLASS_SHORT if short else CLASS_LONG


__all__ = [
    "CLASS_LONG",
    "CLASS_SHORT",
    "LifetimeConfig",
    "LifetimeOracle",
    "LifetimeSketch",
    "propose_cutoffs",
]
