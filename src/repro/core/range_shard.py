"""Range-partitioned sharding with incremental, WAL-backed rebalancing.

:class:`RangeShardedStore` partitions the keyspace into contiguous ranges —
shard ``i`` owns ``[boundaries[i], boundaries[i+1])`` with ``boundaries[0] ==
b""`` and the last range unbounded.  Point ops route by binary search over the
sorted boundary list; ``scan(start, count)`` touches **only the shards whose
range overlaps the scan** and concatenates their results (each shard's output
is already globally ordered — no k-way merge), which is what makes range
partitioning win scan workloads (YCSB E) where the hash-partitioned
:class:`~repro.core.shard.ShardedStore` must fan out to all N shards.

Two things changed from the PR 2 design (stop-the-world migration over an
in-memory atomic boundary map):

**Incremental migration.**  ``split()``/``merge()`` no longer copy their whole
range in one stall.  They install a :class:`MigrationState` and return; each
:meth:`migration_tick` (driven from batch boundaries — ``ycsb.execute``'s
batched ops land in ``_after_batch``) moves at most ``migration_batch_keys``
keys.  The boundary flips **at migration start**, with double-routing during
the transition:

* *writes* for the moved range go to the new owner immediately;
* *reads* probe the new owner first; only a true miss on a key in the
  **pending** region ``[cursor, hi)`` (not yet copied) falls back to the
  draining old shard (one extra probe, counted in ``get_probes``); keys below
  the cursor are the new owner's alone — its answer (including a tombstone)
  is authoritative, so stale copies in the old shard can never resurface;
* *scans* overlapping the pending region consult both sides and keep the old
  shard's row only when the new owner has no entry (live or tombstone) for
  that key.

Each tick preserves the flush-before-flip ordering *per batch*: copy the
batch into the new owner → flush the new owner's logs → write the migration
checkpoint record (this is the moment the batch's keys flip) → tombstone the
batch out of the old shard.  A copy never clobbers a newer write: any entry
the destination already holds with an LSN above the migration's start epoch
was written during the migration (an application write routed to the new
owner, or an earlier copy of the same key) and wins.

**Persistent shard-metadata WAL.**  Every boundary change, shard
create/retire and migration checkpoint is a durable
:class:`~repro.core.metalog.MetadataLog` record (``init`` / ``split_start`` /
``merge_start`` / ``checkpoint`` / ``finish`` / ``snapshot``), written
record-then-apply.  ``recover()`` replays the record stream from its oldest
retained record — genesis, or the ``snapshot`` record
:meth:`RangeShardedStore.snapshot_metadata` roots a truncated WAL at (PR 7) —
to rebuild the boundary
map, the live shard set and any in-flight :class:`MigrationState`, which then
resumes (rolls forward) on subsequent ticks — a crash at *any* record site
leaves a recoverable topology, which ``tests/test_crashpoints.py`` proves by
enumerating every site via ``MetadataLog.crash_after``.  Metadata bytes are
charged to a dedicated device with ``kind="meta"`` and folded into
``device_stats()``/``amplification()``.

Migration traffic is charged to the device like any other put/delete, but it
is *internal* work: like GC relocations, it does not count toward application
op/byte stats.
"""
from __future__ import annotations

import bisect
import dataclasses

from .io import Device, DeviceStats
from .metalog import MetadataLog
from .shard import BaseShardedStore
from .store import ParallaxStore, StoreConfig
from .ycsb import _warn_deprecated


def _uniform_boundaries(num_shards: int) -> list[bytes]:
    """Evenly spaced 2-byte prefixes over the full byte keyspace."""
    out = [b""]
    for i in range(1, num_shards):
        v = (1 << 16) * i // num_shards
        out.append(bytes([v >> 8, v & 0xFF]))
    return out


def _next_key(key: bytes) -> bytes:
    """The smallest key strictly greater than ``key`` (cursor advance)."""
    return key + b"\x00"


@dataclasses.dataclass
class MigrationState:
    """One in-flight range migration: ``[lo, hi)`` moving src -> dst.

    ``cursor`` splits the range: ``[lo, cursor)`` is *migrated* (dst is sole
    owner), ``[cursor, hi)`` is *pending* (dst owns writes, reads fall back
    to src on a miss).  ``epoch_lsn`` is dst's LSN when the migration began:
    any dst entry above it postdates the flip and must not be overwritten by
    a (re-)copy.
    """

    kind: str            # 'split' | 'merge'
    src_id: int
    dst_id: int
    lo: bytes
    hi: bytes | None     # None = unbounded (last shard)
    cursor: bytes
    epoch_lsn: int

    def covers(self, key: bytes) -> bool:
        return key >= self.lo and (self.hi is None or key < self.hi)

    def pending(self, key: bytes) -> bool:
        return key >= self.cursor and (self.hi is None or key < self.hi)


class RangeShardedStore(BaseShardedStore):
    """Contiguous key ranges over N ParallaxStores, rebalanced incrementally."""

    # contract: coordinator-only
    def __init__(
        self,
        num_shards: int = 4,
        config: StoreConfig | None = None,
        *,
        boundaries: list[bytes] | None = None,
        rebalance_window: int = 1024,
        split_factor: float = 2.0,
        merge_factor: float = 0.25,
        min_split_keys: int = 32,
        max_shards: int = 64,
        auto_rebalance: bool = True,
        migration_batch_keys: int = 128,
        rescale_budget: int = 0,
    ):
        if boundaries is not None:
            if not boundaries or boundaries[0] != b"":
                raise ValueError("boundaries must start with b'' (shard 0 owns the keyspace head)")
            if any(a >= b for a, b in zip(boundaries, boundaries[1:])):
                raise ValueError("boundaries must be strictly increasing")
            num_shards = len(boundaries)
        super().__init__(num_shards, config,
                         migration_batch_keys=migration_batch_keys,
                         rescale_budget=rescale_budget)
        self.boundaries = list(boundaries) if boundaries is not None else _uniform_boundaries(num_shards)
        self.rebalance_window = rebalance_window
        self.split_factor = split_factor
        self.merge_factor = merge_factor
        self.min_split_keys = min_split_keys
        self.max_shards = max_shards
        self.auto_rebalance = auto_rebalance
        self.splits = 0
        self.merges = 0
        self.migrated_keys = 0
        self.migration_ticks = 0
        self.get_fallbacks = 0  # pending-region reads served by the old shard
        # shard identity: the WAL names shards by id, not list position; the
        # registry holds every live store including a merge's draining source
        self._shard_ids = list(range(len(self.shards)))
        self._next_shard_id = len(self.shards)
        self._by_id: dict[int, ParallaxStore] = dict(zip(self._shard_ids, self.shards))
        # the shard-metadata WAL lives on its own (cache-less) device so its
        # bytes are attributable; device_stats() folds it into the aggregate
        self.meta_device = Device(
            cache_bytes=0,
            segment_bytes=self.config.segment_bytes,
            chunk_bytes=self.config.chunk_bytes,
        )
        self.metalog = MetadataLog(self.meta_device)
        self.metalog.append(
            {"kind": "init", "boundaries": list(self.boundaries), "shards": list(self._shard_ids)}
        )
        self._window_base = self._op_counts()

    @staticmethod
    def boundaries_for_keys(keys, num_shards: int) -> list[bytes]:
        """Balanced boundaries from a key sample (equal-population quantiles)."""
        ks = sorted(set(keys))
        bounds = [b""]
        for i in range(1, num_shards):
            b = ks[len(ks) * i // num_shards]
            if b > bounds[-1]:
                bounds.append(b)
        return bounds

    @classmethod
    def for_keys(cls, keys, num_shards: int, config: StoreConfig | None = None, **kw) -> "RangeShardedStore":
        """Pre-split on a key sample: see :meth:`boundaries_for_keys`."""
        return cls(config=config, boundaries=cls.boundaries_for_keys(keys, num_shards), **kw)

    # ---------------------------------------------------------------- routing
    def shard_of(self, key: bytes) -> int:
        return bisect.bisect_right(self.boundaries, key) - 1

    def bounds(self, i: int) -> tuple[bytes, bytes | None]:
        """Shard ``i``'s owned range ``[lo, hi)`` (``hi=None`` = unbounded)."""
        hi = self.boundaries[i + 1] if i + 1 < len(self.boundaries) else None
        return self.boundaries[i], hi

    @property
    def migration(self) -> MigrationState | None:
        """The single in-flight migration leg, or the first of a rescale's
        concurrent legs (compat view over ``self.migrations``)."""
        return self._migrations[0] if self._migrations else None

    def _leg_for_key(self, key: bytes) -> MigrationState | None:
        """The leg whose pending window holds ``key`` (legs' moved spans are
        disjoint, so at most one matches)."""
        for m in self._migrations:
            if m.pending(key):
                return m
        return None

    def _leg_for_dst(self, sid: int) -> MigrationState | None:
        """The leg migrating *into* shard id ``sid`` (range plans never give
        one destination two legs: split destinations are fresh shards, merge
        destinations are pairwise non-adjacent)."""
        for m in self._migrations:
            if m.dst_id == sid:
                return m
        return None

    def _store_of_id(self, sid: int) -> ParallaxStore:
        return self._by_id[sid]

    def _all_stores(self) -> list[ParallaxStore]:
        return list(self._by_id.values())

    def _new_shard(self) -> ParallaxStore:
        store = super()._new_shard()
        if store.lifetime is not None:
            # lifetime-aware shards under the range front-end journal their
            # adaptive-cutoff cutovers through the metadata WAL instead of
            # self-applying (record-then-apply; replayed on recovery), and
            # every value-log segment reclaim is fenced behind a WAL record
            # so the crash-point harness can enumerate the copy->reclaim
            # window of a class migration
            store.cutoff_autonomous = False
            store.gc_fence = (
                lambda log_name, segment_id, s=store:
                self._journal_gc_reclaim(s, log_name, segment_id)
            )
        return store

    def _register(self, store: ParallaxStore) -> int:
        sid = self._next_shard_id
        self._next_shard_id += 1
        self._by_id[sid] = store
        return sid

    # ------------------------------------------------------------- point read
    def _get_from(self, sid: int, key: bytes) -> bytes | None:
        """Double-routing read for a key in the pending region: the new owner
        answers authoritatively — even with a tombstone — iff its newest entry
        postdates the migration epoch (it was written after the ownership
        flip).  Anything older is pre-flip residue (a merge destination keeps
        stale tombstones from an earlier split's ranged delete in the absorbed
        range, and possibly stale live copies from a crashed one) and must
        defer to the draining old shard, costing one extra front-end probe.
        """
        m = self._leg_for_key(key)
        if m is not None:
            dst = self._by_id[m.dst_id]
            entry = dst.index_entry(key)  # pure index walk, free
            if entry is not None and entry.lsn > m.epoch_lsn:
                return dst.get(key)
            # the one front-end counter mutation that can run on an executor
            # worker thread (the migration pair's serialized queue): locked so
            # it never races the coordinator's batch-level counter bumps
            with self._stats_lock:
                self.get_probes += 1
                self.get_fallbacks += 1
            return self._by_id[m.src_id].get(key)
        return self.shards[sid].get(key)

    # ------------------------------------------------------------------- scan
    # contract: coordinator-only
    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Range-local scan: only shards overlapping ``[start, ...)`` are probed.

        Ranges are ordered and each shard's result is sorted, so concatenation
        is the global sorted order — no merge.  Results are clipped to each
        shard's owned range so stale copies left behind by a crashed migration
        (always at or past the shard's upper bound) can never surface.  While
        a migration is in flight, the migrating shard's rows are the merge of
        the new owner with the old shard's pending remainder (old rows only
        where the new owner holds no entry), costing one extra scan probe.
        """
        self.scans += 1
        out: list[tuple[bytes, bytes]] = []
        i = self.shard_of(start)
        while i < len(self.shards) and len(out) < count:
            self.scan_probes += 1
            lo, hi = self.bounds(i)
            for key, value in self._shard_rows(i, max(start, lo), count - len(out)):
                if hi is not None and key >= hi:
                    break
                out.append((key, value))
                if len(out) >= count:
                    break
            i += 1
        self._after_batch()  # scans feed the skew window like batched ops
        return out

    # contract: coordinator-only
    def iter_rows(self, start: bytes = b""):
        """Lazy range-local row stream: shards stream one at a time in
        boundary order (their output is already globally sorted), each pulled
        on demand, so rows never consumed are never read or charged.  A shard
        that is the destination of an in-flight migration is served through
        the eager merged view (:meth:`_shard_rows` — the double-routed
        resolution needs both sides' whole pending window); every other shard
        streams through :meth:`ParallaxStore.iter_range` clipped to its owned
        range.  Probe accounting matches ``scan``: one ``scan_probes`` per
        shard entered (plus the draining source, inside ``_shard_rows``) —
        shards the consumer never reaches are never probed.
        """
        self.scans += 1
        return self._iter_rows(start)

    # contract: coordinator-only
    def _iter_rows(self, start: bytes):
        i = self.shard_of(start)
        while i < len(self.shards):
            self.scan_probes += 1
            lo, hi = self.bounds(i)
            first = max(start, lo)
            if self._leg_for_dst(self._shard_ids[i]) is not None:
                for key, value in self._shard_rows(i, first, 1 << 62):
                    if hi is not None and key >= hi:
                        break
                    yield (key, value)
            else:
                # clipping at hi keeps stale post-bound residue from a crashed
                # migration invisible, exactly like scan's per-shard clip
                yield from self.shards[i].iter_range(first, hi)
            i += 1

    # contract: coordinator-only
    def _shard_rows(self, i: int, start: bytes, need: int) -> list[tuple[bytes, bytes]]:
        """Up to ``need`` sorted live rows of shard ``i`` from ``start``,
        merged with the draining source's pending remainder when shard ``i``
        is the destination of an in-flight migration.

        The merged view is resolved per key from index walks on both sides
        (free, like ``live_keys_in``), and only the rows actually returned
        pay a value read — the device cost of the extra probe the front-end
        counters report.  Resolution rule (the scan form of ``_get_from``):
        inside the pending window the owner's entry counts only when it
        postdates the flip — a post-flip tombstone keeps suppressing the
        stale source copy — while pre-flip residue (stale copies/tombstones
        from an earlier crashed split) defers to the draining source.
        Truncation is safe because both walks cover the *whole* window, so
        the first ``need`` resolved keys are the true merged prefix.
        """
        shard = self.shards[i]
        m = self._leg_for_dst(self._shard_ids[i])
        if m is None:
            return shard.scan(start, need)
        pend_lo = max(start, m.cursor)
        if m.hi is not None and pend_lo >= m.hi:
            return shard.scan(start, need)  # scan window is past the pending region
        shard.stats.scans += 1  # the owner serves the scan (skew signal)
        out: list[tuple[bytes, bytes]] = []
        if start < m.cursor:
            # the already-migrated prefix is the owner's alone; if it fills
            # the request the draining source is never consulted (or counted)
            own = shard.newest_entries(start, m.cursor)
            for k in sorted(own):
                e = own[k]
                if e.tombstone:
                    continue
                out.append((k, shard._value_of(e)))
                if len(out) >= need:
                    return out
        self.scan_probes += 1
        src = self._by_id[m.src_id]
        # key -> (answering store, its newest entry)
        resolved = {k: (src, e) for k, e in src.newest_entries(pend_lo, m.hi).items()}
        for k, e in shard.newest_entries(pend_lo, m.hi).items():
            if e.lsn <= m.epoch_lsn:
                continue  # pre-flip residue in the pending window
            resolved[k] = (shard, e)
        for k in sorted(resolved):
            owner, e = resolved[k]
            if e.tombstone:
                continue
            out.append((k, owner._value_of(e)))
            if len(out) >= need:
                break
        return out

    # ------------------------------------------------------------ batched ops
    # batch boundaries (BaseShardedStore's batched ops and gc_tick — which is
    # where ycsb.execute lands) are where migrations advance and, when no
    # migration is in flight, where the skew policy runs
    def _after_batch(self) -> None:
        self._drain_cutoff_proposals()
        if self._migrations or self._rescale is not None:
            self.migration_tick()
        elif self.auto_rebalance:
            self.rebalance_tick()

    # ----------------------------------------------- lifetime cutoff cutover
    def _sid_of(self, store: ParallaxStore) -> int:
        for sid, s in self._by_id.items():
            if s is store:
                return sid
        return -1  # unregistered (a split destination pre-record): still fenced

    # contract: flush-before-record
    def _journal_gc_reclaim(self, store: ParallaxStore, log_name: str, segment_id: int) -> None:
        """GC fence (installed on lifetime-enabled shards): the store calls
        this between making its relocations durable and reclaiming the victim
        segment.  The flush is the class-migration durability barrier —
        relocated values must never be covered by a record while they are
        volatile — and the record makes the reclaim a crash-enumerable site:
        a crash *at* the record leaves both copies, and recovery's newest-LSN
        replay keeps exactly one winner (zero lost, zero duplicated keys)."""
        store.flush_all()
        self.metalog.append(
            {"kind": "gc_reclaim", "shard": self._sid_of(store),
             "log": log_name, "segment": segment_id}
        )

    # contract: coordinator-only, record-then-apply
    def _apply_cutoffs(self, sid: int, t_sm: float, t_ml: float) -> None:
        """Durably journal an adaptive-cutoff cutover, then install it.

        Record-then-apply: a crash before the record means the cutover never
        happened (the store keeps proposing from its ring); a crash after it
        is replayed by recovery so the shard's placement policy is identical
        pre- and post-crash."""
        self.metalog.append({"kind": "cutoff", "shard": sid, "t_sm": t_sm, "t_ml": t_ml})
        self._by_id[sid].apply_cutoffs(t_sm, t_ml)

    def _drain_cutoff_proposals(self) -> None:
        """Runs at batch boundaries (sequence points): collect each shard's
        parked cutoff proposal and commit it through the WAL in shard-id
        order (deterministic record stream)."""
        for sid in sorted(self._by_id):
            proposal = self._by_id[sid].take_cutoff_proposal()
            if proposal is not None:
                self._apply_cutoffs(sid, *proposal)

    # ------------------------------------------------------------ rebalancing
    def _op_counts(self) -> list[int]:
        return [
            s.stats.inserts + s.stats.updates + s.stats.deletes + s.stats.gets + s.stats.scans
            for s in self.shards
        ]

    def rebalance_tick(self, force: bool = False) -> int:
        """Evaluate the skew policy over the current op window.

        Returns the number of topology changes *started* (0 or 1).  While a
        migration is in flight the policy is paused — the tick advances the
        migration instead, so at most one range is ever moving.  The window
        is the per-shard op-count delta since the last evaluation; nothing
        happens until ``rebalance_window`` ops accumulate (unless ``force``).
        A split of the hottest qualifying shard is preferred over a merge of
        the coldest qualifying adjacent pair.
        """
        if self._migrations or self._rescale is not None:
            self.migration_tick()
            return 0
        counts = self._op_counts()
        if len(counts) != len(self._window_base):
            # topology changed out-of-band (manual split/merge): restart window
            self._window_base = counts
            return 0
        deltas = [c - b for c, b in zip(counts, self._window_base)]
        total = sum(deltas)
        if (total < self.rebalance_window and not force) or total <= 0:
            return 0
        avg = total / len(self.shards)

        split_idx = None
        if len(self.shards) < self.max_shards:
            hot = max(range(len(deltas)), key=deltas.__getitem__)
            # >=: a shard carrying the whole window on a 2-shard map has
            # delta == split_factor * avg exactly and must still split; a
            # 1-shard map has no skew signal, so any full window qualifies
            if deltas[hot] >= self.split_factor * avg or len(self.shards) == 1:
                split_idx = hot
        merge_idx = None  # merge pair (merge_idx, merge_idx + 1)
        if len(self.shards) > 1:
            cold = min(range(len(self.shards) - 1), key=lambda i: deltas[i] + deltas[i + 1])
            if deltas[cold] + deltas[cold + 1] < self.merge_factor * avg:
                merge_idx = cold

        changed = 0
        if split_idx is not None and self._split(split_idx, background=True):
            changed = 1
        elif merge_idx is not None:
            self._merge(merge_idx, background=True)
            changed = 1
        self._window_base = self._op_counts()
        return changed

    # -------------------------------------------------------------- migration
    def split(self, i: int, at: bytes | None = None, *, background: bool = False) -> bool:
        """Deprecated public surface (warns once): ad-hoc topology mutation is
        engine-owned now — use ``repro.api`` ``Engine.rescale()`` for explicit
        shape changes (the auto-rebalance policy keeps handling skew).
        Delegates to the internal :meth:`_split` unchanged."""
        _warn_deprecated("RangeShardedStore.split", "repro.api Engine.rescale")
        return self._split(i, at, background=background)

    def merge(self, i: int, *, background: bool = False) -> None:
        """Deprecated public surface (warns once): see :meth:`split`.
        Delegates to the internal :meth:`_merge` unchanged."""
        _warn_deprecated("RangeShardedStore.merge", "repro.api Engine.rescale")
        self._merge(i, background=background)

    # contract: coordinator-only, record-then-apply
    def _split(self, i: int, at: bytes | None = None, *, background: bool = False) -> bool:
        """Split shard ``i`` at ``at`` (default: its median live key).

        Creates the new shard, durably records ``split_start`` and flips the
        boundary — from that instant writes in ``[at, hi)`` route to the new
        owner and reads double-route.  With ``background=True`` the key copy
        then proceeds one :meth:`migration_tick` batch at a time; otherwise
        the migration is drained before returning (the PR 2 stop-the-world
        behavior, as a special case).  Only one migration runs at a time: a
        still-active one is drained first.
        """
        self.drain_migration()
        src = self.shards[i]
        lo, hi = self.bounds(i)
        if at is None:
            keys = src.live_keys_in(lo, hi)
            if len(keys) < max(2, self.min_split_keys):
                return False
            at = keys[len(keys) // 2]
        if at <= lo or (hi is not None and at >= hi):
            return False
        dst = self._new_shard()
        dst_id = self._register(dst)
        src_id = self._shard_ids[i]
        # record-then-apply: if the record never lands (crash), the orphan
        # destination is dropped by recovery replay and the split never was
        self.metalog.append(
            {"kind": "split_start", "src": src_id, "dst": dst_id,
             "at": at, "hi": hi, "epoch": dst.lsn}
        )
        self.shards.insert(i + 1, dst)
        self._shard_ids.insert(i + 1, dst_id)
        self.boundaries.insert(i + 1, at)
        dst.pin_tombstones = True  # fence: see _finish_leg
        self._migrations.append(MigrationState("split", src_id, dst_id, at, hi, at, dst.lsn))
        self.splits += 1
        self._window_base = self._op_counts()
        if not background:
            self.drain_migration()
        return True

    # contract: coordinator-only, record-then-apply
    def _merge(self, i: int, *, background: bool = False) -> None:
        """Merge shard ``i+1`` into shard ``i`` (cold-neighbor compaction).

        Durably records ``merge_start`` and drops the boundary — the
        surviving shard owns the combined range immediately, the absorbed
        shard leaves the routed map but keeps draining through double-routed
        reads until its keys are migrated, then retires (stats folded).
        """
        self.drain_migration()
        left, right = self.shards[i], self.shards[i + 1]
        lo, hi = self.bounds(i + 1)
        # NOTE: the surviving shard may hold stale pre-flip entries in the
        # absorbed range (copies/tombstones a crashed earlier split left
        # behind).  They are *not* cleaned here — a one-shot clean would have
        # its own crash window — but swept per batch by migration_tick's
        # residue pass, and masked until then: reads and scans ignore
        # destination entries at or below the migration epoch.
        left_id, right_id = self._shard_ids[i], self._shard_ids[i + 1]
        self.metalog.append(
            {"kind": "merge_start", "src": right_id, "dst": left_id,
             "lo": lo, "hi": hi, "epoch": left.lsn}
        )
        del self.shards[i + 1]
        del self._shard_ids[i + 1]
        del self.boundaries[i + 1]
        left.pin_tombstones = True  # fence: see _finish_leg
        self._migrations.append(MigrationState("merge", right_id, left_id, lo, hi, lo, left.lsn))
        self.merges += 1
        self._window_base = self._op_counts()
        if not background:
            self.drain_migration()

    # contract: coordinator-only
    def rescale(self, new_shards: int, *, budget: int | None = None,
                key_sample=None) -> int:
        """Start an online rescale of the boundary map to ``new_shards``
        ranges; returns the number of migration legs started (0 when nothing
        changes).

        The plan comes from :func:`repro.elastic.remap.plan_rescale`:
        growing adds quantile cuts inside the most populous ranges (keys
        outside the cut spans never move), shrinking merges the lightest
        non-adjacent pairs; ``key_sample`` defaults to the fleet's live keys
        (an index walk — no device traffic).  Every leg is an ordinary
        journaled migration; all legs drain concurrently through
        :meth:`migration_tick` under a shared device-byte budget per tick
        (``budget``, default the store's ``rescale_budget``; 0 =
        unthrottled).  A rescale already in flight raises ``ValueError``; a
        legacy single split/merge leg is drained first, like ``_split`` does.
        """
        from ..elastic.remap import Topology, plan_rescale

        if self._rescale is not None:
            raise ValueError(
                "a rescale is already in flight; drain it first (drain_migration)")
        self.drain_migration()
        n = len(self.shards)
        if key_sample is None:
            key_sample = []
            for i, s in enumerate(self.shards):
                lo, hi = self.bounds(i)
                key_sample.extend(s.live_keys_in(lo, hi))
        plan = plan_rescale(Topology("range", n, tuple(self.boundaries)),
                            new_shards, key_sample=key_sample)
        if not plan.legs:
            return 0
        use_budget = self.rescale_budget if budget is None else budget
        if plan.new_shards > n:
            # split legs: fresh destination shards, one per boundary cut.
            # plan positions are post-rescale; old ids keep the positions of
            # their (surviving) boundaries, cut positions get the new ids
            dsts = [self._new_shard() for _ in plan.legs]
            dst_ids = [self._register(d) for d in dsts]
            ids_by_pos = {plan.boundaries.index(b): sid
                          for b, sid in zip(self.boundaries, self._shard_ids)}
            for leg, sid in zip(plan.legs, dst_ids):
                ids_by_pos[leg.dst] = sid
            new_ids = [ids_by_pos[p] for p in range(plan.new_shards)]
            legs_rec = [["split", ids_by_pos[leg.src], dst_ids[i],
                         leg.lo, leg.hi, dsts[i].lsn]
                        for i, leg in enumerate(plan.legs)]
        else:
            # merge legs: dropped position t drains into the surviving left
            # neighbor (non-adjacent drops guarantee t-1 survives)
            dropped = {leg.src for leg in plan.legs}
            new_ids = [sid for p, sid in enumerate(self._shard_ids)
                       if p not in dropped]
            legs_rec = [["merge", self._shard_ids[leg.src],
                         self._shard_ids[leg.src - 1], leg.lo, leg.hi,
                         self._by_id[self._shard_ids[leg.src - 1]].lsn]
                        for leg in plan.legs]
        return self._start_rescale(plan, legs_rec, new_ids, use_budget)

    # contract: coordinator-only, record-then-apply
    def _start_rescale(self, plan, legs_rec, new_ids, budget: int) -> int:
        """Commit the ``rescale_start`` record — the full post-rescale
        topology plus every leg — then flip the boundary map and install the
        legs.  Record-then-apply: a crash at the record site leaves the old
        topology; replay drops the orphan split destinations and the rescale
        never was."""
        from ..elastic.remap import RescaleState

        self.metalog.append(
            {"kind": "rescale_start", "scheme": "range",
             "boundaries": list(plan.boundaries), "shards": list(new_ids),
             "legs": [list(r) for r in legs_rec],
             "from": plan.old_shards, "to": plan.new_shards, "budget": budget})
        self.boundaries = list(plan.boundaries)
        self._shard_ids = list(new_ids)
        self.shards = [self._by_id[sid] for sid in new_ids]
        for kind, src_id, dst_id, lo, hi, epoch in legs_rec:
            self._by_id[dst_id].pin_tombstones = True  # fence: see _finish_leg
            self._migrations.append(
                MigrationState(kind, src_id, dst_id, lo, hi, lo, epoch))
        self._rescale = RescaleState(plan, budget=budget,
                                     dst_ids=tuple(r[2] for r in legs_rec))
        self._window_base = self._op_counts()
        return len(legs_rec)

    # contract: coordinator-only, record-then-apply, flush-before-record
    def _advance_leg(self, m: MigrationState, max_keys: int | None = None) -> int:
        """Advance one migration leg by one batch; returns keys copied.

        Per-batch ordering (the PR 1/PR 2 discipline at batch granularity):
        copy the batch into the destination → **flush the destination** →
        durably checkpoint the cursor (this record flips ownership of the
        batch) → tombstone the batch out of the source.  A crash anywhere
        re-runs the batch from the last durable cursor; re-copies are
        idempotent because any destination entry newer than the migration
        epoch (an application write since the flip, or the earlier copy
        itself) is left untouched.  Under a rescale the checkpoint/finish
        records carry a ``leg`` key (the destination shard id) so replay can
        advance the right one of several concurrent legs; legacy single-leg
        records are byte-identical to the pre-elastic stream.
        """
        budget = max(1, self.migration_batch_keys if max_keys is None else max_keys)
        src, dst = self._by_id[m.src_id], self._by_id[m.dst_id]
        keys = src.live_keys_in(m.cursor, m.hi)
        batch = keys[:budget]
        last_batch = len(keys) <= budget
        batch_hi = m.hi if last_batch else _next_key(batch[-1])
        # residue sweep: stale pre-flip entries in this tick's window (what a
        # crashed earlier split left in a merge destination) with no
        # authoritative replacement get a post-flip tombstone — the batch's
        # own copies shadow the rest.  Split destinations are fresh (epoch 0),
        # so this never fires for them.
        batch_set = set(batch)
        for key, e in dst.newest_entries(m.cursor, batch_hi).items():
            if e.lsn <= m.epoch_lsn and not e.tombstone and key not in batch_set:
                dst._write(key, b"", tombstone=True, internal=True)
        moved = 0
        if batch:
            for key, value in src.scan_range(batch[0], batch_hi, internal=True):
                cur = dst.index_entry(key)
                if cur is not None and cur.lsn > m.epoch_lsn:
                    continue  # written since the flip (app write or earlier copy)
                dst._write(key, value, tombstone=False, internal=True)
                moved += 1
        # durability barrier: the batch (and the residue tombstones) must be
        # durable in the new owner before the record that flips ownership
        dst.flush_all()
        if batch:
            new_cursor = batch_hi if batch_hi is not None else _next_key(batch[-1])
            rec = {"kind": "checkpoint", "cursor": new_cursor}
            if self._rescale is not None:
                rec["leg"] = m.dst_id  # names one of the concurrent legs
            self.metalog.append(rec)
            m.cursor = new_cursor
            # only now does the source drop the batch (tombstones through the
            # normal write path); losing them in a crash leaves stale copies
            # below the cursor — unreachable: reads and scans stop consulting
            # the source once a key's ownership has flipped
            src.delete_range(batch[0], batch_hi, internal=True, keys=batch)
            self.migrated_keys += len(batch)
        if last_batch:
            rec = {"kind": "finish"}
            if self._rescale is not None:
                rec["leg"] = m.dst_id
            self.metalog.append(rec)
            self._finish_leg(m)
        return moved

    def _finish_leg(self, m: MigrationState) -> None:
        # lift the tombstone fence: while the migration was in flight, the
        # destination's tombstones were the only evidence that a key was
        # deleted after the flip — compaction must not drop them at the last
        # level or the copy-skip rule / read fallback would resurrect the
        # source's stale copy.  With the source drained (and, for merges,
        # retired) they may be collected again.
        self._migrations.remove(m)
        if self._leg_for_dst(m.dst_id) is None:
            self._by_id[m.dst_id].pin_tombstones = False
        if m.kind == "merge":
            self._retire_by_id(m.src_id)
        if self._rescale is not None:
            self._rescale.legs_done += 1
        self._window_base = self._op_counts()

    def _retire_by_id(self, sid: int) -> None:
        """Drop a drained store from the registry, folding its history.

        Idempotent (recovery replay may retire a shard the live path already
        retired — or vice versa); folding happens exactly once, at the drop.
        """
        store = self._by_id.pop(sid, None)
        if store is not None:
            self._retire_shard_stats(store)

    # -------------------------------------------------------------- snapshots
    # contract: coordinator-only, flush-before-record, rename-before-truncate
    def snapshot_metadata(self, *, truncate: bool = True) -> int:
        """Append a ``snapshot`` record — the whole topology in one record —
        and (by default) truncate the WAL prefix it replaces.

        Ordering is rename-before-truncate: every shard store is flushed
        first (the data the record points at is durable before the record),
        the snapshot record commits synchronously, and only then is the
        now-redundant prefix destroyed.  A crash *at* the snapshot's record
        site therefore leaves the full old stream — recovery replays from
        genesis exactly as before — while a crash any time after it replays
        O(delta): the snapshot record plus whatever followed it.  Returns the
        snapshot record's index (0 after truncation).
        """
        for store in self._all_stores():
            store.flush_all()
        m = self.migration if self._rescale is None else None
        rec = {
            "kind": "snapshot",
            "boundaries": list(self.boundaries),
            "shards": list(self._shard_ids),
            "next_shard_id": self._next_shard_id,
            "migration": None if m is None else dataclasses.asdict(m),
            # adapted per-shard cutoffs ride the snapshot so truncating
            # the WAL prefix doesn't forget journaled cutoff cutovers
            "cutoffs": [
                [sid, store.policy.t_sm, store.policy.t_ml]
                for sid, store in sorted(self._by_id.items())
                if store.lifetime is not None
            ],
        }
        if self._rescale is not None:
            # an in-flight rescale rides the snapshot (key absent otherwise,
            # so legacy snapshot records stay byte-identical): the active
            # legs at their cursors plus the coordinator bookkeeping
            rec["rescale"] = self._rescale_record()
        idx = self.metalog.append(rec)
        if truncate:
            self.metalog.truncate(idx)
            idx = 0
        return idx

    def state_snapshot(self) -> dict:
        """Portable logical state: topology + per-store rows (by shard id).

        Includes the draining source of an in-flight migration and the full
        :class:`MigrationState`, so a restore resumes the migration exactly
        where the snapshot caught it — likewise a whole in-flight rescale
        (every concurrent leg plus the coordinator bookkeeping, under the
        ``"rescale"`` key).  Used by ``repro.api.Engine.snapshot`` /
        ``clone``; the inverse is :meth:`load_state`.
        """
        m = self.migration if self._rescale is None else None
        state = {
            "kind": "range",
            "boundaries": list(self.boundaries),
            "shard_ids": list(self._shard_ids),
            "next_shard_id": self._next_shard_id,
            "migration": None if m is None else dataclasses.asdict(m),
            "stores": [
                [sid, {"rows": store.snapshot_rows(), "lsn": store.lsn}]
                for sid, store in sorted(self._by_id.items())
            ],
        }
        if self._rescale is not None:
            state["rescale"] = self._rescale_record()
        return state

    def _rescale_record(self) -> dict:
        """Portable form of the in-flight rescale: the active legs at their
        cursors plus everything needed to rebuild the plan and coordinator
        (``RescalePlan``/``RescaleState``) on replay or restore."""
        r = self._rescale
        return {
            "legs": [dataclasses.asdict(m) for m in self._migrations],
            "plan_legs": [[l.kind, l.src, l.dst] for l in r.plan.legs],
            "from": r.plan.old_shards,
            "to": r.plan.new_shards,
            "moved_fraction": r.plan.moved_fraction,
            "budget": r.budget,
            "dst_ids": list(r.dst_ids),
            "keys_moved": r.keys_moved,
            "ticks": r.ticks,
            "next_leg": r.next_leg,
        }

    def _load_rescale(self, rec: dict, boundaries) -> None:
        """Inverse of :meth:`_rescale_record`: install legs + coordinator."""
        from ..elastic.remap import RescaleLeg, RescalePlan, RescaleState

        self._migrations = [MigrationState(**d) for d in rec["legs"]]
        plan = RescalePlan(
            "range", rec["from"], rec["to"],
            tuple(RescaleLeg(k, s, d) for k, s, d in rec["plan_legs"]),
            tuple(boundaries), rec["moved_fraction"])
        state = RescaleState(plan, budget=rec["budget"],
                             dst_ids=tuple(rec["dst_ids"]))
        state.legs_done = len(plan.legs) - len(self._migrations)
        state.keys_moved = rec["keys_moved"]
        state.ticks = rec["ticks"]
        state.next_leg = rec["next_leg"]
        self._rescale = state

    def load_state(self, state: dict) -> None:
        """Replace this store's contents with a :meth:`state_snapshot`.

        Builds fresh shard stores (tombstone fences installed *before* rows
        load, so a migration destination's post-epoch tombstones survive
        compaction during the load), installs the topology and in-flight
        migration, and roots the metadata WAL at a fresh truncated snapshot
        record — the restored store recovers without the donor's history.
        """
        if state.get("kind") != "range":
            raise ValueError(f"expected a range-store state, got {state.get('kind')!r}")
        rescale = state.get("rescale")
        if rescale is not None:
            migrations = [MigrationState(**d) for d in rescale["legs"]]
        else:
            m = state["migration"]
            migrations = [] if m is None else [MigrationState(**m)]
        pinned = {m.dst_id for m in migrations}
        by_id: dict[int, ParallaxStore] = {}
        for sid, snap in state["stores"]:
            store = self._new_shard()
            store.pin_tombstones = sid in pinned
            store.load_rows(snap["rows"], snap["lsn"])
            by_id[sid] = store
        self.boundaries = list(state["boundaries"])
        self._shard_ids = list(state["shard_ids"])
        self._by_id = by_id
        self.shards = [by_id[sid] for sid in self._shard_ids]
        if rescale is not None:
            self._load_rescale(rescale, state["boundaries"])
        else:
            self._migrations = migrations
            self._rescale = None
        self._next_shard_id = max(state["next_shard_id"], max(by_id, default=-1) + 1)
        self.snapshot_metadata(truncate=True)
        self._window_base = self._op_counts()

    # --------------------------------------------------------------- recovery
    def recover(self) -> None:
        """Rebuild topology + in-flight migration from the metadata WAL, then
        recover every live store.

        The WAL — not the possibly-mid-mutation in-memory maps — is the source
        of truth: replay reconstructs ``boundaries``/``shards`` from the
        ``init`` record forward, restores the :class:`MigrationState` of an
        unfinished migration at its last durable checkpoint (it resumes on
        subsequent ticks), and drops orphan stores whose start record never
        landed.  Shard *objects* are looked up by id in the registry: their
        contents are the (simulated) device's contents, which survive the
        crash just like a single ``ParallaxStore``'s do.
        """
        self._replay_metalog()
        for s in self._all_stores():
            s.recover()

    def _replay_metalog(self) -> None:
        from ..elastic.remap import RescaleLeg, RescalePlan, RescaleState

        boundaries: list[bytes] = []
        ids: list[int] = []
        migrations: list[MigrationState] = []
        rescale_state: RescaleState | None = None
        snap_next = 0
        cutoffs: dict[int, tuple[float, float]] = {}
        for rec in self.metalog.replay():
            kind = rec["kind"]
            if kind == "init":
                boundaries = list(rec["boundaries"])
                ids = list(rec["shards"])
            elif kind == "snapshot":
                # a full-state reset mid-stream: after truncation this is
                # records[0] and replay proceeds from here instead of genesis
                boundaries = list(rec["boundaries"])
                ids = list(rec["shards"])
                m = rec["migration"]
                migrations = [] if m is None else [MigrationState(**m)]
                r = rec.get("rescale")
                if r is not None:
                    migrations = [MigrationState(**d) for d in r["legs"]]
                    plan = RescalePlan(
                        "range", r["from"], r["to"],
                        tuple(RescaleLeg(k, s, d) for k, s, d in r["plan_legs"]),
                        tuple(boundaries), r["moved_fraction"])
                    rescale_state = RescaleState(
                        plan, budget=r["budget"], dst_ids=tuple(r["dst_ids"]))
                    rescale_state.legs_done = len(plan.legs) - len(migrations)
                    rescale_state.keys_moved = r["keys_moved"]
                    rescale_state.ticks = r["ticks"]
                    rescale_state.next_leg = r["next_leg"]
                else:
                    rescale_state = None
                snap_next = max(snap_next, rec["next_shard_id"])
                for sid, t_sm, t_ml in rec.get("cutoffs", ()):
                    cutoffs[sid] = (t_sm, t_ml)
            elif kind == "cutoff":
                # journaled adaptive-cutoff cutover: last record wins per shard
                cutoffs[rec["shard"]] = (rec["t_sm"], rec["t_ml"])
            elif kind == "gc_reclaim":
                # GC reclaim fence: purely a crash-enumerable sequence point —
                # the relocations it covers are replayed from the value logs
                pass
            elif kind == "split_start":
                pos = ids.index(rec["src"])
                boundaries.insert(pos + 1, rec["at"])
                ids.insert(pos + 1, rec["dst"])
                migrations = [MigrationState(
                    "split", rec["src"], rec["dst"], rec["at"], rec["hi"], rec["at"], rec["epoch"]
                )]
            elif kind == "merge_start":
                pos = ids.index(rec["src"])
                del boundaries[pos]
                del ids[pos]
                migrations = [MigrationState(
                    "merge", rec["src"], rec["dst"], rec["lo"], rec["hi"], rec["lo"], rec["epoch"]
                )]
            elif kind == "rescale_start":
                # the whole flip in one record: topology after, one leg per
                # moving pair (all start at their span's lo)
                boundaries = list(rec["boundaries"])
                ids = list(rec["shards"])
                migrations = [
                    MigrationState(k, src, dst, lo, hi, lo, epoch)
                    for k, src, dst, lo, hi, epoch in rec["legs"]]
                plan = RescalePlan(
                    "range", rec["from"], rec["to"],
                    tuple(RescaleLeg(k, s, d)
                          for k, s, d, _lo, _hi, _e in rec["legs"]),
                    tuple(boundaries), 0.0)
                rescale_state = RescaleState(
                    plan, budget=rec["budget"],
                    dst_ids=tuple(r[2] for r in rec["legs"]))
            elif kind == "checkpoint":
                m = (migrations[0] if "leg" not in rec else
                     next(x for x in migrations if x.dst_id == rec["leg"]))
                m.cursor = rec["cursor"]
            elif kind == "finish":
                m = (migrations[0] if "leg" not in rec else
                     next(x for x in migrations if x.dst_id == rec["leg"]))
                if m.kind == "merge":
                    self._retire_by_id(m.src_id)
                migrations.remove(m)
                if rescale_state is not None:
                    rescale_state.legs_done += 1
            elif kind == "rescale_finish":
                migrations = []
                rescale_state = None
        live = set(ids)
        for m in migrations:
            live.update((m.src_id, m.dst_id))
        for sid in [s for s in self._by_id if s not in live]:
            # a destination created just before its start record was lost:
            # empty by construction (data only moves after the record), drop
            del self._by_id[sid]
        self.boundaries = boundaries
        self._shard_ids = ids
        self.shards = [self._by_id[sid] for sid in ids]
        self._migrations = migrations
        self._rescale = rescale_state
        # rebuild the tombstone fence from the WAL (it is derived state): only
        # the destinations of in-flight migration legs, if any, are pinned
        pinned = {m.dst_id for m in migrations}
        for sid, store in self._by_id.items():
            store.pin_tombstones = sid in pinned
            applied = cutoffs.get(sid)
            if applied is not None and store.lifetime is not None:
                store.apply_cutoffs(*applied)
        self._next_shard_id = max(self._next_shard_id, snap_next, max(live, default=0) + 1)
        self._window_base = self._op_counts()

    # ------------------------------------------------------------------ stats
    def device_stats(self) -> DeviceStats:
        total = super().device_stats()
        for f in dataclasses.fields(DeviceStats):
            setattr(total, f.name, getattr(total, f.name) + getattr(self.meta_device.stats, f.name))
        return total

    def space_bytes(self) -> int:
        # retained WAL bytes, not lifetime-appended: truncation reclaims space
        return super().space_bytes() + self.metalog.log_bytes

    def device_time(self, policy: str = "ideal") -> float:
        """Shard devices combined under the overlap policy, plus the metadata
        WAL's serial commits — synchronous records block the protocol, they
        never overlap shard traffic regardless of policy."""
        return super().device_time(policy) + self.meta_device.device_time()

    def checkpoint_stats(self) -> dict:
        out = super().checkpoint_stats()
        m = self.migration if self._rescale is None else None
        out.update(
            boundaries=list(self.boundaries),
            splits=self.splits,
            merges=self.merges,
            migrated_keys=self.migrated_keys,
            migration_ticks=self.migration_ticks,
            get_fallbacks=self.get_fallbacks,
            migration=None if m is None else dataclasses.asdict(m),
            meta_records=self.metalog.n_records,
            meta_bytes=self.metalog.bytes_appended,
        )
        if self._rescale is not None:
            out["rescale"] = self._rescale.progress()
        return out


__all__ = ["MigrationState", "RangeShardedStore"]
