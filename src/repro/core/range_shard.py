"""Range-partitioned sharding with skew-driven splits/merges.

:class:`RangeShardedStore` partitions the keyspace into contiguous ranges —
shard ``i`` owns ``[boundaries[i], boundaries[i+1])`` with ``boundaries[0] ==
b""`` and the last range unbounded.  Point ops route by binary search over the
sorted boundary list; ``scan(start, count)`` touches **only the shards whose
range overlaps the scan** and concatenates their results (each shard's output
is already globally ordered — no k-way merge), which is what makes range
partitioning win scan workloads (YCSB E) where the hash-partitioned
:class:`~repro.core.shard.ShardedStore` must fan out to all N shards.

When to pick which front-end:

* **hash** (``ShardedStore``) — point-op dominated workloads; crc32 routing is
  perfectly uniform so no shard ever runs hot, but scans pay N-way fan-out.
* **range** (this class) — scan-heavy or locality-sensitive workloads; scans
  are range-local, but a zipfian hot-spot concentrates load on one shard, so
  the shard map must adapt.

The adaptation is skew-driven rebalancing: per-shard op counters (the shards'
own :class:`~repro.core.store.StoreStats`) are windowed by
:meth:`rebalance_tick`; a shard carrying more than ``split_factor`` times the
average window load splits at its median key, and the coldest adjacent pair
whose combined load falls under ``merge_factor`` times the average merges.
``ycsb.execute``'s batch mode ticks the policy after every batch.

Key migration rides the normal durability path (the same ordering discipline
as GC relocation-before-reclaim, PR 1): a split **copies** the moved range
into the new shard via ``scan_range`` + puts, **flushes the new shard's
logs**, then atomically adopts the boundary, and only then tombstones the
moved range out of the old shard via ``delete_range``.  A crash at any point
is safe: before the boundary flips, the old shard is still authoritative and
fully intact; after it flips, the new shard is durable, and any stale copies
the crash leaves in the old shard are unreachable — routing directs their
keys elsewhere and per-shard scans are clipped to the shard's owned range.
Boundary updates themselves model a tiny WAL'd metadata record and survive
``crash()``.

Migration traffic is charged to the device like any other put/delete, but it
is *internal* work: like GC relocations, it does not count toward application
op/byte stats.
"""
from __future__ import annotations

import bisect

from .shard import BaseShardedStore
from .store import StoreConfig


def _uniform_boundaries(num_shards: int) -> list[bytes]:
    """Evenly spaced 2-byte prefixes over the full byte keyspace."""
    out = [b""]
    for i in range(1, num_shards):
        v = (1 << 16) * i // num_shards
        out.append(bytes([v >> 8, v & 0xFF]))
    return out


class RangeShardedStore(BaseShardedStore):
    """Contiguous key ranges over N ParallaxStores, rebalanced on skew."""

    def __init__(
        self,
        num_shards: int = 4,
        config: StoreConfig | None = None,
        *,
        boundaries: list[bytes] | None = None,
        rebalance_window: int = 1024,
        split_factor: float = 2.0,
        merge_factor: float = 0.25,
        min_split_keys: int = 32,
        max_shards: int = 64,
        auto_rebalance: bool = True,
    ):
        if boundaries is not None:
            if not boundaries or boundaries[0] != b"":
                raise ValueError("boundaries must start with b'' (shard 0 owns the keyspace head)")
            if any(a >= b for a, b in zip(boundaries, boundaries[1:])):
                raise ValueError("boundaries must be strictly increasing")
            num_shards = len(boundaries)
        super().__init__(num_shards, config)
        self.boundaries = list(boundaries) if boundaries is not None else _uniform_boundaries(num_shards)
        self.rebalance_window = rebalance_window
        self.split_factor = split_factor
        self.merge_factor = merge_factor
        self.min_split_keys = min_split_keys
        self.max_shards = max_shards
        self.auto_rebalance = auto_rebalance
        self.splits = 0
        self.merges = 0
        self.migrated_keys = 0
        self._window_base = self._op_counts()

    @classmethod
    def for_keys(cls, keys, num_shards: int, config: StoreConfig | None = None, **kw) -> "RangeShardedStore":
        """Balanced boundaries from a key sample (equal-population quantiles)."""
        ks = sorted(set(keys))
        bounds = [b""]
        for i in range(1, num_shards):
            b = ks[len(ks) * i // num_shards]
            if b > bounds[-1]:
                bounds.append(b)
        return cls(config=config, boundaries=bounds, **kw)

    # ---------------------------------------------------------------- routing
    def shard_of(self, key: bytes) -> int:
        return bisect.bisect_right(self.boundaries, key) - 1

    def bounds(self, i: int) -> tuple[bytes, bytes | None]:
        """Shard ``i``'s owned range ``[lo, hi)`` (``hi=None`` = unbounded)."""
        hi = self.boundaries[i + 1] if i + 1 < len(self.boundaries) else None
        return self.boundaries[i], hi

    # ------------------------------------------------------------------- scan
    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Range-local scan: only shards overlapping ``[start, ...)`` are probed.

        Ranges are ordered and each shard's result is sorted, so concatenation
        is the global sorted order — no merge.  Results are clipped to each
        shard's owned range so stale copies left behind by a crashed migration
        (always at or past the shard's upper bound) can never surface.
        """
        self.scans += 1
        out: list[tuple[bytes, bytes]] = []
        i = self.shard_of(start)
        while i < len(self.shards) and len(out) < count:
            self.scan_probes += 1
            lo, hi = self.bounds(i)
            for key, value in self.shards[i].scan(max(start, lo), count - len(out)):
                if hi is not None and key >= hi:
                    break
                out.append((key, value))
            i += 1
        self._after_batch()  # scans feed the skew window like batched ops
        return out

    # ------------------------------------------------------------ batched ops
    # batch boundaries (BaseShardedStore's batched ops and gc_tick — which is
    # where ycsb.execute lands) are the points where the skew policy runs
    def _after_batch(self) -> None:
        if self.auto_rebalance:
            self.rebalance_tick()

    # ------------------------------------------------------------ rebalancing
    def _op_counts(self) -> list[int]:
        return [
            s.stats.inserts + s.stats.updates + s.stats.deletes + s.stats.gets + s.stats.scans
            for s in self.shards
        ]

    def rebalance_tick(self, force: bool = False) -> int:
        """Evaluate the skew policy over the current op window.

        Returns the number of topology changes applied (0, 1 split, 1 merge,
        or both).  The window is the per-shard op-count delta since the last
        evaluation; nothing happens until ``rebalance_window`` ops accumulate
        (unless ``force``).  At most one split (the hottest qualifying shard)
        and one merge (the coldest qualifying adjacent pair) per tick keeps
        migrations incremental.
        """
        counts = self._op_counts()
        if len(counts) != len(self._window_base):
            # topology changed out-of-band (manual split/merge): restart window
            self._window_base = counts
            return 0
        deltas = [c - b for c, b in zip(counts, self._window_base)]
        total = sum(deltas)
        if (total < self.rebalance_window and not force) or total <= 0:
            return 0
        avg = total / len(self.shards)

        # decide both actions from this window's deltas before mutating
        split_idx = None
        if len(self.shards) < self.max_shards:
            hot = max(range(len(deltas)), key=deltas.__getitem__)
            # >=: a shard carrying the whole window on a 2-shard map has
            # delta == split_factor * avg exactly and must still split; a
            # 1-shard map has no skew signal, so any full window qualifies
            if deltas[hot] >= self.split_factor * avg or len(self.shards) == 1:
                split_idx = hot
        merge_idx = None  # merge pair (merge_idx, merge_idx + 1)
        if len(self.shards) > 1:
            cold = min(range(len(self.shards) - 1), key=lambda i: deltas[i] + deltas[i + 1])
            if deltas[cold] + deltas[cold + 1] < self.merge_factor * avg:
                merge_idx = cold
        if merge_idx is not None and split_idx is not None and merge_idx in (split_idx - 1, split_idx):
            merge_idx = None  # never merge a shard we are about to split

        changed = 0
        if split_idx is not None and self.split(split_idx):
            changed += 1
            if merge_idx is not None and merge_idx > split_idx:
                merge_idx += 1  # the split inserted a shard before the pair
        if merge_idx is not None:
            self.merge(merge_idx)
            changed += 1
        self._window_base = self._op_counts()
        return changed

    def split(self, i: int, at: bytes | None = None) -> bool:
        """Split shard ``i`` at ``at`` (default: its median live key).

        Ordering discipline (crash-safe at every step, see module docstring):
        copy -> flush new shard -> adopt boundary -> tombstone old range.
        """
        src = self.shards[i]
        lo, hi = self.bounds(i)
        if at is None:
            keys = src.live_keys_in(lo, hi)
            if len(keys) < max(2, self.min_split_keys):
                return False
            at = keys[len(keys) // 2]
        if at <= lo or (hi is not None and at >= hi):
            return False
        # 1. copy the moved range through the normal read path; writes into
        #    the new shard are internal (not application traffic), like GC
        #    relocations
        dst = self._new_shard()
        rows = src.scan_range(at, hi, internal=True)
        for key, value in rows:
            dst._write(key, value, tombstone=False, internal=True)
        # 2. durability barrier: the moved data must be durable before the
        #    boundary flips (same ordering as GC relocations before segment
        #    reclaim — PR 1)
        dst.flush_all()
        # 3. atomically adopt the new topology (a tiny WAL'd metadata record)
        self.shards.insert(i + 1, dst)
        self.boundaries.insert(i + 1, at)
        # 4. only now does the old shard drop the moved range (tombstones for
        #    exactly the rows copied in step 1, through the normal write
        #    path); a crash that loses some of these tombstones leaves stale
        #    copies at/past the shard's new upper bound — unreachable via
        #    routing/clipped scans
        src.delete_range(at, hi, internal=True, keys=[k for k, _ in rows])
        self.splits += 1
        self.migrated_keys += len(rows)
        self._window_base = self._op_counts()
        return True

    def merge(self, i: int) -> None:
        """Merge shard ``i+1`` into shard ``i`` (cold-neighbor compaction).

        Same ordering as :meth:`split`: copy into the surviving shard, flush
        it, then drop the boundary; the absorbed shard is discarded wholesale
        (no ranged delete needed — its device disappears with it).
        """
        left, right = self.shards[i], self.shards[i + 1]
        lo, hi = self.bounds(i + 1)
        # clear any stale copies a crashed earlier split left in the surviving
        # shard beyond its boundary: extending its range would make them
        # reachable again, resurrecting keys deleted in the absorbed shard
        left.delete_range(lo, hi, internal=True)
        rows = right.scan_range(lo, hi, internal=True)
        for key, value in rows:
            left._write(key, value, tombstone=False, internal=True)
        left.flush_all()
        self._retire_shard_stats(right)
        del self.shards[i + 1]
        del self.boundaries[i + 1]
        self.merges += 1
        self.migrated_keys += len(rows)
        self._window_base = self._op_counts()

    # ------------------------------------------------------------------ stats
    def checkpoint_stats(self) -> dict:
        out = super().checkpoint_stats()
        out.update(
            boundaries=list(self.boundaries),
            splits=self.splits,
            merges=self.merges,
            migrated_keys=self.migrated_keys,
        )
        return out


__all__ = ["RangeShardedStore"]
