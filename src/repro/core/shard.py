"""Sharded batch front-ends: N independent ParallaxStore shards behind one API.

First step from the single-store simulation toward a serving-scale system
(ROADMAP north star; Scavenger-style placement-aware sharding on top of the
paper's hybrid placement).  Two partitioning schemes share the plumbing here:

* :class:`ShardedStore` (this module) — **hash** partitioning with
  ``zlib.crc32`` routing: stable across processes, perfectly uniform load, but
  no key locality — every ``scan`` must consult all N shards and k-way merge.
* :class:`repro.core.range_shard.RangeShardedStore` — **range** partitioning:
  shards own contiguous key ranges, so a scan touches only the shards that
  overlap the range, at the cost of skew (hot ranges) which it repairs with
  load-driven splits/merges.

Pick hash when the workload is point-op dominated (YCSB A-D) and uniformity
matters more than scans; pick range when scans matter (YCSB E) or when the
shard map must adapt to hot-spots.

Each shard is a full :class:`~repro.core.store.ParallaxStore` with its own
:class:`~repro.core.io.Device`, LSM tree, logs and block cache — the model of
one store-per-core (or per-machine) deployment.  The shared base class
:class:`BaseShardedStore` adds:

* batched ``put_many`` / ``update_many`` / ``delete_many`` / ``get_many`` that
  group a batch by destination shard and drain each shard's sub-batch in one
  pass (order within a shard preserves batch order, so duplicate keys in one
  batch resolve to the last write like the sequential path);
* aggregated stats/amplification, and a parallel device-time model
  (``device_time`` = max over shards) used by the shard benchmarks to turn
  byte counts into a throughput proxy for N devices.

Crash/recover delegates to every shard.  Shard LSN counters are independent,
so ``crash()`` returns the per-shard recovery cutoffs — each shard recovers
to its own prefix; there is no single global LSN.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import zlib
from typing import Iterable, Iterator, Sequence

from .io import DeviceStats, overlap_time
from .store import ParallaxStore, StoreConfig, StoreStats

# routing uses a different crc32 stream than bloom/cache hashing so shard
# choice is uncorrelated with block placement inside a shard
_ROUTE_SEED = 0xA5A5A5A5


def route(key: bytes, num_shards: int) -> int:
    """Deterministic shard index for a key (crc32, stable across processes)."""
    return zlib.crc32(key, _ROUTE_SEED) % num_shards


class BaseShardedStore:
    """Partitioning-agnostic sharded front-end: batching, stats, crash/recover.

    Subclasses provide the partitioning scheme by implementing
    :meth:`shard_of` (key -> shard index) and :meth:`scan` (global sorted
    scan); everything else — single ops, batched ops, GC, crash/recover and
    stat aggregation — routes through those and is shared.
    """

    # contract: coordinator-only
    def __init__(self, num_shards: int = 4, config: StoreConfig | None = None):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        # the front-end is bloom-filtered by default (the bare store keeps the
        # paper's filterless index); an explicit config is taken as-is
        self.config = config or StoreConfig(bloom_bits_per_key=10)
        self.shards = [self._new_shard() for _ in range(num_shards)]
        # front-end scan accounting: how many shards each scan had to consult
        # (the fan-out cost hash partitioning pays and range partitioning
        # avoids); survives topology changes, unlike per-shard counters
        self.scans = 0
        self.scan_probes = 0
        # front-end point-read accounting, same rationale: one probe per shard
        # consulted.  Normally get_probes == gets; during an incremental
        # migration a read that misses the new owner and falls back to the
        # draining old shard costs one extra probe (range front-end only).
        self.gets = 0
        self.get_probes = 0
        # stats of shards retired by topology changes (range-shard merges):
        # folded in here so aggregates never lose traffic history
        self.retired_stats = StoreStats()
        self.retired_device = DeviceStats()
        # Thread-safety (see docs/execution.md): shard stores are only ever
        # touched by one executor task at a time, but the *front-end* counters
        # above are shared.  The serial path is single-threaded and never
        # contends; `repro.core.exec.ShardExecutor` worker threads must hold
        # this lock for any front-end counter mutation (the double-routing
        # read path's fallback probes are the one in-worker site).
        self._stats_lock = threading.Lock()

    def _new_shard(self) -> ParallaxStore:
        return ParallaxStore(dataclasses.replace(self.config))

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def _all_stores(self) -> list[ParallaxStore]:
        """Every live backing store — the routed shards plus any store still
        draining out of the topology (a range-shard merge retires its source
        only once the migration finishes).  Maintenance, crash/recover and
        stat aggregation iterate this, not ``self.shards``."""
        return list(self.shards)

    # ---------------------------------------------------------------- routing
    def shard_of(self, key: bytes) -> int:
        raise NotImplementedError

    def shard_for(self, key: bytes) -> ParallaxStore:
        return self.shards[self.shard_of(key)]

    def _group(self, keys: Iterable[bytes]) -> dict[int, list[int]]:
        """Batch positions grouped by shard, preserving batch order per shard."""
        groups: dict[int, list[int]] = {}
        for pos, key in enumerate(keys):
            groups.setdefault(self.shard_of(key), []).append(pos)
        return groups

    # ------------------------------------------------------------- single ops
    def put(self, key: bytes, value: bytes) -> None:
        self.shard_for(key).put(key, value)

    def update(self, key: bytes, value: bytes) -> None:
        self.shard_for(key).update(key, value)

    def delete(self, key: bytes) -> None:
        self.shard_for(key).delete(key)

    def _get_from(self, sid: int, key: bytes) -> bytes | None:
        """Point-read routed to shard ``sid``; adaptive front-ends override
        this for migration-aware double-routing (and bump ``get_probes`` for
        any extra store they consult)."""
        return self.shards[sid].get(key)

    # contract: coordinator-only
    def get(self, key: bytes) -> bytes | None:
        self.gets += 1
        self.get_probes += 1
        return self._get_from(self.shard_of(key), key)

    # ------------------------------------------------------------ batched ops
    def _after_batch(self) -> None:
        """Hook run after every batched op (and GC tick): adaptive front-ends
        evaluate their policies here; the base class does nothing."""

    def put_many(self, items: Sequence[tuple[bytes, bytes]]) -> None:
        for sid, positions in self._group(k for k, _ in items).items():
            shard = self.shards[sid]
            for pos in positions:
                key, value = items[pos]
                shard.put(key, value)
        self._after_batch()

    def update_many(self, items: Sequence[tuple[bytes, bytes]]) -> None:
        for sid, positions in self._group(k for k, _ in items).items():
            shard = self.shards[sid]
            for pos in positions:
                key, value = items[pos]
                shard.update(key, value)
        self._after_batch()

    def delete_many(self, keys: Sequence[bytes]) -> None:
        for sid, positions in self._group(keys).items():
            shard = self.shards[sid]
            for pos in positions:
                shard.delete(keys[pos])
        self._after_batch()

    # contract: coordinator-only
    def get_many(self, keys: Sequence[bytes]) -> list[bytes | None]:
        out: list[bytes | None] = [None] * len(keys)
        for sid, positions in self._group(keys).items():
            for pos in positions:
                self.gets += 1
                self.get_probes += 1
                out[pos] = self._get_from(sid, keys[pos])
        self._after_batch()
        return out

    # ------------------------------------------------------------------- scan
    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        raise NotImplementedError

    def iter_rows(self, start: bytes = b"") -> Iterator[tuple[bytes, bytes]]:
        """Lazy global sorted row stream from ``start`` (no count bound).

        The cursor behind :class:`repro.api.Iterator`: rows are produced — and
        their device bytes charged — on demand, unlike :meth:`scan`, which
        materializes ``count`` rows per consulted shard up front.  Valid only
        while the store is not written to and the topology does not change;
        mutate, then take a fresh iterator.  Unlike ``scan``, iteration never
        runs the per-batch policy hook.
        """
        raise NotImplementedError

    # ------------------------------------------------------------ maintenance
    def gc_tick(self, force: bool = False) -> int:
        n = sum(s.gc_tick(force=force) for s in self._all_stores())
        self._after_batch()
        return n

    def flush_all(self) -> None:
        for s in self._all_stores():
            s.flush_all()

    def crash(self) -> list[int]:
        """Crash every live store; returns the per-store recovery cutoff LSNs.

        Store LSN counters are independent, so there is no single global
        cutoff — each store recovers to its own prefix (``_all_stores()[i]``
        honors the ``ParallaxStore.crash`` contract for cutoff ``[i]``).
        """
        return [s.crash() for s in self._all_stores()]

    def recover(self) -> None:
        for s in self._all_stores():
            s.recover()

    # -------------------------------------------------------------- snapshots
    def state_snapshot(self) -> dict:
        """Portable logical state: one row capture per shard, in shard order.

        Hash routing is positional, so the capture is meaningful only for a
        front-end with the *same* shard count — :meth:`load_state` enforces
        that.  Adaptive front-ends (range) override both methods with their
        topology-carrying form.
        """
        return {
            "kind": "hash",
            "shards": [{"rows": s.snapshot_rows(), "lsn": s.lsn} for s in self.shards],
        }

    def load_state(self, state: dict) -> None:
        """Replace every shard's contents with a :meth:`state_snapshot`."""
        if state.get("kind") != "hash":
            raise ValueError(f"expected a hash-store state, got {state.get('kind')!r}")
        snaps = state["shards"]
        if len(snaps) != len(self.shards):
            raise ValueError(
                f"state has {len(snaps)} shards, this front-end has {len(self.shards)}"
            )
        shards = []
        for snap in snaps:
            s = self._new_shard()
            s.load_rows(snap["rows"], snap["lsn"])
            shards.append(s)
        self.shards = shards

    # ------------------------------------------------------------------ stats
    def aggregate_stats(self) -> StoreStats:
        total = dataclasses.replace(self.retired_stats)
        for s in self._all_stores():
            for f in dataclasses.fields(StoreStats):
                setattr(total, f.name, getattr(total, f.name) + getattr(s.stats, f.name))
        return total

    def device_stats(self) -> DeviceStats:
        total = dataclasses.replace(self.retired_device)
        for s in self._all_stores():
            for f in dataclasses.fields(DeviceStats):
                setattr(total, f.name, getattr(total, f.name) + getattr(s.device.stats, f.name))
        return total

    def _retire_shard_stats(self, shard: ParallaxStore) -> None:
        """Fold a dropped shard's counters into the retired accumulators."""
        for f in dataclasses.fields(StoreStats):
            setattr(self.retired_stats, f.name,
                    getattr(self.retired_stats, f.name) + getattr(shard.stats, f.name))
        for f in dataclasses.fields(DeviceStats):
            setattr(self.retired_device, f.name,
                    getattr(self.retired_device, f.name) + getattr(shard.device.stats, f.name))

    def amplification(self) -> float:
        stats = self.aggregate_stats()
        return self.device_stats().total / max(1, stats.app_bytes)

    def device_times(self) -> list[float]:
        """Per-store device busy times (one entry per live backing store)."""
        return [s.device.device_time() for s in self._all_stores()]

    def device_time(self, policy: str = "ideal") -> float:
        """Completion time of the fleet's device traffic under an overlap
        policy (:func:`repro.core.io.overlap_time`): ``"ideal"`` — the default
        and the historical model — is perfect overlap (the slowest shard
        bounds the batch), ``"serial"`` is no overlap (sum), ``"channels:k"``
        packs shards onto k NVMe channels (LPT)."""
        return overlap_time(self.device_times(), policy)

    def space_bytes(self) -> int:
        return sum(s.space_bytes() for s in self._all_stores())

    def lifetime_states(self) -> list[dict] | None:
        """Per-shard lifetime/adaptive-cutoff observability (None when the
        config has no lifetime placement).  Hash shards adapt autonomously —
        each backing store applies its own cutoff proposals and re-learns
        them after recovery; the range front-end journals cutovers instead."""
        states = [s.lifetime_state() for s in self._all_stores()]
        if all(st is None for st in states):
            return None
        return states

    def checkpoint_stats(self) -> dict:
        return {
            "num_shards": self.num_shards,
            "amplification": self.amplification(),
            "per_shard": [s.checkpoint_stats() for s in self.shards],
        }


class ShardedStore(BaseShardedStore):
    """Hash-partitioned collection of ParallaxStores with batched APIs."""

    # ---------------------------------------------------------------- routing
    def shard_of(self, key: bytes) -> int:
        return route(key, len(self.shards))

    # ------------------------------------------------------------------- scan
    # contract: coordinator-only
    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Global sorted scan: k-way merge of per-shard scans.

        Shards partition the keyspace by hash (not range), so every shard must
        be consulted for up to ``count`` pairs; the merge keeps the first
        ``count`` globally.  Keys are disjoint across shards — no dedup needed.
        For a front-end whose scans touch only the shards overlapping the
        range, see :class:`repro.core.range_shard.RangeShardedStore`.
        """
        self.scans += 1
        self.scan_probes += len(self.shards)
        per_shard = [s.scan(start, count) for s in self.shards]
        return list(itertools.islice(heapq.merge(*per_shard), count))

    # contract: coordinator-only
    def iter_rows(self, start: bytes = b"") -> Iterator[tuple[bytes, bytes]]:
        """Incremental k-way merge of per-shard lazy streams.

        Every shard must still be consulted (hash routing has no key
        locality), but each contributes rows on demand: pulling ``k`` rows
        costs ~``k`` row reads plus one buffered lookahead row per shard,
        where the eager :meth:`scan` pays ``count`` rows on *every* shard.
        """
        self.scans += 1
        self.scan_probes += len(self.shards)
        return heapq.merge(*(s.iter_range(start) for s in self.shards))
