"""Sharded batch front-ends: N independent ParallaxStore shards behind one API.

First step from the single-store simulation toward a serving-scale system
(ROADMAP north star; Scavenger-style placement-aware sharding on top of the
paper's hybrid placement).  Two partitioning schemes share the plumbing here:

* :class:`ShardedStore` (this module) — **hash** partitioning with
  ``zlib.crc32`` routing: stable across processes, perfectly uniform load, but
  no key locality — every ``scan`` must consult all N shards and k-way merge.
* :class:`repro.core.range_shard.RangeShardedStore` — **range** partitioning:
  shards own contiguous key ranges, so a scan touches only the shards that
  overlap the range, at the cost of skew (hot ranges) which it repairs with
  load-driven splits/merges.

Pick hash when the workload is point-op dominated (YCSB A-D) and uniformity
matters more than scans; pick range when scans matter (YCSB E) or when the
shard map must adapt to hot-spots.

Each shard is a full :class:`~repro.core.store.ParallaxStore` with its own
:class:`~repro.core.io.Device`, LSM tree, logs and block cache — the model of
one store-per-core (or per-machine) deployment.  The shared base class
:class:`BaseShardedStore` adds:

* batched ``put_many`` / ``update_many`` / ``delete_many`` / ``get_many`` that
  group a batch by destination shard and drain each shard's sub-batch in one
  pass (order within a shard preserves batch order, so duplicate keys in one
  batch resolve to the last write like the sequential path);
* aggregated stats/amplification, and a parallel device-time model
  (``device_time`` = max over shards) used by the shard benchmarks to turn
  byte counts into a throughput proxy for N devices.

Crash/recover delegates to every shard.  Shard LSN counters are independent,
so ``crash()`` returns the per-shard recovery cutoffs — each shard recovers
to its own prefix; there is no single global LSN.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import zlib
from typing import Iterable, Iterator, Sequence

from .io import Device, DeviceStats, overlap_time
from .metalog import MetadataLog
from .store import ParallaxStore, StoreConfig, StoreStats

# routing uses a different crc32 stream than bloom/cache hashing so shard
# choice is uncorrelated with block placement inside a shard
_ROUTE_SEED = 0xA5A5A5A5


def route(key: bytes, num_shards: int) -> int:
    """Deterministic shard index for a key (crc32, stable across processes)."""
    return zlib.crc32(key, _ROUTE_SEED) % num_shards


def _next_key(key: bytes) -> bytes:
    """The smallest key strictly greater than ``key`` (cursor advance)."""
    return key + b"\x00"


@dataclasses.dataclass
class HashMigrationState:
    """One in-flight hash-rescale leg: the keys of slot ``src_id`` (under the
    old modulus) that route to slot ``dst_id`` under the new one.

    The moving set is hash-defined, not contiguous, so the leg carries both
    moduli and ``pending`` tests the routing predicate on top of the cursor:
    ``[b'', cursor)`` of the moving set is migrated (dst is sole owner),
    the rest is pending (dst owns writes, reads fall back to src on a miss).
    ``epoch_lsn`` is dst's LSN at the flip — dst entries above it postdate
    the flip and are authoritative, exactly like the range protocol.
    """

    src_id: int
    dst_id: int
    mod_old: int
    mod_new: int
    cursor: bytes
    epoch_lsn: int
    leg_index: int = 0      # position in the rescale's leg list (shrink legs
    kind: str = "hash"      # can share a dst, so ids alone don't name a leg)

    def moving(self, key: bytes) -> bool:
        return (route(key, self.mod_old) == self.src_id
                and route(key, self.mod_new) == self.dst_id)

    def covers(self, key: bytes) -> bool:
        return self.moving(key)

    def pending(self, key: bytes) -> bool:
        return key >= self.cursor and self.moving(key)


class BaseShardedStore:
    """Partitioning-agnostic sharded front-end: batching, stats, crash/recover.

    Subclasses provide the partitioning scheme by implementing
    :meth:`shard_of` (key -> shard index) and :meth:`scan` (global sorted
    scan); everything else — single ops, batched ops, GC, crash/recover and
    stat aggregation — routes through those and is shared.
    """

    # contract: coordinator-only
    def __init__(self, num_shards: int = 4, config: StoreConfig | None = None, *,
                 migration_batch_keys: int = 128, rescale_budget: int = 0):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        # the front-end is bloom-filtered by default (the bare store keeps the
        # paper's filterless index); an explicit config is taken as-is
        self.config = config or StoreConfig(bloom_bits_per_key=10)
        self.shards = [self._new_shard() for _ in range(num_shards)]
        # elastic rescale state, shared by both partitioning schemes: the
        # in-flight migration legs (each an ordinary journaled migration; the
        # range front-end also parks its single legacy split/merge leg here),
        # the rescale coordinator bookkeeping, and the per-tick knobs
        self.migration_batch_keys = migration_batch_keys
        self.rescale_budget = rescale_budget   # device bytes per tick; 0 = unthrottled
        self._migrations: list = []
        self._rescale = None                   # elastic.remap.RescaleState | None
        # shard-metadata WAL: the range front-end always journals; a hash
        # front-end creates it lazily at its first rescale (so a never-rescaled
        # hash fleet stays byte-identical to the pre-elastic accounting)
        self.meta_device: Device | None = None
        self.metalog: MetadataLog | None = None
        # front-end scan accounting: how many shards each scan had to consult
        # (the fan-out cost hash partitioning pays and range partitioning
        # avoids); survives topology changes, unlike per-shard counters
        self.scans = 0
        self.scan_probes = 0
        # front-end point-read accounting, same rationale: one probe per shard
        # consulted.  Normally get_probes == gets; during an incremental
        # migration a read that misses the new owner and falls back to the
        # draining old shard costs one extra probe (range front-end only).
        self.gets = 0
        self.get_probes = 0
        # stats of shards retired by topology changes (range-shard merges):
        # folded in here so aggregates never lose traffic history
        self.retired_stats = StoreStats()
        self.retired_device = DeviceStats()
        # Thread-safety (see docs/execution.md): shard stores are only ever
        # touched by one executor task at a time, but the *front-end* counters
        # above are shared.  The serial path is single-threaded and never
        # contends; `repro.core.exec.ShardExecutor` worker threads must hold
        # this lock for any front-end counter mutation (the double-routing
        # read path's fallback probes are the one in-worker site).
        self._stats_lock = threading.Lock()

    def _new_shard(self) -> ParallaxStore:
        return ParallaxStore(dataclasses.replace(self.config))

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def _all_stores(self) -> list[ParallaxStore]:
        """Every live backing store — the routed shards plus any store still
        draining out of the topology (a range-shard merge retires its source
        only once the migration finishes).  Maintenance, crash/recover and
        stat aggregation iterate this, not ``self.shards``."""
        return list(self.shards)

    @property
    def migrations(self) -> tuple:
        """Every in-flight migration leg (empty when the topology is stable).

        Legacy single split/merge migrations appear here as a one-leg tuple;
        a rescale parks one leg per moving shard pair.  The executor derives
        its merged queue groups from this."""
        return tuple(self._migrations)

    def rescale_progress(self) -> dict | None:
        """Progress counters of the in-flight rescale (None when idle)."""
        return None if self._rescale is None else self._rescale.progress()

    def _store_of_id(self, sid: int):
        """Backing store for a migration-leg shard id (range: registry id;
        hash: slot index, including a draining ex-slot)."""
        return self.shards[sid]

    # ---------------------------------------------------------------- routing
    def shard_of(self, key: bytes) -> int:
        raise NotImplementedError

    def shard_for(self, key: bytes) -> ParallaxStore:
        return self.shards[self.shard_of(key)]

    def _group(self, keys: Iterable[bytes]) -> dict[int, list[int]]:
        """Batch positions grouped by shard, preserving batch order per shard."""
        groups: dict[int, list[int]] = {}
        for pos, key in enumerate(keys):
            groups.setdefault(self.shard_of(key), []).append(pos)
        return groups

    # ------------------------------------------------------------- single ops
    def put(self, key: bytes, value: bytes) -> None:
        self.shard_for(key).put(key, value)

    def update(self, key: bytes, value: bytes) -> None:
        self.shard_for(key).update(key, value)

    def delete(self, key: bytes) -> None:
        self.shard_for(key).delete(key)

    def _get_from(self, sid: int, key: bytes) -> bytes | None:
        """Point-read routed to shard ``sid``; adaptive front-ends override
        this for migration-aware double-routing (and bump ``get_probes`` for
        any extra store they consult)."""
        return self.shards[sid].get(key)

    # contract: coordinator-only
    def get(self, key: bytes) -> bytes | None:
        self.gets += 1
        self.get_probes += 1
        return self._get_from(self.shard_of(key), key)

    # ------------------------------------------------------------ batched ops
    def _after_batch(self) -> None:
        """Hook run after every batched op (and GC tick): adaptive front-ends
        evaluate their policies here; the base class does nothing."""

    def put_many(self, items: Sequence[tuple[bytes, bytes]]) -> None:
        for sid, positions in self._group(k for k, _ in items).items():
            shard = self.shards[sid]
            for pos in positions:
                key, value = items[pos]
                shard.put(key, value)
        self._after_batch()

    def update_many(self, items: Sequence[tuple[bytes, bytes]]) -> None:
        for sid, positions in self._group(k for k, _ in items).items():
            shard = self.shards[sid]
            for pos in positions:
                key, value = items[pos]
                shard.update(key, value)
        self._after_batch()

    def delete_many(self, keys: Sequence[bytes]) -> None:
        for sid, positions in self._group(keys).items():
            shard = self.shards[sid]
            for pos in positions:
                shard.delete(keys[pos])
        self._after_batch()

    # contract: coordinator-only
    def get_many(self, keys: Sequence[bytes]) -> list[bytes | None]:
        out: list[bytes | None] = [None] * len(keys)
        for sid, positions in self._group(keys).items():
            for pos in positions:
                self.gets += 1
                self.get_probes += 1
                out[pos] = self._get_from(sid, keys[pos])
        self._after_batch()
        return out

    # ------------------------------------------------------------------- scan
    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        raise NotImplementedError

    def iter_rows(self, start: bytes = b"") -> Iterator[tuple[bytes, bytes]]:
        """Lazy global sorted row stream from ``start`` (no count bound).

        The cursor behind :class:`repro.api.Iterator`: rows are produced — and
        their device bytes charged — on demand, unlike :meth:`scan`, which
        materializes ``count`` rows per consulted shard up front.  Valid only
        while the store is not written to and the topology does not change;
        mutate, then take a fresh iterator.  Unlike ``scan``, iteration never
        runs the per-batch policy hook.
        """
        raise NotImplementedError

    # ------------------------------------------------------------ maintenance
    def gc_tick(self, force: bool = False) -> int:
        n = sum(s.gc_tick(force=force) for s in self._all_stores())
        self._after_batch()
        return n

    def flush_all(self) -> None:
        for s in self._all_stores():
            s.flush_all()

    def _fleet_bytes(self) -> int:
        """Total device bytes moved so far, fleet-wide (data + metadata WAL).
        The rescale budget meters the *delta* of this between sequence points."""
        total = sum(s.device.stats.total for s in self._all_stores())
        if self.meta_device is not None:
            total += self.meta_device.stats.total
        return total

    def _advance_leg(self, m, max_keys: int | None = None) -> int:
        raise NotImplementedError

    # contract: coordinator-only, record-then-apply
    def migration_tick(self, max_keys: int | None = None) -> int:
        """Advance the in-flight migration legs by one batch; returns keys moved.

        A legacy single-leg migration (range split/merge) advances exactly one
        batch per tick, as before.  Under a rescale the tick round-robins over
        the active legs and stops once the shared device-byte budget
        (``RescaleState.budget``) is spent — but always advances at least one
        leg, so even a tiny budget makes forward progress.  When the last leg
        drains, the tick appends the ``rescale_finish`` record and retires the
        coordinator state (roll-forward safe: a crash right at that record
        site resumes here on the next tick).
        """
        r = self._rescale
        if not self._migrations:
            if r is not None:
                self.metalog.append({"kind": "rescale_finish"})
                self._rescale = None
            return 0
        self.migration_ticks += 1
        if r is None:
            return self._advance_leg(self._migrations[0], max_keys)
        start_bytes = self._fleet_bytes()
        legs = list(self._migrations)
        moved = 0
        advanced = 0
        for i in range(len(legs)):
            if advanced and r.budget and self._fleet_bytes() - start_bytes >= r.budget:
                break
            leg = legs[(r.next_leg + i) % len(legs)]
            if leg in self._migrations:
                moved += self._advance_leg(leg, max_keys)
                advanced += 1
        r.next_leg = (r.next_leg + 1) % max(1, len(legs))
        r.ticks += 1
        r.keys_moved += moved
        if not self._migrations:
            self.metalog.append({"kind": "rescale_finish"})
            self._rescale = None
        return moved

    def drain_migration(self, max_ticks: int = 1_000_000) -> int:
        """Run :meth:`migration_tick` until every leg (and the rescale record
        stream, if one is open) is fully drained; returns ticks used."""
        n = 0
        while (self._migrations or self._rescale is not None) and n < max_ticks:
            self.migration_tick()
            n += 1
        return n

    def crash(self) -> list[int]:
        """Crash every live store; returns the per-store recovery cutoff LSNs.

        Store LSN counters are independent, so there is no single global
        cutoff — each store recovers to its own prefix (``_all_stores()[i]``
        honors the ``ParallaxStore.crash`` contract for cutoff ``[i]``).
        """
        return [s.crash() for s in self._all_stores()]

    def recover(self) -> None:
        for s in self._all_stores():
            s.recover()

    # -------------------------------------------------------------- snapshots
    def state_snapshot(self) -> dict:
        """Portable logical state: one row capture per shard, in shard order.

        Hash routing is positional, so the capture is meaningful only for a
        front-end with the *same* shard count — :meth:`load_state` enforces
        that.  Adaptive front-ends (range) override both methods with their
        topology-carrying form (including any in-flight migration; the hash
        form does not carry one, so snapshotting mid-rescale is refused).
        """
        if self._migrations:
            raise ValueError(
                "hash state snapshot with a rescale in flight is unsupported; "
                "drain the rescale first (drain_migration)")
        return {
            "kind": "hash",
            "shards": [{"rows": s.snapshot_rows(), "lsn": s.lsn} for s in self.shards],
        }

    def load_state(self, state: dict) -> None:
        """Replace every shard's contents with a :meth:`state_snapshot`."""
        if state.get("kind") != "hash":
            raise ValueError(f"expected a hash-store state, got {state.get('kind')!r}")
        snaps = state["shards"]
        if len(snaps) != len(self.shards):
            raise ValueError(
                f"state has {len(snaps)} shards, this front-end has {len(self.shards)}"
            )
        shards = []
        for snap in snaps:
            s = self._new_shard()
            s.load_rows(snap["rows"], snap["lsn"])
            shards.append(s)
        self.shards = shards

    # ------------------------------------------------------------------ stats
    def aggregate_stats(self) -> StoreStats:
        total = dataclasses.replace(self.retired_stats)
        for s in self._all_stores():
            for f in dataclasses.fields(StoreStats):
                setattr(total, f.name, getattr(total, f.name) + getattr(s.stats, f.name))
        return total

    def device_stats(self) -> DeviceStats:
        total = dataclasses.replace(self.retired_device)
        for s in self._all_stores():
            for f in dataclasses.fields(DeviceStats):
                setattr(total, f.name, getattr(total, f.name) + getattr(s.device.stats, f.name))
        return total

    def _retire_shard_stats(self, shard: ParallaxStore) -> None:
        """Fold a dropped shard's counters into the retired accumulators."""
        for f in dataclasses.fields(StoreStats):
            setattr(self.retired_stats, f.name,
                    getattr(self.retired_stats, f.name) + getattr(shard.stats, f.name))
        for f in dataclasses.fields(DeviceStats):
            setattr(self.retired_device, f.name,
                    getattr(self.retired_device, f.name) + getattr(shard.device.stats, f.name))

    def amplification(self) -> float:
        stats = self.aggregate_stats()
        return self.device_stats().total / max(1, stats.app_bytes)

    def device_times(self) -> list[float]:
        """Per-store device busy times (one entry per live backing store)."""
        return [s.device.device_time() for s in self._all_stores()]

    def device_time(self, policy: str = "ideal") -> float:
        """Completion time of the fleet's device traffic under an overlap
        policy (:func:`repro.core.io.overlap_time`): ``"ideal"`` — the default
        and the historical model — is perfect overlap (the slowest shard
        bounds the batch), ``"serial"`` is no overlap (sum), ``"channels:k"``
        packs shards onto k NVMe channels (LPT)."""
        return overlap_time(self.device_times(), policy)

    def space_bytes(self) -> int:
        return sum(s.space_bytes() for s in self._all_stores())

    def lifetime_states(self) -> list[dict] | None:
        """Per-shard lifetime/adaptive-cutoff observability (None when the
        config has no lifetime placement).  Hash shards adapt autonomously —
        each backing store applies its own cutoff proposals and re-learns
        them after recovery; the range front-end journals cutovers instead."""
        states = [s.lifetime_state() for s in self._all_stores()]
        if all(st is None for st in states):
            return None
        return states

    def checkpoint_stats(self) -> dict:
        return {
            "num_shards": self.num_shards,
            "amplification": self.amplification(),
            "per_shard": [s.checkpoint_stats() for s in self.shards],
        }


class ShardedStore(BaseShardedStore):
    """Hash-partitioned collection of ParallaxStores with batched APIs.

    Since the elastic-rescale work the fleet can also grow or shrink *online*
    between mod-routing-compatible sizes (:meth:`rescale`): each new/retiring
    slot becomes one journaled migration leg (``HashMigrationState``) with the
    same record-then-apply WAL discipline, double-routed reads and epoch-LSN
    fences as the range front-end's split/merge protocol.  The metadata WAL is
    created lazily at the first rescale, so a never-rescaled hash fleet is
    byte-identical to the pre-elastic accounting.
    """

    # contract: coordinator-only
    def __init__(self, num_shards: int = 4, config: StoreConfig | None = None, *,
                 migration_batch_keys: int = 128, rescale_budget: int = 0):
        super().__init__(num_shards, config,
                         migration_batch_keys=migration_batch_keys,
                         rescale_budget=rescale_budget)
        # double-routing read accounting (mirrors the range front-end): a read
        # that misses the new owner mid-rescale and falls back to the old slot
        self.get_fallbacks = 0
        self.migrated_keys = 0
        self.migration_ticks = 0
        # shrink: ex-slots past the new modulus keep serving their un-migrated
        # residue while their legs drain; retired (and stats-folded) at finish
        self._draining: dict[int, ParallaxStore] = {}

    # ---------------------------------------------------------------- routing
    def shard_of(self, key: bytes) -> int:
        return route(key, len(self.shards))

    def _all_stores(self) -> list[ParallaxStore]:
        return list(self.shards) + [self._draining[s] for s in sorted(self._draining)]

    def _store_of_id(self, sid: int) -> ParallaxStore:
        st = self._draining.get(sid)
        return st if st is not None else self.shards[sid]

    def _get_from(self, sid: int, key: bytes) -> bytes | None:
        """Double-routed point read during a rescale: the new owner ``sid`` is
        authoritative for entries newer than the leg's epoch LSN (and for the
        migrated prefix of the moving set); otherwise fall back to the old
        slot, charging the extra probe."""
        dst = self.shards[sid]
        for m in self._migrations:
            if m.dst_id == sid and m.pending(key):
                e = dst.index_entry(key)
                if e is not None and e.lsn > m.epoch_lsn:
                    break  # post-flip write on the new owner wins
                with self._stats_lock:
                    self.get_probes += 1
                    self.get_fallbacks += 1
                return self._store_of_id(m.src_id).get(key)
        return dst.get(key)

    # ------------------------------------------------------------------- scan
    def _scan_owner(self, key: bytes) -> ParallaxStore:
        """The store whose row for ``key`` is authoritative right now (the
        per-key arbiter behind the rescale-aware merged scan)."""
        slot = self.shard_of(key)
        dst = self.shards[slot]
        for m in self._migrations:
            if m.dst_id == slot and m.pending(key):
                e = dst.index_entry(key)
                if e is not None and e.lsn > m.epoch_lsn:
                    return dst
                return self._store_of_id(m.src_id)
        return dst

    # contract: coordinator-only
    def _iter_resolved(self, start: bytes) -> Iterator[tuple[bytes, bytes]]:
        """Merged row stream during a rescale: every live store (routed slots
        plus draining ex-slots) contributes, and each key is kept only from
        its authoritative owner — so a half-migrated key is never duplicated
        and a stale pre-flip copy never shadows a post-flip write."""
        stores = self._all_stores()
        self.scan_probes += len(stores)

        def tag(i: int, s: ParallaxStore):
            return ((k, i, v) for k, v in s.iter_range(start))

        tagged = [tag(i, s) for i, s in enumerate(stores)]
        for key, i, value in heapq.merge(*tagged):
            if self._scan_owner(key) is stores[i]:
                yield (key, value)

    # contract: coordinator-only
    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Global sorted scan: k-way merge of per-shard scans.

        Shards partition the keyspace by hash (not range), so every shard must
        be consulted for up to ``count`` pairs; the merge keeps the first
        ``count`` globally.  Keys are disjoint across shards — no dedup needed
        — except mid-rescale, when the merge also covers the draining ex-slots
        and each key is resolved against its authoritative owner.
        For a front-end whose scans touch only the shards overlapping the
        range, see :class:`repro.core.range_shard.RangeShardedStore`.
        """
        self.scans += 1
        if self._migrations:
            return list(itertools.islice(self._iter_resolved(start), count))
        self.scan_probes += len(self.shards)
        per_shard = [s.scan(start, count) for s in self.shards]
        return list(itertools.islice(heapq.merge(*per_shard), count))

    # contract: coordinator-only
    def iter_rows(self, start: bytes = b"") -> Iterator[tuple[bytes, bytes]]:
        """Incremental k-way merge of per-shard lazy streams.

        Every shard must still be consulted (hash routing has no key
        locality), but each contributes rows on demand: pulling ``k`` rows
        costs ~``k`` row reads plus one buffered lookahead row per shard,
        where the eager :meth:`scan` pays ``count`` rows on *every* shard.
        Mid-rescale the stream runs through the owner-resolved merge.
        """
        self.scans += 1
        if self._migrations:
            return self._iter_resolved(start)
        self.scan_probes += len(self.shards)
        return heapq.merge(*(s.iter_range(start) for s in self.shards))

    # ---------------------------------------------------------------- rescale
    def _ensure_metalog(self) -> None:
        if self.metalog is None:
            self.meta_device = Device(cache_bytes=0,
                                      segment_bytes=self.config.segment_bytes,
                                      chunk_bytes=self.config.chunk_bytes)
            self.metalog = MetadataLog(self.meta_device)

    # contract: coordinator-only, record-then-apply
    def rescale(self, new_shards: int, *, budget: int | None = None) -> int:
        """Start an online rescale to ``new_shards`` slots; returns the number
        of migration legs started (0 when ``new_shards`` equals the current
        count).

        Mod routing keeps movement minimal only between compatible sizes —
        ``new_shards`` must be a multiple (grow) or divisor (shrink) of the
        current count; anything else raises ``ValueError`` (a near-full
        reshuffle is never worth doing online).  The routing flip is applied
        only after the ``rescale_start`` record commits (record-then-apply);
        from then on every leg drains incrementally via
        :meth:`migration_tick`, with reads double-routed and writes going to
        the new owner.  ``budget`` (device bytes per tick, shared across all
        legs) defaults to the store's ``rescale_budget``; 0 = unthrottled.
        """
        from ..elastic.remap import RescaleState, Topology, plan_rescale

        if self._rescale is not None or self._migrations:
            raise ValueError(
                "a rescale is already in flight; drain it first (drain_migration)")
        n = len(self.shards)
        plan = plan_rescale(Topology("hash", n), new_shards)
        if not plan.legs:
            return 0
        self._ensure_metalog()
        if plan.new_shards > n:
            new_stores = [self._new_shard() for _ in range(n, plan.new_shards)]
            epochs = [s.lsn for s in new_stores]
        else:
            new_stores = []
            epochs = [self.shards[leg.dst].lsn for leg in plan.legs]
        legs_rec = [[leg.src, leg.dst, epochs[i]]
                    for i, leg in enumerate(plan.legs)]
        self.metalog.append({"kind": "rescale_start", "scheme": "hash",
                             "from": n, "to": plan.new_shards, "legs": legs_rec})
        # the flip: from here on shard_of routes under the new modulus
        if plan.new_shards > n:
            self.shards.extend(new_stores)
        else:
            for slot in range(plan.new_shards, n):
                self._draining[slot] = self.shards[slot]
            del self.shards[plan.new_shards:]
        for i, (src, dst, epoch) in enumerate(legs_rec):
            self.shards[dst].pin_tombstones = True
            self._migrations.append(HashMigrationState(
                src, dst, n, plan.new_shards, b"", epoch, leg_index=i))
        self._rescale = RescaleState(
            plan, budget=self.rescale_budget if budget is None else budget,
            dst_ids=tuple(leg.dst for leg in plan.legs))
        return len(plan.legs)

    # contract: coordinator-only, record-then-apply, flush-before-record
    def _advance_leg(self, m: HashMigrationState,
                     max_keys: int | None = None) -> int:
        """Move one batch of ``m``'s moving set from the old slot to the new
        owner: residue-sweep stale pre-flip rows on the destination, copy the
        batch (skipping keys the destination already rewrote post-flip), flush
        the destination, *then* journal the per-leg checkpoint, then delete
        the batch from the source — the crash-safe order."""
        budget = max(1, self.migration_batch_keys if max_keys is None else max_keys)
        src = self._store_of_id(m.src_id)
        dst = self.shards[m.dst_id]
        moving = [k for k in src.live_keys_in(m.cursor, None) if m.moving(k)]
        batch = moving[:budget]
        last_batch = len(moving) <= budget
        batch_hi = None if last_batch else _next_key(batch[-1])
        batch_set = set(batch)
        # residue sweep: pre-flip rows on the destination for keys of this
        # window's moving set with no authoritative replacement (what an
        # earlier crashed rescale left behind) get a post-flip tombstone
        for key, e in dst.newest_entries(m.cursor, batch_hi).items():
            if (e.lsn <= m.epoch_lsn and not e.tombstone and m.moving(key)
                    and key not in batch_set):
                dst._write(key, b"", tombstone=True, internal=True)
        moved = 0
        span_hi = batch_hi if batch_hi is not None else (
            _next_key(batch[-1]) if batch else m.cursor)
        if batch:
            for key, value in src.scan_range(batch[0], span_hi, internal=True):
                if key not in batch_set:
                    continue  # interleaved keys that are not moving
                cur = dst.index_entry(key)
                if cur is not None and cur.lsn > m.epoch_lsn:
                    continue  # rewritten on the new owner since the flip
                dst._write(key, value, tombstone=False, internal=True)
                moved += 1
        # durability barrier: the batch (and residue tombstones) must be
        # durable on the new owner before the record that advances ownership
        dst.flush_all()
        if batch:
            self.metalog.append({"kind": "checkpoint", "cursor": span_hi,
                                 "leg": m.leg_index})
            m.cursor = span_hi
            src.delete_range(batch[0], span_hi, internal=True, keys=batch)
            with self._stats_lock:
                self.migrated_keys += len(batch)
        if last_batch:
            # the finish record drops the leg from recovery's view, so every
            # src delete it covers must be durable first — a checkpoint-covered
            # delete may stay volatile (recovery's src residue sweep redoes it)
            src.flush_all()
            self.metalog.append({"kind": "finish", "leg": m.leg_index})
            self._finish_leg(m)
        return moved

    def _finish_leg(self, m: HashMigrationState) -> None:
        self._migrations.remove(m)
        if not any(x.dst_id == m.dst_id for x in self._migrations):
            self.shards[m.dst_id].pin_tombstones = False
        src = self._draining.pop(m.src_id, None)
        if src is not None:
            self._retire_shard_stats(src)
        if self._rescale is not None:
            self._rescale.legs_done += 1

    # ---------------------------------------------------------- crash/recover
    def recover(self) -> None:
        """Recover every store, then roll the metadata WAL forward (when one
        exists) to rebuild the in-flight rescale exactly as journaled."""
        for s in self._all_stores():
            s.recover()
        if self.metalog is not None:
            self._replay_metalog()

    def _replay_metalog(self) -> None:
        from ..elastic.remap import RescaleLeg, RescalePlan, RescaleState

        legs: list[HashMigrationState] = []
        start_rec: dict | None = None
        finished = True
        for rec in self.metalog.replay():
            kind = rec["kind"]
            if kind == "rescale_start":
                start_rec, finished = rec, False
                legs = [HashMigrationState(src, dst, rec["from"], rec["to"],
                                           b"", epoch, leg_index=i)
                        for i, (src, dst, epoch) in enumerate(rec["legs"])]
            elif kind == "checkpoint":
                for m in legs:
                    if m.leg_index == rec["leg"]:
                        m.cursor = rec["cursor"]
            elif kind == "finish":
                legs = [m for m in legs if m.leg_index != rec["leg"]]
            elif kind == "rescale_finish":
                legs, start_rec, finished = [], None, True
        self._migrations = legs
        for i, s in enumerate(self.shards):
            s.pin_tombstones = any(m.dst_id == i for m in legs)
        # src residue sweep: a checkpoint covers a durable dst copy, but the
        # matching src delete may have been volatile at the crash — re-delete
        # every moving key below each live leg's cursor (hash routing cannot
        # mask stale src rows the way range boundary routing does)
        for m in legs:
            src = self._store_of_id(m.src_id)
            residue = [k for k in src.live_keys_in(b"", m.cursor) if m.moving(k)]
            if residue:
                src.delete_range(residue[0], m.cursor, internal=True, keys=residue)
        # a shrink leg's finish may be durable while its _finish_leg never ran:
        # retire any draining ex-slot no live leg still sources
        live_srcs = {m.src_id for m in legs}
        for slot in [s for s in self._draining if s not in live_srcs]:
            self._retire_shard_stats(self._draining.pop(slot))
        if finished:
            self._rescale = None
            return
        # note: legs may be empty here with the rescale still open — a crash
        # exactly at the rescale_finish record site; the next migration_tick
        # re-appends it and retires the coordinator state
        n, to = start_rec["from"], start_rec["to"]
        frac = (to - n) / to if to > n else (n - to) / n
        plan = RescalePlan(
            "hash", n, to,
            tuple(RescaleLeg("hash", src, dst)
                  for src, dst, _ in start_rec["legs"]),
            None, frac)
        state = RescaleState(plan, budget=self.rescale_budget,
                             dst_ids=tuple(l.dst for l in plan.legs))
        state.legs_done = len(plan.legs) - len(legs)
        self._rescale = state

    # ------------------------------------------------------------------ stats
    def device_stats(self) -> DeviceStats:
        total = super().device_stats()
        if self.meta_device is not None:
            for f in dataclasses.fields(DeviceStats):
                setattr(total, f.name,
                        getattr(total, f.name) + getattr(self.meta_device.stats, f.name))
        return total

    def space_bytes(self) -> int:
        extra = self.metalog.log_bytes if self.metalog is not None else 0
        return super().space_bytes() + extra

    def device_time(self, policy: str = "ideal") -> float:
        extra = (self.meta_device.device_time()
                 if self.meta_device is not None else 0.0)
        return super().device_time(policy) + extra

    def checkpoint_stats(self) -> dict:
        out = super().checkpoint_stats()
        out["migrated_keys"] = self.migrated_keys
        out["migration_ticks"] = self.migration_ticks
        if self.metalog is not None:
            out["meta_records"] = self.metalog.n_records
            out["meta_bytes"] = self.metalog.bytes_appended
        if self._rescale is not None:
            out["rescale"] = self._rescale.progress()
        return out
