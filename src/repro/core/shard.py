"""Sharded batch front-end: N independent ParallaxStore shards behind one API.

First step from the single-store simulation toward a serving-scale system
(ROADMAP north star; Scavenger-style placement-aware sharding on top of the
paper's hybrid placement).  Keys are hash-partitioned with ``zlib.crc32`` —
stable across processes, unlike ``hash()`` — so routing is deterministic and a
key always lands on the same shard.

Each shard is a full :class:`~repro.core.store.ParallaxStore` with its own
:class:`~repro.core.io.Device`, LSM tree, logs and block cache — the model of
one store-per-core (or per-machine) deployment.  The front-end adds:

* batched ``put_many`` / ``update_many`` / ``delete_many`` / ``get_many`` that
  group a batch by destination shard and drain each shard's sub-batch in one
  pass (order within a shard preserves batch order, so duplicate keys in one
  batch resolve to the last write like the sequential path);
* merged ``scan`` across shards (each shard holds a disjoint key subset, so a
  k-way merge of per-shard sorted results is the global sorted order);
* aggregated stats/amplification, and a parallel device-time model
  (``device_time`` = max over shards) used by ``benchmarks/bench_shard.py``
  to turn byte counts into a throughput proxy for N devices.

Crash/recover delegates to every shard.  Shard LSN counters are independent,
so ``crash()`` returns the per-shard recovery cutoffs — each shard recovers
to its own prefix; there is no single global LSN.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import zlib
from typing import Iterable, Sequence

from .io import DeviceStats
from .store import ParallaxStore, StoreConfig, StoreStats

# routing uses a different crc32 stream than bloom/cache hashing so shard
# choice is uncorrelated with block placement inside a shard
_ROUTE_SEED = 0xA5A5A5A5


def route(key: bytes, num_shards: int) -> int:
    """Deterministic shard index for a key (crc32, stable across processes)."""
    return zlib.crc32(key, _ROUTE_SEED) % num_shards


class ShardedStore:
    """Hash-partitioned collection of ParallaxStores with batched APIs."""

    def __init__(self, num_shards: int = 4, config: StoreConfig | None = None):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        # the front-end is bloom-filtered by default (the bare store keeps the
        # paper's filterless index); an explicit config is taken as-is
        self.config = config or StoreConfig(bloom_bits_per_key=10)
        self.shards = [
            ParallaxStore(dataclasses.replace(self.config)) for _ in range(num_shards)
        ]

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    # ---------------------------------------------------------------- routing
    def shard_of(self, key: bytes) -> int:
        return route(key, len(self.shards))

    def shard_for(self, key: bytes) -> ParallaxStore:
        return self.shards[self.shard_of(key)]

    def _group(self, keys: Iterable[bytes]) -> dict[int, list[int]]:
        """Batch positions grouped by shard, preserving batch order per shard."""
        groups: dict[int, list[int]] = {}
        for pos, key in enumerate(keys):
            groups.setdefault(self.shard_of(key), []).append(pos)
        return groups

    # ------------------------------------------------------------- single ops
    def put(self, key: bytes, value: bytes) -> None:
        self.shard_for(key).put(key, value)

    def update(self, key: bytes, value: bytes) -> None:
        self.shard_for(key).update(key, value)

    def delete(self, key: bytes) -> None:
        self.shard_for(key).delete(key)

    def get(self, key: bytes) -> bytes | None:
        return self.shard_for(key).get(key)

    # ------------------------------------------------------------ batched ops
    def put_many(self, items: Sequence[tuple[bytes, bytes]]) -> None:
        for sid, positions in self._group(k for k, _ in items).items():
            shard = self.shards[sid]
            for pos in positions:
                key, value = items[pos]
                shard.put(key, value)

    def update_many(self, items: Sequence[tuple[bytes, bytes]]) -> None:
        for sid, positions in self._group(k for k, _ in items).items():
            shard = self.shards[sid]
            for pos in positions:
                key, value = items[pos]
                shard.update(key, value)

    def delete_many(self, keys: Sequence[bytes]) -> None:
        for sid, positions in self._group(keys).items():
            shard = self.shards[sid]
            for pos in positions:
                shard.delete(keys[pos])

    def get_many(self, keys: Sequence[bytes]) -> list[bytes | None]:
        out: list[bytes | None] = [None] * len(keys)
        for sid, positions in self._group(keys).items():
            shard = self.shards[sid]
            for pos in positions:
                out[pos] = shard.get(keys[pos])
        return out

    # ------------------------------------------------------------------- scan
    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Global sorted scan: k-way merge of per-shard scans.

        Shards partition the keyspace by hash (not range), so every shard must
        be consulted for up to ``count`` pairs; the merge keeps the first
        ``count`` globally.  Keys are disjoint across shards — no dedup needed.
        """
        per_shard = [s.scan(start, count) for s in self.shards]
        return list(itertools.islice(heapq.merge(*per_shard), count))

    # ------------------------------------------------------------ maintenance
    def gc_tick(self, force: bool = False) -> int:
        return sum(s.gc_tick(force=force) for s in self.shards)

    def flush_all(self) -> None:
        for s in self.shards:
            s.flush_all()

    def crash(self) -> list[int]:
        """Crash every shard; returns the per-shard recovery cutoff LSNs.

        Shard LSN counters are independent, so there is no single global
        cutoff — each shard recovers to its own prefix (``shards[i]`` honors
        the ``ParallaxStore.crash`` contract for cutoff ``[i]``).
        """
        return [s.crash() for s in self.shards]

    def recover(self) -> None:
        for s in self.shards:
            s.recover()

    # ------------------------------------------------------------------ stats
    def aggregate_stats(self) -> StoreStats:
        total = StoreStats()
        for s in self.shards:
            for f in dataclasses.fields(StoreStats):
                setattr(total, f.name, getattr(total, f.name) + getattr(s.stats, f.name))
        return total

    def device_stats(self) -> DeviceStats:
        total = DeviceStats()
        for s in self.shards:
            for f in dataclasses.fields(DeviceStats):
                setattr(total, f.name, getattr(total, f.name) + getattr(s.device.stats, f.name))
        return total

    def amplification(self) -> float:
        app = max(1, sum(s.stats.app_bytes for s in self.shards))
        return sum(s.device.stats.total for s in self.shards) / app

    def device_time(self) -> float:
        """Parallel-device completion time: the slowest shard bounds the batch."""
        return max(s.device.device_time() for s in self.shards)

    def space_bytes(self) -> int:
        return sum(s.space_bytes() for s in self.shards)

    def checkpoint_stats(self) -> dict:
        return {
            "num_shards": self.num_shards,
            "amplification": self.amplification(),
            "per_shard": [s.checkpoint_stats() for s in self.shards],
        }
