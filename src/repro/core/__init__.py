"""Parallax core: hybrid KV placement in an LSM store (the paper's contribution).

New call-sites should open these building blocks through the unified engine
API — :func:`repro.api.open` with a declarative :class:`repro.api.EngineConfig`
composing placement, partitioning and execution (see ``docs/api.md``); the
classes below remain public as the engine's substrate and for
maintenance/test surfaces.

Public surface:

* :mod:`repro.core.model` — the paper's I/O-amplification model (Eq. 1-4, R(i))
* :class:`repro.core.store.ParallaxStore` — the store (modes: parallax,
  rocksdb, blobdb, nomerge; MS/ML threshold variants)
* :mod:`repro.core.ycsb` — YCSB workload generation (Table 1 mixes) and the
  batched ``execute`` driver
* :class:`repro.core.shard.ShardedStore` — hash-partitioned batch front-end
  (N independent stores, ``put_many``/``get_many``/merged ``scan``)
* :class:`repro.core.range_shard.RangeShardedStore` — range-partitioned
  front-end (contiguous key ranges, range-local ``scan``, skew-driven
  split/merge rebalancing whose key migration is incremental — double-routed
  reads, per-batch ticks — and whose topology is backed by a persistent
  shard-metadata WAL)
* :class:`repro.core.metalog.MetadataLog` — the shard-metadata WAL
  (synchronous boundary/migration records, replayed by recovery;
  ``crash_after`` fault-injection hook for the crash-point harness)
* per-level bloom filters (:class:`repro.core.lsm.BloomFilter`) let point
  reads skip levels; skips are counted in ``StoreStats.bloom_skips``
* :mod:`repro.core.lifetime` — lifetime-aware value placement: the
  deterministic (crc32-keyed) windowed update-distance sketch
  (:class:`~repro.core.lifetime.LifetimeSketch`) that splits the Large log
  into short/long-lived per-class value logs with per-class GC thresholds,
  the adaptive medium/large cutoff controller
  (:func:`~repro.core.lifetime.propose_cutoffs`), and the exact test oracle
  (:class:`~repro.core.lifetime.LifetimeOracle`); enabled via
  ``StoreConfig(lifetime=LifetimeConfig(...))``
* :class:`repro.core.exec.ShardExecutor` — async pipelined shard execution:
  per-shard FIFO queues on a thread pool, pipelined batches, background
  GC/migration at sequence points, byte-identical to serial execution
  (``ycsb.execute_async`` is the batch driver); pluggable device overlap
  policies (:func:`repro.core.io.overlap_time`: serial / ideal / channels:k)
"""
from .exec import BatchHandle, ShardExecutor
from .io import BLOCK, CHUNK, SEGMENT, Device, DeviceStats, overlap_time
from .lifetime import (
    CLASS_LONG,
    CLASS_SHORT,
    LifetimeConfig,
    LifetimeOracle,
    LifetimeSketch,
    propose_cutoffs,
)
from .logs import Log, LogEntry, Pointer, TransientLog
from .lsm import CAT_LARGE, CAT_MEDIUM, CAT_SMALL, BloomFilter, IndexEntry, Level
from .metalog import CrashPoint, MetadataLog
from .model import (
    T_ML,
    T_SM,
    SizePolicy,
    amplification_inplace,
    amplification_inplace_sum,
    amplification_separated,
    capacity_ratio,
    levels_for_dataset,
    separation_benefit,
)
from .range_shard import MigrationState, RangeShardedStore
from .shard import BaseShardedStore, ShardedStore, route
from .store import ParallaxStore, StoreConfig, StoreStats

__all__ = [
    "BLOCK", "CHUNK", "SEGMENT", "Device", "DeviceStats", "overlap_time",
    "BatchHandle", "ShardExecutor",
    "Log", "LogEntry", "Pointer", "TransientLog",
    "CAT_SMALL", "CAT_MEDIUM", "CAT_LARGE", "BloomFilter", "IndexEntry", "Level",
    "CLASS_SHORT", "CLASS_LONG", "LifetimeConfig", "LifetimeOracle",
    "LifetimeSketch", "propose_cutoffs",
    "CrashPoint", "MetadataLog",
    "T_ML", "T_SM", "SizePolicy",
    "amplification_inplace", "amplification_inplace_sum", "amplification_separated",
    "capacity_ratio", "levels_for_dataset", "separation_benefit",
    "ParallaxStore", "StoreConfig", "StoreStats",
    "BaseShardedStore", "ShardedStore", "MigrationState", "RangeShardedStore", "route",
]
