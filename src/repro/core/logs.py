"""Value logs: Small (WAL), Large (GC'd), and Medium transient logs.

Logs are lists of 2 MB segments (paper §3.4).  Appends buffer into a circular
tail buffer and hit the device in 256 KB chunks.  Entries are addressed by
``(segment_id, slot)`` pointers; the device offset of a segment comes from the
shared allocator so GC-region bookkeeping can be keyed by segment start offset
(paper §3.2).

The Medium log is *transient* (paper §3.3): its segments are attached to an
LSM level and travel down with compactions; when they reach the merge level
their contents are merged in place and the segments are reclaimed wholesale —
no GC walk ever happens on the medium log.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

from .io import CHUNK, SEGMENT, Device


@dataclasses.dataclass
class LogEntry:
    lsn: int
    key: bytes
    value: bytes
    category: int  # 0 small, 1 medium, 2 large
    tombstone: bool = False
    end_off: int = 0  # cumulative append offset; durable iff <= flushed bytes

    @property
    def size(self) -> int:
        # 8B LSN + 4B sizes header + payload (tombstones carry no value)
        return 12 + len(self.key) + (0 if self.tombstone else len(self.value))


@dataclasses.dataclass
class Pointer:
    """Device-side address of a log entry: segment id + slot inside it."""

    segment_id: int
    slot: int


class Segment:
    __slots__ = ("segment_id", "offset", "entries", "live_bytes", "dead_bytes", "sorted")

    def __init__(self, segment_id: int, offset: int):
        self.segment_id = segment_id
        self.offset = offset
        self.entries: list[LogEntry | None] = []
        self.live_bytes = 0
        self.dead_bytes = 0
        self.sorted = False

    @property
    def used_bytes(self) -> int:
        return self.live_bytes + self.dead_bytes

    def invalid_fraction(self) -> float:
        used = self.used_bytes
        return (self.dead_bytes / used) if used else 0.0


class Log:
    """Append-only segmented log with chunked device writes."""

    def __init__(self, device: Device, name: str, kind: str = "log"):
        self.device = device
        self.name = name
        self.kind = kind  # device-stat attribution ('log' for value logs,
        #                   'meta' for the shard-metadata WAL)
        self.segments: dict[int, Segment] = {}
        self._next_segment_id = 0
        self._tail: Segment | None = None
        self._unflushed = 0  # bytes buffered in the tail chunk
        self.appended_bytes = 0

    # -- append path ----------------------------------------------------------
    def _new_segment(self) -> Segment:
        seg = Segment(self._next_segment_id, self.device.alloc_segment())
        self._next_segment_id += 1
        self.segments[seg.segment_id] = seg
        return seg

    # contract: single-threaded
    def append(self, entry: LogEntry) -> Pointer:
        if self._tail is None or self._tail.used_bytes + entry.size > self.device.segment_bytes:
            self.flush()
            self._tail = self._new_segment()
        seg = self._tail
        seg.entries.append(entry)
        seg.live_bytes += entry.size
        self.appended_bytes += entry.size
        entry.end_off = self.appended_bytes
        self._unflushed += entry.size
        # chunk-granularity group commit (256 KB default)
        chunk = self.device.chunk_bytes
        while self._unflushed >= chunk:
            self.device.sequential_write(chunk, chunk, kind=self.kind)
            self._unflushed -= chunk
        return Pointer(seg.segment_id, len(seg.entries) - 1)

    def flush(self) -> None:
        if self._unflushed:
            self.device.sequential_write(self._unflushed, self.device.chunk_bytes, kind=self.kind)
            self._unflushed = 0

    # -- read / invalidate ----------------------------------------------------
    def get(self, ptr: Pointer) -> LogEntry:
        entry = self.segments[ptr.segment_id].entries[ptr.slot]
        assert entry is not None, "dereferenced a GC'd slot"
        return entry

    def read(self, ptr: Pointer, kind: str = "get") -> LogEntry:
        """Get + charge a 4 KB random block read at the entry's device offset."""
        seg = self.segments[ptr.segment_id]
        entry = seg.entries[ptr.slot]
        assert entry is not None
        # approximate intra-segment offset by slot position
        approx_off = seg.offset + (ptr.slot * max(1, seg.used_bytes // max(1, len(seg.entries))))
        self.device.random_read(approx_off, entry.size, kind=kind)
        return entry

    def mark_dead(self, ptr: Pointer) -> None:
        """Update/delete invalidated this entry (GC-region free-space info).

        No-op for already-reclaimed segments: a stale index entry in a deep
        level may outlive the segment its pointer refers to (GC relocated the
        live value under a newer LSN), until compaction merges it away.
        """
        seg = self.segments.get(ptr.segment_id)
        if seg is None or ptr.slot >= len(seg.entries):
            return
        entry = seg.entries[ptr.slot]
        if entry is None:
            return
        # NOTE: the entry stays in the segment — GC still pays a lookup to
        # learn it is dead (the paper's 'lookup cost'); only counters move.
        seg.live_bytes -= entry.size
        seg.dead_bytes += entry.size

    def reclaim(self, segment_id: int) -> None:
        seg = self.segments.pop(segment_id)
        if seg is self._tail:
            self._tail = None
        self.device.free_segment(seg.offset)

    # -- iteration -------------------------------------------------------------
    def iter_segments(self) -> Iterator[Segment]:
        return iter(list(self.segments.values()))

    @property
    def total_bytes(self) -> int:
        return sum(s.used_bytes for s in self.segments.values())

    @property
    def live_bytes(self) -> int:
        return sum(s.live_bytes for s in self.segments.values())


class TransientLog(Log):
    """Medium-KV log whose segments are attached to LSM levels (paper §3.3).

    ``seal_tail`` closes the tail segment (optionally marking it sorted — the
    eager L0 sort of Fig. 4/Fig. 8) and returns its id so the caller can attach
    it to the destination level.  Reclaim happens only via the in-place merge
    at the configured merge level; there is no GC path.
    """

    def seal_tail(self, sorted_segment: bool) -> int | None:
        if self._tail is None:
            return None
        self.flush()
        self._tail.sorted = sorted_segment
        sid = self._tail.segment_id
        self._tail = None
        return sid

    def merge_read(self, segment_id: int) -> list[LogEntry]:
        """Charge the device for fetching one segment during the in-place merge.

        Sorted segments are fetched exactly once, incrementally in 8 KB reads
        (paper Fig. 4).  Unsorted segments devolve to one 4 KB random read per
        KV (paper §3.3 'up to 40x the size of the transient log').
        """
        from .io import BLOCK, MERGE_FETCH

        seg = self.segments[segment_id]
        live = [e for e in seg.entries if e is not None]
        if seg.sorted:
            self.device.sequential_read(seg.used_bytes, MERGE_FETCH, kind="compaction")
        else:
            # random order: one uncached block read per entry
            self.device._read(len(live) * BLOCK, len(live), "compaction")
        return live
