"""LSM level structure: per-level sorted index with hybrid entry placement.

Each level is the functional equivalent of the paper's per-level B+-tree: a
sorted run of index entries.  Entries are either *in place* (key+value stored
in the leaf's slot-array/data-segment layout) or *log-placed* (12 B prefix +
8 B pointer in the leaf, value in one of the logs).  We keep the paper's dual
size accounting for medium KVs (§3.3 last paragraph):

* ``index_bytes``  — what the level occupies on the device (pointer-sized for
  log-placed entries).  Used as the level's size when merging *into* it.
* ``logical_bytes`` — full key+value footprint.  Used as the level's size when
  merging it *into the next* level at/after the in-place merge level.

Slot-array overhead (4 B/entry) is charged so the small-KV overhead the paper
reports (≈8 % of leaf capacity, Fig. 6 discussion) is reproduced.

Each level can additionally carry a :class:`BloomFilter` over its key set
(rebuilt with the level on every compaction, like RocksDB's per-SST filter
blocks).  Point reads consult the filter before the leaf probe: a negative
answer lets the store skip the level without touching the device (the
``bloom_skips`` counter in :class:`repro.core.store.StoreStats`).  Filters are
in-memory and deterministic (crc32 double hashing), so they never change the
store's visible state — only its read traffic.
"""
from __future__ import annotations

import bisect
import dataclasses
import zlib

from .logs import Pointer

SLOT = 4          # slot-array cell (paper §3.2)
ENTRY_HEADER = 4  # key/value length headers in the data segment
PREFIX = 12       # fixed index prefix for log-placed KVs (paper §3.1)
POINTER = 8       # log pointer

CAT_SMALL, CAT_MEDIUM, CAT_LARGE = 0, 1, 2


@dataclasses.dataclass
class IndexEntry:
    key: bytes
    lsn: int
    category: int
    tombstone: bool = False
    value: bytes | None = None       # in-place payload
    ptr: Pointer | None = None       # log payload
    log: str | None = None           # which log the pointer refers to ('medium'|'large')
    kv_size: int = 0                 # full key+value size (survives pointer form)
    slot_bytes: int = SLOT           # 0 for packed-SST baselines (RocksDB mode)

    @property
    def in_place(self) -> bool:
        return self.ptr is None

    def index_size(self) -> int:
        """Bytes this entry occupies inside the level on device."""
        if self.tombstone:
            return self.slot_bytes + ENTRY_HEADER + len(self.key)
        if self.in_place:
            return self.slot_bytes + ENTRY_HEADER + len(self.key) + len(self.value or b"")
        return self.slot_bytes + PREFIX + POINTER

    def logical_size(self) -> int:
        return self.slot_bytes + ENTRY_HEADER + self.kv_size if not self.tombstone else self.index_size()


class BloomFilter:
    """Fixed-size bloom filter with crc32 double hashing (deterministic).

    ``h_i(key) = h1 + i*h2 (mod nbits)`` — the standard Kirsch–Mitzenmacher
    construction, so membership answers are identical across processes
    regardless of ``PYTHONHASHSEED``.  May return false positives, never false
    negatives.
    """

    __slots__ = ("nbits", "k", "_bits")

    def __init__(self, num_keys: int, bits_per_key: int = 10):
        self.nbits = max(64, num_keys * bits_per_key)
        # optimal hash count ~= bits_per_key * ln 2
        self.k = max(1, min(16, int(round(bits_per_key * 0.69))))
        self._bits = bytearray((self.nbits + 7) // 8)

    def _positions(self, key: bytes):
        h1 = zlib.crc32(key)
        h2 = zlib.crc32(key, 0x9E3779B9) | 1  # odd so strides cycle the table
        for i in range(self.k):
            yield (h1 + i * h2) % self.nbits

    def add(self, key: bytes) -> None:
        for pos in self._positions(key):
            self._bits[pos >> 3] |= 1 << (pos & 7)

    def __contains__(self, key: bytes) -> bool:
        return all(self._bits[pos >> 3] & (1 << (pos & 7)) for pos in self._positions(key))


class Level:
    """A sorted run of IndexEntry (unique keys, ascending)."""

    def __init__(self, index: int, bloom_bits_per_key: int = 0):
        self.index = index
        self.entries: list[IndexEntry] = []
        self._keys: list[bytes] = []
        self.index_bytes = 0
        self.logical_bytes = 0
        self.transient_segments: list[int] = []  # medium-log segments attached here
        self.bloom_bits_per_key = bloom_bits_per_key
        self.bloom: BloomFilter | None = None

    def __len__(self) -> int:
        return len(self.entries)

    def rebuild(self, entries: list[IndexEntry]) -> None:
        self.entries = entries
        self._keys = [e.key for e in entries]
        self.index_bytes = sum(e.index_size() for e in entries)
        self.logical_bytes = sum(e.logical_size() for e in entries)
        if self.bloom_bits_per_key > 0 and entries:
            self.bloom = BloomFilter(len(entries), self.bloom_bits_per_key)
            for k in self._keys:
                self.bloom.add(k)
        else:
            self.bloom = None

    def maybe_contains(self, key: bytes) -> bool:
        """Filter check for point reads; True when no filter is attached."""
        return self.bloom is None or key in self.bloom

    def clear(self) -> list[int]:
        segs, self.transient_segments = self.transient_segments, []
        self.rebuild([])
        return segs

    def find(self, key: bytes) -> IndexEntry | None:
        i = bisect.bisect_left(self._keys, key)
        if i < len(self._keys) and self._keys[i] == key:
            return self.entries[i]
        return None

    def range(self, start: bytes, count_hint: int) -> list[IndexEntry]:
        i = bisect.bisect_left(self._keys, start)
        return self.entries[i : i + count_hint]

    def iter_from(self, start: bytes):
        i = bisect.bisect_left(self._keys, start)
        while i < len(self.entries):
            yield self.entries[i]
            i += 1


def merge_runs(newer: list[IndexEntry], older: list[IndexEntry], *, drop_tombstones: bool) -> tuple[list[IndexEntry], list[IndexEntry]]:
    """Merge two sorted runs; newer wins on key collision (it has higher LSN).

    Returns (merged, superseded) where ``superseded`` are the shadowed/dropped
    entries — the caller uses them to mark log slots dead (GC-region info,
    paper §3.2) .
    """
    merged: list[IndexEntry] = []
    dead: list[IndexEntry] = []
    i = j = 0
    while i < len(newer) and j < len(older):
        a, b = newer[i], older[j]
        if a.key < b.key:
            merged.append(a)
            i += 1
        elif a.key > b.key:
            merged.append(b)
            j += 1
        else:
            # same key: newer shadows older
            dead.append(b)
            merged.append(a)
            i += 1
            j += 1
    merged.extend(newer[i:])
    merged.extend(older[j:])
    if drop_tombstones:
        out = []
        for e in merged:
            if e.tombstone:
                dead.append(e)
            else:
                out.append(e)
        merged = out
    return merged, dead
