"""Async pipelined shard execution engine (PR 4).

The front-ends' batched ops execute shard-by-shard, so the modeled
``device_time = max over shards`` overlap was *pretended*, never realized —
while the paper's headline wins (up to 12.4x vs RocksDB) come from keeping the
NVMe device busy with overlapped, mostly-sequential I/O.
:class:`ShardExecutor` makes the overlap real:

* **Per-shard FIFO work queues.**  A batch is routed/partitioned on the
  submitting (coordinator) thread and each shard's sub-batch becomes one task
  on that shard's queue; a shared ``ThreadPoolExecutor`` drains the queues,
  one in-flight task per queue.  Shards are independent stores, so tasks on
  different queues commute — and each task *asserts* the independence
  invariant with a non-blocking per-store lock acquire (a blocked acquire
  means two tasks touched one store: the executor raises instead of silently
  corrupting stats; see the thread-safety audit in ``store.py``).

* **Pipelining.**  Submission returns immediately (bounded by a backpressure
  window), so batch N+1's routing/partitioning on the coordinator overlaps
  batch N's shard work on the pool — the front of the pipeline never waits
  for the device.

* **Sequence points.**  Anything that reads or mutates cross-shard state —
  ``migration_tick``, the skew rebalancer, range-store scans, crash/recover —
  runs via :meth:`exclusive`: drain all queues, run the function on the
  coordinator, resume.  Because only the coordinator submits work, this is a
  full barrier with no reader/writer lock machinery, and it makes async
  execution *byte-identical to serial*: the per-shard projection of the op
  stream is exactly the serial path's, and every policy decision happens at
  the same op-stream position with the same counter values
  (``tests/test_exec.py`` is the differential oracle).

* **Background maintenance.**  Large-log GC on a hash front-end is enqueued
  per shard (:meth:`gc_tick`) — truly off the foreground path, ordered only
  against its own shard's traffic.  Migration ticks stay sequence points
  (they touch two shards and append WAL records whose order must match apply
  order — see ``metalog.py``), but the *driver* never blocks submitting them:
  ``ycsb.execute_async`` interleaves them between batches exactly where the
  serial driver does, bounded by the same ``migrate_budget``.

* **Double-routing safety.**  While a migration is in flight, reads routed to
  the destination may fall back to the draining source — two stores, one
  logical shard.  The coordinator maps both stores' queues onto one merged
  queue key for the duration, so pair-touching tasks serialize with both
  sides' foreground work.

**Pacing (measured vs modeled time).**  This container runs CPython with a
GIL: pure-Python shard work cannot overlap in wall-clock no matter how many
workers run.  What *does* overlap — and what the paper's engine overlaps — is
device time.  With ``pace > 0`` every task sleeps ``pace x`` the modeled
device-time delta of the stores it touched (sleeps release the GIL), so
measured wall-clock becomes a faithful execution of the byte-accounted device
model: 1 worker realizes the ``serial`` overlap policy, k workers approximate
``channels:k``, and many workers approach ``ideal``
(:func:`repro.core.io.overlap_time`).  Benchmarks compare the modeled
policies against measured paced wall-clock per run; the default ``pace=0``
adds no sleeps and is what tests use.

The executor is **single-coordinator**: exactly one thread may submit
batches/maintenance.  Results and stats are byte-identical to serial
execution regardless of ``workers``/``pipeline``/pacing.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

from .shard import BaseShardedStore
from .store import ParallaxStore


class _ShardQueue:
    """FIFO of tasks for one shard (or one migration pair), at most one
    in-flight drainer on the pool at a time."""

    __slots__ = ("items", "active")

    def __init__(self):
        self.items: deque = deque()
        self.active = False


class BatchHandle:
    """Completion handle for one submitted batch (its per-shard tasks).

    ``result()`` blocks until every task of the batch ran and returns the
    batch's value (the filled output list for ``get_many``, ``None`` for
    writes), re-raising the first executor error if any task failed.
    """

    __slots__ = ("_ex", "_remaining", "value")

    def __init__(self, ex: "ShardExecutor", ntasks: int, value=None):
        self._ex = ex
        self._remaining = ntasks
        self.value = value

    def result(self, timeout: float | None = None):
        with self._ex._cv:
            ok = self._ex._cv.wait_for(lambda: self._remaining == 0 or self._ex._errors,
                                       timeout=timeout)
            if not ok:
                raise TimeoutError("batch did not complete in time")
            self._ex._raise_if_failed_locked()
        return self.value


class ShardExecutor:
    """Drains a sharded store's batched ops through per-shard queues.

    Parameters:

    * ``workers`` — pool threads; with pacing, realizes up to that many
      overlapped device channels.
    * ``pipeline`` — submission returns before the batch completes (up to
      ``max_pending`` batches in flight); off = every batch is drained before
      the next is accepted (still fans out *within* the batch).
    * ``pace`` — seconds of sleep per modeled device-second a task incurred
      (0 = no pacing; see module docstring).
    """

    # contract: coordinator-only
    def __init__(self, store: BaseShardedStore, workers: int = 4, *,
                 pipeline: bool = True, pace: float = 0.0, max_pending: int = 8):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.store = store
        self.workers = workers
        self.pipeline = pipeline
        self.pace = pace
        self.max_pending = max(1, max_pending)
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="shard-exec")
        self._cv = threading.Condition()
        self._queues: dict = {}
        self._pending = 0          # tasks enqueued but not finished
        self._errors: list[BaseException] = []
        self._inflight: deque[BatchHandle] = deque()
        self._locks: dict[int, threading.Lock] = {}  # id(store) -> exclusivity lock
        self._closed = False
        # a front-end with a nontrivial _after_batch (the range store's
        # migration/rebalance policy) needs a sequence point per batch to stay
        # byte-identical to serial; a hash front-end pipelines barrier-free
        self._has_policy = type(store)._after_batch is not BaseShardedStore._after_batch

    # ------------------------------------------------------------- lifecycle
    def __enter__(self) -> "ShardExecutor":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(wait=exc_type is None)

    def close(self, wait: bool = True) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            if wait:
                self.drain()
        finally:
            self._pool.shutdown(wait=True)

    # -------------------------------------------------------------- plumbing
    def _raise_if_failed_locked(self) -> None:
        if self._errors:
            raise RuntimeError("shard executor task failed") from self._errors[0]

    def _leg_groups(self) -> dict:
        """Merged queue keys for every shard id touched by an in-flight
        migration leg: union-find over the legs' src/dst pairs.  Shard ids
        connected (transitively) by legs share one ``("mig", root)`` key —
        their queues serialize — while *disjoint* groups keep distinct keys,
        so a rescale's independent legs drain concurrently.  With a single
        legacy leg this degenerates to the old ``("mig", min(src, dst))``."""
        legs = getattr(self.store, "migrations", ())
        if not legs:
            return {}
        parent: dict[int, int] = {}

        def find(x: int) -> int:
            root = x
            while parent[root] != root:
                root = parent[root]
            while parent[x] != root:
                parent[x], x = root, parent[x]
            return root

        for m in legs:
            parent.setdefault(m.src_id, m.src_id)
            parent.setdefault(m.dst_id, m.dst_id)
            a, b = find(m.src_id), find(m.dst_id)
            if a != b:
                parent[max(a, b)] = min(a, b)
        return {sid: ("mig", find(sid)) for sid in parent}

    def _queue_key(self, sid: int):
        """Stable queue identity for shard index ``sid``: the shard id where
        the store has stable ids (range), the index otherwise (hash).  Every
        shard in a migration leg group collapses to the group's merged key —
        double-routed reads touch both sides of a leg, so each group must
        serialize (but only within itself; see :meth:`_leg_groups`)."""
        ids = getattr(self.store, "_shard_ids", None)
        key = ids[sid] if ids is not None else sid
        return self._leg_groups().get(key, key)

    def _group_stores(self, qkey) -> list[ParallaxStore]:
        """Backing stores of one merged migration group — the set a
        double-routed read submitted under ``qkey`` may touch.  Only this
        group's stores are locked by its tasks: locking another group's
        stores would contend with that group's own (concurrent) tasks and
        trip the shard-independence assertion spuriously."""
        groups = self._leg_groups()
        return [self.store._store_of_id(sid)
                for sid, key in groups.items() if key == qkey]

    # contract: coordinator-only
    def _new_store_lock(self) -> threading.Lock:
        """Factory for per-store exclusivity locks — the *only* place they are
        created (worker threads must never create locks: two racing creations
        would hand mis-queued tasks *different* locks and blind the very
        assertion they implement).  The race detector overrides this per
        instance to hand out tracked locks."""
        return threading.Lock()

    def _lock_of(self, store: ParallaxStore) -> threading.Lock:
        """Coordinator-only: the per-store exclusivity lock, created at
        enqueue time."""
        with self._cv:
            lock = self._locks.get(id(store))
            if lock is None:
                lock = self._locks[id(store)] = self._new_store_lock()
            return lock

    def _enqueue(self, key, stores: list[ParallaxStore], fn: Callable[[], None],
                 handle: BatchHandle) -> None:
        # NOTE: earlier task failures are NOT raised here — submission racing
        # a worker's failure would make the raise site nondeterministic.
        # Errors surface only at sync points (drain / BatchHandle.result /
        # exclusive), which every driver reaches promptly (backpressure,
        # per-batch policy hooks, end-of-stream drain).
        with self._cv:
            if self._closed:
                raise RuntimeError("executor is closed")
            # pre-create the stores' exclusivity locks here, on the single
            # submitter, so workers only ever *read* self._locks
            for s in stores:
                if id(s) not in self._locks:
                    self._locks[id(s)] = self._new_store_lock()
            self._pending += 1
            q = self._queues.get(key)
            if q is None:
                q = self._queues[key] = _ShardQueue()
            q.items.append((stores, fn, handle))
            if not q.active:
                q.active = True
                self._pool.submit(self._drain_queue, q)

    def _drain_queue(self, q: _ShardQueue) -> None:
        while True:
            with self._cv:
                if not q.items:
                    q.active = False
                    return
                stores, fn, handle = q.items.popleft()
            try:
                self._run_task(stores, fn)
            except BaseException as e:  # noqa: BLE001 — reported via drain/result
                with self._cv:
                    self._errors.append(e)
            finally:
                with self._cv:
                    self._pending -= 1
                    if handle is not None:
                        handle._remaining -= 1
                    self._cv.notify_all()

    def _run_task(self, stores: list[ParallaxStore], fn: Callable[[], None]) -> None:
        # shard-independence assertion: queue FIFO already guarantees one task
        # per store, so a blocked acquire is an invariant violation, not a
        # wait-for condition (locks pre-created at enqueue; read-only here)
        locks = [self._locks[id(s)] for s in stores]
        acquired = []
        try:
            for lock in locks:
                if not lock.acquire(blocking=False):
                    raise RuntimeError(
                        "shard-independence violated: two executor tasks "
                        "touched one store concurrently"
                    )
                acquired.append(lock)
            before = sum(s.device.device_time() for s in stores) if self.pace else 0.0
            fn()
        finally:
            for lock in acquired:
                lock.release()
        if self.pace:
            busy = sum(s.device.device_time() for s in stores) - before
            if busy > 0:
                time.sleep(busy * self.pace)

    def _track(self, handle: BatchHandle) -> BatchHandle:
        """Backpressure: cap the pipelined window, or drain when pipelining
        is off (within-batch fan-out only)."""
        if not self.pipeline:
            handle.result()
            return handle
        self._inflight.append(handle)
        while len(self._inflight) > self.max_pending:
            self._inflight.popleft().result()
        return handle

    # ---------------------------------------------------------- sequencing
    def drain(self) -> None:
        """Block until every submitted task has finished; re-raise failures."""
        with self._cv:
            self._cv.wait_for(lambda: self._pending == 0)
            self._raise_if_failed_locked()
        self._inflight.clear()

    def exclusive(self, fn: Callable[[], object]):
        """Run ``fn`` at a sequence point: all queues drained, nothing else in
        flight (the coordinator is the only submitter).  Cross-shard reads
        (scans), policy ticks, GC on adaptive stores, crash/recover and
        topology mutations all come through here; with pacing, the stall is
        charged like any task (a synchronous maintenance stall)."""
        self.drain()
        before = self._fleet_time() if self.pace else 0.0
        try:
            return fn()
        finally:
            if self.pace:
                busy = self._fleet_time() - before
                if busy > 0:
                    time.sleep(busy * self.pace)

    def _fleet_time(self) -> float:
        total = sum(s.device.device_time() for s in self.store._all_stores())
        meta = getattr(self.store, "meta_device", None)
        return total + (meta.device_time() if meta is not None else 0.0)

    # ------------------------------------------------------------ batched ops
    def _submit_write(self, op: str, items: Sequence, keys: Sequence[bytes]) -> BatchHandle:
        groups = self.store._group(keys)
        handle = BatchHandle(self, len(groups))
        for sid, positions in groups.items():
            shard = self.store.shards[sid]
            sub = [items[p] for p in positions]
            # writes touch only the routed shard (pending-region writes go to
            # the migration destination, whose queue key is the merged pair —
            # so they still serialize against double-routed reads)
            self._enqueue(self._queue_key(sid), [shard], self._write_fn(op, shard, sub), handle)
        return self._track(handle)

    @staticmethod
    def _write_fn(op: str, shard: ParallaxStore, sub: list) -> Callable[[], None]:
        if op == "put":
            def fn():
                for key, value in sub:
                    shard.put(key, value)
        elif op == "update":
            def fn():
                for key, value in sub:
                    shard.update(key, value)
        else:
            def fn():
                for key in sub:
                    shard.delete(key)
        return fn

    def put_many(self, items: Sequence[tuple[bytes, bytes]]) -> BatchHandle:
        return self._submit_write("put", items, [k for k, _ in items])

    def update_many(self, items: Sequence[tuple[bytes, bytes]]) -> BatchHandle:
        return self._submit_write("update", items, [k for k, _ in items])

    def delete_many(self, keys: Sequence[bytes]) -> BatchHandle:
        return self._submit_write("delete", keys, keys)

    def get_many(self, keys: Sequence[bytes]) -> BatchHandle:
        """Batched point reads; ``.result()`` yields the value list in key
        order (same totals and per-shard traffic as the serial path)."""
        store = self.store
        groups = store._group(keys)
        out: list[bytes | None] = [None] * len(keys)
        handle = BatchHandle(self, len(groups), value=out)
        # batch-level counter bumps on the coordinator (the serial path bumps
        # per key; the totals are identical) — locked against the worker-side
        # fallback-probe bumps of the double-routing read path
        with store._stats_lock:
            store.gets += len(keys)
            store.get_probes += len(keys)
        for sid, positions in groups.items():
            shard = store.shards[sid]
            qkey = self._queue_key(sid)
            # only tasks on a merged migration queue can double-route into
            # that group's stores (pending-region keys route to a leg's
            # destination, whose queue key is the group's); they lock the
            # group's stores and nothing else — see _group_stores
            if isinstance(qkey, tuple):
                group = self._group_stores(qkey)
                stores = [shard] + [s for s in group if s is not shard]
            else:
                stores = [shard]

            def fn(sid=sid, positions=positions):
                for pos in positions:
                    out[pos] = store._get_from(sid, keys[pos])

            self._enqueue(qkey, stores, fn, handle)
        return self._track(handle)

    def scan(self, start: bytes, count: int) -> list[tuple[bytes, bytes]]:
        """Sorted scan at a sequence point (it reads across shards, and on
        adaptive stores it feeds the skew window / ticks the policy exactly
        like the serial path)."""
        return self.exclusive(lambda: self.store.scan(start, count))

    # ------------------------------------------------------------ maintenance
    def after_batch(self) -> None:
        """The serial path's per-batch policy hook, as a sequence point.

        Hash front-ends have a no-op hook: nothing is scheduled and the
        pipeline keeps flowing.  Policy stores (range) evaluate the rebalancer
        / advance the in-flight migration exactly once per batch, exactly
        like ``BaseShardedStore``'s batched ops do inline."""
        if self._has_policy:
            self.exclusive(self.store._after_batch)

    def migration_tick(self, budget: int | None = None) -> int:
        """Advance an in-flight migration at a sequence point (bounded by
        ``budget`` keys, defaulting to the store's ``migration_batch_keys``)."""
        tick = getattr(self.store, "migration_tick", None)
        if tick is None:
            return 0
        return self.exclusive(lambda: tick(budget))

    def gc_tick(self, force: bool = False) -> None:
        """Large-log GC off the foreground path.

        On a policy-free (hash) front-end each shard's GC is enqueued on that
        shard's queue: it runs behind the shard's earlier foreground work and
        ahead of later work — the same per-shard projection as the serial
        path's stop-the-world ``gc_tick`` — while other shards' foreground
        traffic keeps flowing.  Policy stores run it at a sequence point (its
        ``_after_batch`` must see the post-GC counters, like serial), and so
        does a hash store mid-rescale: ``_all_stores()`` then includes
        draining ex-slots whose list position is not their queue identity,
        so per-shard enqueueing would mis-key their tasks."""
        if self._has_policy or getattr(self.store, "migrations", ()):
            self.exclusive(lambda: self.store.gc_tick(force=force))
            return
        handle = BatchHandle(self, len(self.store._all_stores()))
        for i, shard in enumerate(self.store._all_stores()):
            def fn(shard=shard):
                shard.gc_tick(force=force)
            self._enqueue(self._queue_key(i), [shard], fn, handle)
        self._track(handle)


__all__ = ["BatchHandle", "ShardExecutor"]
