"""Static + dynamic checkers for the engine's concurrency/durability contracts.

The async sharded engine's correctness rests on invariants that used to live
only in comments and after-the-fact differential tests: single-coordinator
submission, per-store exclusivity locks created coordinator-side only,
front-end counters mutated only under ``_stats_lock``, metadata-WAL
record-then-apply and flush-before-record ordering, and the determinism rules
(crc32 not ``hash()``, no wall-clock in modeled paths).  This package checks
them mechanically:

* :mod:`repro.analysis.lint` — a stdlib-``ast`` static linter with pluggable
  rules keyed on ``# contract:`` source annotations; run it as
  ``scripts/lint_contracts.py`` (a CI hard gate with a seeded-violation
  self-test under ``tests/fixtures/``).
* :mod:`repro.analysis.racecheck` — an Eraser-style dynamic lockset race
  detector, enabled with ``EngineConfig(debug_checks=True)`` or the
  ``REPRO_DEBUG_CHECKS`` env var.  Nothing here is imported unless a checker
  is switched on, so the production path provably pays nothing.
* :mod:`repro.analysis.protocol` — the metadata-WAL record protocol declared
  once (:data:`~repro.analysis.protocol.spec.WAL_SPEC`) and enforced three
  ways: a static conformance pass over every append site
  (``scripts/check_protocol.py``, a CI hard gate), a runtime stream monitor
  behind the same debug switch as the race detector, and the spec-derived
  coverage requirement of the crash-point sweep.

See ``docs/analysis.md`` for the annotation vocabulary, the protocol spec,
and how to add rules or record kinds.
"""

__all__ = ["lint", "protocol", "racecheck"]
