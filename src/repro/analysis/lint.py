"""Static contract linter: pluggable ``ast`` rules over ``# contract:`` markers.

Each rule is a class with a ``name`` and a ``check(mod) -> list[Violation]``;
the :data:`RULES` registry is the pluggable surface — adding a rule is
appending a class here (and seeding ``tests/fixtures/lint_bad/`` with a
planted violation so the self-test proves it fires; ``--self-test`` fails if
any registered rule has no bad-fixture coverage).

The rules encode the contracts PRs 1-5 established (see ``docs/analysis.md``):

* ``no-nondeterminism`` — modeled paths must be bit-identical across
  processes: no builtin ``hash()``, no wall-clock reads (``time.time`` and
  friends; ``time.sleep`` is pacing, not modeling, and is allowed), no stdlib
  ``random`` (seeded ``numpy`` generators are fine).
* ``coordinator-only-locks`` — ``threading`` lock objects may only be created
  inside functions annotated ``coordinator-only``: worker threads racing to
  create a lock would hand two tasks *different* locks and blind the very
  exclusivity assertion the lock implements.
* ``stats-lock`` — shared front-end counters (``self.gets += 1`` etc.) may be
  mutated only under ``with ..._stats_lock:`` or inside ``coordinator-only``
  functions.  Per-store ``self.stats.*`` counters are out of scope: each
  backing store is single-threaded by the executor's exclusivity contract.
* ``record-then-apply`` — in annotated functions, topology state may only be
  mutated *after* the first durable ``metalog.append`` record call (the WAL
  replay discipline: a crash before the record means the action never was).
* ``flush-before-record`` — in annotated functions, the first ``flush``/
  ``flush_all`` must precede the first durable-record write (the redo record
  must not cover data that is not yet durable — the PR 1 dangling-pointer
  class of bug).
* ``rename-before-truncate`` — in annotated functions, the first
  ``.truncate(...)`` call must follow the first replacement write
  (``metalog.append``, ``os.replace``/``os.rename``, or
  ``atomic_write_bytes``): history may only be dropped *after* the state it
  summarized has been durably republished — a crash between the truncate and
  the replacement would lose the only copy (the PR 7 snapshot discipline).
* ``lock-free-hot-path`` — functions annotated ``single-threaded`` are
  modeled hot paths and must not acquire or create locks.
* ``contract-annotation`` — annotation hygiene: unknown markers and
  ``exempt`` without a justification are themselves violations.

Run as ``scripts/lint_contracts.py`` (the CI hard gate).
"""
from __future__ import annotations

import ast
import dataclasses

from .contracts import ModuleContracts

# shared front-end counters (BaseShardedStore / RangeShardedStore); the
# stats-lock rule matches direct attributes only (``self.gets``), never
# ``self.stats.gets`` — per-store StoreStats are executor-serialized
FRONTEND_COUNTERS = frozenset([
    "gets", "get_probes", "get_fallbacks", "scans", "scan_probes",
    "splits", "merges", "migrated_keys", "migration_ticks",
])

# topology state covered by the record-then-apply discipline: the range
# boundary map and shard registries, plus the elastic-rescale state shared by
# both partitioning schemes (concurrent migration legs, the rescale
# coordinator, and a shrinking hash fleet's draining ex-slots)
TOPOLOGY_ATTRS = frozenset([
    "boundaries", "shards", "_shard_ids", "_migration",
    "_migrations", "_rescale", "_draining",
])
_MUTATOR_METHODS = frozenset([
    "insert", "append", "pop", "remove", "clear", "extend", "sort", "reverse",
])

_LOCK_FACTORIES = frozenset([
    "Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore", "Barrier", "Event",
])

_WALLCLOCK_FNS = frozenset([
    "time", "time_ns", "monotonic", "monotonic_ns", "perf_counter", "perf_counter_ns",
])


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    lineno: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.lineno}: [{self.rule}] {self.message}"


def _attr_chain_root(node: ast.AST) -> ast.AST:
    while isinstance(node, ast.Attribute):
        node = node.value
    return node


def _is_threading_lock_call(node: ast.Call) -> bool:
    f = node.func
    return (isinstance(f, ast.Attribute) and f.attr in _LOCK_FACTORIES
            and isinstance(f.value, ast.Name) and f.value.id == "threading")


class Rule:
    """Base: subclass, set ``name``, implement :meth:`check`."""

    name = "rule"

    def check(self, mod: ModuleContracts) -> list[Violation]:
        raise NotImplementedError

    def _v(self, mod: ModuleContracts, lineno: int, message: str) -> Violation:
        return Violation(mod.path, lineno, self.name, message)


class NoNondeterminismRule(Rule):
    name = "no-nondeterminism"

    def check(self, mod: ModuleContracts) -> list[Violation]:
        out = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Name) and f.id == "hash":
                    out.append(self._v(mod, node.lineno,
                                       "builtin hash() is PYTHONHASHSEED-randomized; "
                                       "use zlib.crc32 in modeled paths"))
                elif (isinstance(f, ast.Attribute) and f.attr in _WALLCLOCK_FNS
                      and isinstance(f.value, ast.Name) and f.value.id == "time"):
                    out.append(self._v(mod, node.lineno,
                                       f"wall-clock time.{f.attr}() in a modeled path; "
                                       "model time via Device.device_time"))
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "random" or alias.name.startswith("random."):
                        out.append(self._v(mod, node.lineno,
                                           "stdlib random is process-seeded; use a seeded "
                                           "numpy Generator (np.random.default_rng)"))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "random":
                    out.append(self._v(mod, node.lineno,
                                       "stdlib random is process-seeded; use a seeded "
                                       "numpy Generator (np.random.default_rng)"))
        return out


class CoordinatorOnlyLocksRule(Rule):
    name = "coordinator-only-locks"

    def check(self, mod: ModuleContracts) -> list[Violation]:
        out = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and _is_threading_lock_call(node):
                if not mod.has_marker(node, "coordinator-only"):
                    out.append(self._v(
                        mod, node.lineno,
                        f"threading.{node.func.attr}() created outside a "
                        "'# contract: coordinator-only' function (racing lock "
                        "creation hands tasks different locks)"))
        return out


class StatsLockRule(Rule):
    name = "stats-lock"

    def check(self, mod: ModuleContracts) -> list[Violation]:
        out = []
        self._scan(mod, mod.tree, False, out)
        return out

    @staticmethod
    def _is_stats_lock_with(node: ast.With) -> bool:
        return any(isinstance(item.context_expr, ast.Attribute)
                   and item.context_expr.attr == "_stats_lock"
                   for item in node.items)

    def _scan(self, mod, node, locked, out) -> None:
        for child in ast.iter_child_nodes(node):
            child_locked = locked
            if isinstance(child, ast.With) and self._is_stats_lock_with(child):
                child_locked = True
            targets = []
            if isinstance(child, ast.AugAssign):
                targets = [child.target]
            elif isinstance(child, ast.Assign):
                targets = child.targets
            for t in targets:
                if (isinstance(t, ast.Attribute) and t.attr in FRONTEND_COUNTERS
                        and isinstance(t.value, ast.Name)):
                    if not child_locked and not mod.has_marker(child, "coordinator-only"):
                        out.append(self._v(
                            mod, child.lineno,
                            f"front-end counter '{t.value.id}.{t.attr}' mutated outside "
                            "'with ..._stats_lock:' and outside a coordinator-only "
                            "function"))
            self._scan(mod, child, child_locked, out)


def _record_call_lineno(fn: ast.AST, *, include_device_writes: bool) -> int | None:
    """Line of the first durable-record call in ``fn``: ``*.metalog.append(...)``
    and, for the flush rule, ``*.device.sequential_write(...)`` (the store's
    redo-record idiom)."""
    best: int | None = None
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
            continue
        f = node.func
        hit = (f.attr == "append" and isinstance(f.value, ast.Attribute)
               and f.value.attr == "metalog")
        if include_device_writes and not hit:
            hit = (f.attr == "sequential_write" and isinstance(f.value, ast.Attribute)
                   and f.value.attr == "device")
        if hit and (best is None or node.lineno < best):
            best = node.lineno
    return best


class RecordThenApplyRule(Rule):
    name = "record-then-apply"

    def check(self, mod: ModuleContracts) -> list[Violation]:
        out = []
        for fn in mod.functions_with("record-then-apply"):
            record_line = _record_call_lineno(fn, include_device_writes=False)
            if record_line is None:
                out.append(self._v(
                    mod, fn.lineno,
                    f"'{fn.name}' is annotated record-then-apply but never calls "
                    "metalog.append"))
                continue
            for node, attr in self._topology_mutations(fn):
                if node.lineno < record_line:
                    out.append(self._v(
                        mod, node.lineno,
                        f"topology state '{attr}' mutated before the metalog.append "
                        f"record at line {record_line} (a crash here would leave "
                        "applied-but-unrecorded state)"))
        return out

    @staticmethod
    def _topo_attr(node: ast.AST) -> str | None:
        """The topology attribute a store/delete target touches, if any."""
        if isinstance(node, ast.Subscript):
            node = node.value
        if (isinstance(node, ast.Attribute) and node.attr in TOPOLOGY_ATTRS
                and isinstance(node.value, ast.Name)):
            return node.attr
        return None

    def _topology_mutations(self, fn: ast.AST):
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) else [node.target]
                for t in targets:
                    attr = self._topo_attr(t)
                    if attr is not None:
                        yield node, attr
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    attr = self._topo_attr(t)
                    if attr is not None:
                        yield node, attr
            elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
                if node.func.attr in _MUTATOR_METHODS:
                    attr = self._topo_attr(node.func.value)
                    if attr is not None:
                        yield node, attr


class FlushBeforeRecordRule(Rule):
    name = "flush-before-record"

    def check(self, mod: ModuleContracts) -> list[Violation]:
        out = []
        for fn in mod.functions_with("flush-before-record"):
            flush_line = None
            for node in ast.walk(fn):
                if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                        and node.func.attr in ("flush", "flush_all")):
                    if flush_line is None or node.lineno < flush_line:
                        flush_line = node.lineno
            record_line = _record_call_lineno(fn, include_device_writes=True)
            if record_line is None:
                out.append(self._v(
                    mod, fn.lineno,
                    f"'{fn.name}' is annotated flush-before-record but writes no "
                    "durable record (metalog.append / device.sequential_write)"))
            elif flush_line is None:
                out.append(self._v(
                    mod, fn.lineno,
                    f"'{fn.name}' is annotated flush-before-record but never "
                    "flushes before its record"))
            elif record_line < flush_line:
                out.append(self._v(
                    mod, record_line,
                    f"durable record written before the flush at line {flush_line}: "
                    "the record must not cover data that is not yet durable"))
        return out


class RenameBeforeTruncateRule(Rule):
    name = "rename-before-truncate"

    @staticmethod
    def _replacement_lineno(fn: ast.AST) -> int | None:
        """Line of the first replacement write: ``*.metalog.append(...)``,
        ``os.replace``/``os.rename``, or ``atomic_write_bytes(...)``."""
        best = _record_call_lineno(fn, include_device_writes=False)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            hit = (isinstance(f, ast.Name) and f.id == "atomic_write_bytes")
            if not hit:
                hit = (isinstance(f, ast.Attribute) and f.attr in ("replace", "rename")
                       and isinstance(f.value, ast.Name) and f.value.id == "os")
            if hit and (best is None or node.lineno < best):
                best = node.lineno
        return best

    def check(self, mod: ModuleContracts) -> list[Violation]:
        out = []
        for fn in mod.functions_with("rename-before-truncate"):
            truncate_line = None
            for node in ast.walk(fn):
                if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "truncate"):
                    if truncate_line is None or node.lineno < truncate_line:
                        truncate_line = node.lineno
            replacement_line = self._replacement_lineno(fn)
            if truncate_line is None:
                out.append(self._v(
                    mod, fn.lineno,
                    f"'{fn.name}' is annotated rename-before-truncate but never "
                    "calls .truncate(...)"))
            elif replacement_line is None:
                out.append(self._v(
                    mod, truncate_line,
                    f"'{fn.name}' truncates history but writes no replacement "
                    "(metalog.append / os.replace / atomic_write_bytes): a crash "
                    "after the truncate loses the only copy"))
            elif truncate_line < replacement_line:
                out.append(self._v(
                    mod, truncate_line,
                    f"history truncated before the replacement write at line "
                    f"{replacement_line}: a crash between them loses the only copy"))
        return out


class LockFreeHotPathRule(Rule):
    name = "lock-free-hot-path"

    def check(self, mod: ModuleContracts) -> list[Violation]:
        out = []
        for fn in mod.functions_with("single-threaded"):
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    if (isinstance(node.func, ast.Attribute)
                            and node.func.attr == "acquire"):
                        out.append(self._v(
                            mod, node.lineno,
                            f"lock acquire in single-threaded hot path '{fn.name}'"))
                    elif _is_threading_lock_call(node):
                        out.append(self._v(
                            mod, node.lineno,
                            f"lock created in single-threaded hot path '{fn.name}'"))
                elif isinstance(node, ast.With):
                    for item in node.items:
                        e = item.context_expr
                        if isinstance(e, ast.Attribute) and "lock" in e.attr.lower():
                            out.append(self._v(
                                mod, node.lineno,
                                f"'with {e.attr}:' in single-threaded hot path "
                                f"'{fn.name}'"))
        return out


class AnnotationHygieneRule(Rule):
    name = "contract-annotation"

    def check(self, mod: ModuleContracts) -> list[Violation]:
        return [self._v(mod, p.lineno, p.message) for p in mod.problems]


RULES: list[Rule] = [
    NoNondeterminismRule(),
    CoordinatorOnlyLocksRule(),
    StatsLockRule(),
    RecordThenApplyRule(),
    FlushBeforeRecordRule(),
    RenameBeforeTruncateRule(),
    LockFreeHotPathRule(),
    AnnotationHygieneRule(),
]


def lint_source(path: str, source: str) -> list[Violation]:
    """All rules over one source text; ``exempt``-covered lines are dropped
    (the hygiene rule is never exemptable — a bad annotation cannot justify
    itself)."""
    mod = ModuleContracts(path, source)
    out: list[Violation] = []
    for rule in RULES:
        for v in rule.check(mod):
            if rule.name != AnnotationHygieneRule.name and mod.exempted(v.lineno):
                continue
            out.append(v)
    return sorted(out, key=lambda v: (v.path, v.lineno, v.rule))


def lint_paths(paths) -> list[Violation]:
    out: list[Violation] = []
    for path in paths:
        with open(path, "r", encoding="utf-8") as fh:
            out.extend(lint_source(str(path), fh.read()))
    return out


__all__ = [
    "FRONTEND_COUNTERS",
    "RULES",
    "Rule",
    "TOPOLOGY_ATTRS",
    "Violation",
    "lint_paths",
    "lint_source",
]
