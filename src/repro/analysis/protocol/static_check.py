"""Static conformance pass: the code against :data:`~.spec.WAL_SPEC`.

An ``ast``-based intraprocedural CFG + dataflow analysis over the protocol's
implementation files (``repro/core/{metalog,range_shard,shard,store}.py`` and
``repro/elastic/remap.py``): every ``MetadataLog.append`` call site is
resolved to its record kind(s) — through flow-sensitive reaching definitions
when the record is built in a local variable and extended with conditional
``rec["key"] = ...`` assigns — and checked against the spec on every path:

* **undeclared-kind / unappended-kind** — the appended kind must exist in the
  spec, and (with ``require_complete``) every spec kind must be appended
  somewhere in the analyzed tree: adding a record kind to the code without
  extending the spec, or to the spec without wiring it up, is a hard failure.
* **unresolved-kind** — an append whose argument cannot be resolved to dict
  literals with a constant ``"kind"`` defeats the whole analysis and is
  itself a violation (the protocol implementation must stay analyzable).
* **payload-keys** — on every resolved path, required keys present and no
  keys outside the spec's ``required | optional``.
* **order** — the automaton run over *feasible-state sets*: a function's
  entry state is unknown, so the set starts as all states and each append
  keeps only the states reachable through that kind's transitions; an empty
  set means no caller state could make the emission sequence legal.
* **fence-flush** — kinds fenced ``flush-before-append`` need a
  ``<store>.flush_all()`` that reaches the append on every path with the
  flushed receiver not written in between (must-dataflow over receiver
  variables; a loop that only flushes counts as flushing the fleet).
* **fence-apply** — kinds fenced ``record-then-apply`` must not be preceded
  (on any path) by a mutation of the topology attributes the record
  describes (``TOPOLOGY_ATTRS``, shared with :mod:`repro.analysis.lint`).
* **fence-truncate** — ``metalog.truncate`` only after an append of a
  ``truncate-after-append`` kind on every path (rename-before-truncate).

The analysis is deliberately conservative where it must approximate:
``return``/``raise``/``break``/``continue`` kill their paths, branch joins
union reaching record shapes and automaton states, and loops are iterated to
a small fixpoint.  Run it as ``scripts/check_protocol.py`` (a CI hard gate
next to ``lint_contracts``), or call :func:`check_paths` directly.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib

from ..lint import TOPOLOGY_ATTRS, Violation
from .spec import (
    FLUSH_BEFORE_APPEND,
    RECORD_THEN_APPLY,
    TRUNCATE_AFTER_APPEND,
    ProtocolSpec,
    WAL_SPEC,
)

#: every rule this pass can emit (mirrors ``lint.RULES`` for self-test coverage)
PROTOCOL_RULES = (
    "undeclared-kind", "unappended-kind", "unresolved-kind", "payload-keys",
    "order", "fence-flush", "fence-apply", "fence-truncate",
)

# store methods that make a receiver's logs dirty (volatile) again
_DIRTYING_METHODS = frozenset([
    "_write", "put", "put_many", "update", "delete", "delete_range",
    "delete_many", "load_rows", "write",
])
_FLUSH_METHODS = frozenset(["flush_all"])
# container mutators that count as a topology mutation on a TOPOLOGY_ATTRS
_TOPO_MUTATORS = frozenset([
    "insert", "append", "pop", "remove", "clear", "extend", "sort", "reverse",
    "update",
])

_CLEAN, _DIRTY = "clean", "dirty"


@dataclasses.dataclass(frozen=True)
class AppendSite:
    """One statically resolved ``metalog.append`` call site."""

    path: str
    lineno: int
    func: str
    kind: str  # "" when unresolved


@dataclasses.dataclass(frozen=True)
class _DictFact:
    """Abstract value of a record dict: its kind and key set."""

    kind: str | None  # None: no constant "kind" key
    keys: frozenset
    open: bool  # non-constant keys / ** expansion: unknown-key check off


class _State:
    """Abstract state at one program point (one path bundle)."""

    __slots__ = ("feasible", "flush", "defs", "topo_mutated", "truncate_ok",
                 "live")

    def __init__(self, feasible):
        self.feasible = feasible      # frozenset of automaton states
        self.flush = {}               # var -> _CLEAN | _DIRTY (absent: unknown)
        self.defs = {}                # var -> frozenset[_DictFact] | None
        self.topo_mutated = False     # may-analysis
        self.truncate_ok = False      # must-analysis
        self.live = True

    def copy(self) -> "_State":
        s = _State(self.feasible)
        s.flush = dict(self.flush)
        s.defs = dict(self.defs)
        s.topo_mutated = self.topo_mutated
        s.truncate_ok = self.truncate_ok
        s.live = self.live
        return s

    def key(self):
        return (self.feasible, tuple(sorted(self.flush.items())),
                tuple(sorted((k, v) for k, v in self.defs.items()
                             if v is not None)),
                self.topo_mutated, self.truncate_ok, self.live)


def _join(a: _State, b: _State) -> _State:
    if not a.live:
        return b
    if not b.live:
        return a
    out = _State(a.feasible | b.feasible)
    # flush status: must-join (clean only if clean on both paths)
    for var in set(a.flush) | set(b.flush):
        va, vb = a.flush.get(var), b.flush.get(var)
        if va == vb == _CLEAN:
            out.flush[var] = _CLEAN
        elif _DIRTY in (va, vb):
            out.flush[var] = _DIRTY
    # reaching record shapes: may-join (union of fact sets; None poisons)
    for var in set(a.defs) | set(b.defs):
        fa, fb = a.defs.get(var, None), b.defs.get(var, None)
        if fa is None or fb is None:
            out.defs[var] = None
        else:
            out.defs[var] = fa | fb
    out.topo_mutated = a.topo_mutated or b.topo_mutated
    out.truncate_ok = a.truncate_ok and b.truncate_ok
    return out


# ------------------------------------------------------------- ast utilities
def _is_metalog_recv(node: ast.AST) -> bool:
    """``self.metalog`` / ``st.metalog`` / bare ``metalog``."""
    return ((isinstance(node, ast.Attribute) and node.attr == "metalog")
            or (isinstance(node, ast.Name) and node.id == "metalog"))


def _recv_token(node: ast.AST) -> str | None:
    """A stable name for a call receiver: ``dst`` -> "dst", ``self.x`` ->
    "self.x"; subscripted/call receivers have no stable identity (None)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _recv_token(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _iter_calls(node: ast.AST):
    """Calls inside ``node`` in source (pre)order, skipping nested function
    and lambda bodies (they execute elsewhere, if at all)."""
    stack = [node]
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        if isinstance(n, ast.Call):
            yield n
        stack.extend(reversed(list(ast.iter_child_nodes(n))))


def _dict_fact(node: ast.Dict) -> _DictFact:
    keys, kind, open_ = set(), None, False
    for k, v in zip(node.keys, node.values):
        if k is None:  # ** expansion
            open_ = True
            continue
        if isinstance(k, ast.Constant) and isinstance(k.value, str):
            keys.add(k.value)
            if k.value == "kind":
                kind = v.value if (isinstance(v, ast.Constant)
                                   and isinstance(v.value, str)) else None
        else:
            open_ = True
    return _DictFact(kind, frozenset(keys), open_)


def _is_self_topo_target(node: ast.AST) -> bool:
    """``self.<topo>``, or a subscript/slice of it (``del self.shards[i]``)."""
    while isinstance(node, ast.Subscript):
        node = node.value
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and node.attr in TOPOLOGY_ATTRS)


# ---------------------------------------------------------------- the checker
class _FunctionChecker:
    def __init__(self, path: str, qualname: str, spec: ProtocolSpec):
        self.path = path
        self.qualname = qualname
        self.spec = spec
        self.violations: dict[tuple, Violation] = {}
        self.sites: dict[tuple, AppendSite] = {}

    # ------------------------------------------------------------- reporting
    def _report(self, lineno: int, rule: str, message: str) -> None:
        key = (lineno, rule)
        if key not in self.violations:
            self.violations[key] = Violation(self.path, lineno, rule, message)

    def _site(self, lineno: int, kind: str) -> None:
        self.sites.setdefault((lineno, kind),
                              AppendSite(self.path, lineno, self.qualname, kind))

    # ------------------------------------------------------------ call effects
    def _apply_call(self, call: ast.Call, state: _State) -> None:
        fn = call.func
        if not isinstance(fn, ast.Attribute):
            return
        recv = fn.value
        if fn.attr == "append" and _is_metalog_recv(recv):
            self._apply_append(call, state)
            return
        if fn.attr == "truncate" and _is_metalog_recv(recv):
            if not state.truncate_ok:
                self._report(
                    call.lineno, "fence-truncate",
                    "metalog.truncate without a durable snapshot-class append "
                    "on every path to it (rename-before-truncate: history may "
                    "only be destroyed after its replacement record commits)")
            return
        # topology mutation via container method: self.<topo>.insert(...)
        if (fn.attr in _TOPO_MUTATORS and isinstance(recv, ast.Attribute)
                and isinstance(recv.value, ast.Name) and recv.value.id == "self"
                and recv.attr in TOPOLOGY_ATTRS):
            state.topo_mutated = True
            return
        token = _recv_token(recv)
        if fn.attr in _FLUSH_METHODS and token is not None:
            state.flush[token] = _CLEAN
        elif fn.attr in _DIRTYING_METHODS and token is not None:
            state.flush[token] = _DIRTY
            # a write anywhere invalidates whole-fleet flush facts
            for var in list(state.flush):
                if var.startswith("__fleet") or var == "self":
                    state.flush.pop(var)

    def _resolve_arg(self, call: ast.Call, state: _State):
        """The record argument's reaching dict facts, or None (unresolved)."""
        if len(call.args) != 1 or call.keywords:
            return None
        arg = call.args[0]
        if isinstance(arg, ast.Dict):
            return frozenset([_dict_fact(arg)])
        if isinstance(arg, ast.Name):
            facts = state.defs.get(arg.id, None)
            if facts:  # None (poisoned) and empty both mean unresolved
                return facts
        return None

    def _apply_append(self, call: ast.Call, state: _State) -> None:
        lineno = call.lineno
        facts = self._resolve_arg(call, state)
        if facts is None:
            self._report(
                lineno, "unresolved-kind",
                f"metalog.append argument in {self.qualname} cannot be "
                "resolved to dict literal(s) with a constant 'kind' — the "
                "protocol implementation must stay statically analyzable")
            self._site(lineno, "")
            return
        next_feasible = frozenset()
        stepped_kinds = []
        for fact in facts:
            if fact.kind is None:
                self._report(
                    lineno, "unresolved-kind",
                    f"record dict reaching metalog.append in {self.qualname} "
                    "has no constant 'kind' key")
                continue
            if fact.kind not in self.spec:
                self._report(
                    lineno, "undeclared-kind",
                    f"record kind {fact.kind!r} is appended here but not "
                    f"declared in the {self.spec.name} spec")
                continue
            kind = self.spec[fact.kind]
            self._site(lineno, kind.name)
            missing = kind.required - fact.keys
            unknown = (frozenset() if fact.open
                       else fact.keys - kind.payload_keys)
            if missing or unknown:
                parts = []
                if missing:
                    parts.append(f"missing required key(s) {sorted(missing)}")
                if unknown:
                    parts.append(f"key(s) {sorted(unknown)} not in the spec's "
                                 "required|optional set")
                self._report(lineno, "payload-keys",
                             f"{kind.name} payload: " + "; ".join(parts))
            stepped_kinds.append(kind)
            next_feasible |= kind.step(state.feasible)
            if FLUSH_BEFORE_APPEND in kind.fences:
                if _CLEAN not in state.flush.values():
                    self._report(
                        lineno, "fence-flush",
                        f"{kind.name} requires flush-before-append: no "
                        "store.flush_all() reaches this append on every path "
                        "(or the flushed store was written again in between) "
                        "— the data the record covers could be volatile when "
                        "it commits")
            if RECORD_THEN_APPLY in kind.fences and state.topo_mutated:
                self._report(
                    lineno, "fence-apply",
                    f"{kind.name} requires record-then-apply: topology state "
                    "(TOPOLOGY_ATTRS) is mutated before the append on some "
                    "path, so a crash at the record site would leave applied "
                    "but unjournaled state")
            if TRUNCATE_AFTER_APPEND in kind.fences:
                state.truncate_ok = True
        if stepped_kinds:
            if not next_feasible:
                names = sorted({k.name for k in stepped_kinds})
                self._report(
                    lineno, "order",
                    f"append of {'/'.join(names)} is infeasible here: no "
                    "automaton state consistent with the records already "
                    f"appended in {self.qualname} has a transition for it")
                # resynchronize so one bug does not cascade down the function
                next_feasible = frozenset(
                    to for k in stepped_kinds for _frm, to in k.transitions)
            state.feasible = next_feasible

    # --------------------------------------------------------- statement walk
    def _exec_expr_calls(self, node: ast.AST, state: _State) -> None:
        for call in _iter_calls(node):
            self._apply_call(call, state)

    def _exec_stmt(self, stmt: ast.stmt, state: _State) -> _State:
        if not state.live:
            return state
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Import, ast.ImportFrom,
                             ast.Global, ast.Nonlocal, ast.Pass)):
            return state
        if isinstance(stmt, ast.If):
            self._exec_expr_calls(stmt.test, state)
            then = self._exec_stmts(stmt.body, state.copy())
            other = self._exec_stmts(stmt.orelse, state.copy())
            return _join(then, other)
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            return self._exec_loop(stmt, state)
        if isinstance(stmt, ast.Try):
            body = self._exec_stmts(stmt.body, state.copy())
            merged = body
            for handler in stmt.handlers:
                # a handler can enter from any point in the body: start from
                # the pre-body state with may-facts from the body folded in
                h_in = state.copy()
                h_in.topo_mutated = state.topo_mutated or body.topo_mutated
                merged = _join(merged, self._exec_stmts(handler.body, h_in))
            if stmt.orelse:
                merged = _join(merged,
                               self._exec_stmts(stmt.orelse, body.copy()))
            if stmt.finalbody:
                merged = self._exec_stmts(stmt.finalbody, merged)
            return merged
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._exec_expr_calls(item.context_expr, state)
            return self._exec_stmts(stmt.body, state)
        if isinstance(stmt, (ast.Return, ast.Raise)):
            self._exec_expr_calls(stmt, state)
            state.live = False
            return state
        if isinstance(stmt, (ast.Break, ast.Continue)):
            state.live = False
            return state
        # straight-line statements: evaluate calls, then apply bindings
        self._exec_expr_calls(stmt, state)
        if isinstance(stmt, ast.Assign):
            for target in stmt.targets:
                self._bind(target, stmt.value, state)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._bind(stmt.target, stmt.value, state)
        elif isinstance(stmt, ast.AugAssign):
            if _is_self_topo_target(stmt.target):
                state.topo_mutated = True
            elif isinstance(stmt.target, ast.Name):
                state.defs[stmt.target.id] = None
        elif isinstance(stmt, ast.Delete):
            for target in stmt.targets:
                if _is_self_topo_target(target):
                    state.topo_mutated = True
        return state

    def _bind(self, target: ast.AST, value: ast.AST, state: _State) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, ast.Constant(value=None), state)
            return
        if _is_self_topo_target(target):
            state.topo_mutated = True
            return
        if isinstance(target, ast.Name):
            state.flush.pop(target.id, None)
            if isinstance(value, ast.Dict):
                state.defs[target.id] = frozenset([_dict_fact(value)])
            else:
                state.defs[target.id] = None
            return
        # rec["key"] = ...: extend every reaching dict fact of rec
        if (isinstance(target, ast.Subscript)
                and isinstance(target.value, ast.Name)):
            var = target.value.id
            facts = state.defs.get(var)
            if not facts:
                return
            key = target.slice
            if isinstance(key, ast.Constant) and isinstance(key.value, str):
                state.defs[var] = frozenset(
                    dataclasses.replace(f, keys=f.keys | {key.value})
                    for f in facts)
            else:
                state.defs[var] = frozenset(
                    dataclasses.replace(f, open=True) for f in facts)

    def _exec_loop(self, stmt, state: _State) -> _State:
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._exec_expr_calls(stmt.iter, state)
            # the loop variable shadows any outer binding of the same name
            self._bind(stmt.target, ast.Constant(value=None), state)
            body_has_flush = any(
                isinstance(c.func, ast.Attribute)
                and c.func.attr in _FLUSH_METHODS
                for s in stmt.body for c in _iter_calls(s))
            body_writes = any(
                isinstance(c.func, ast.Attribute)
                and (c.func.attr in _DIRTYING_METHODS
                     or (c.func.attr == "append"
                         and _is_metalog_recv(c.func.value)))
                for s in stmt.body for c in _iter_calls(s))
        else:
            self._exec_expr_calls(stmt.test, state)
            body_has_flush = body_writes = False
        out = state.copy()
        for _ in range(3):  # small fixpoint: joins are monotone in practice
            prev = out.key()
            after = self._exec_stmts(stmt.body, out.copy())
            out = _join(out, after)
            if out.key() == prev:
                break
        if stmt.orelse:
            out = self._exec_stmts(stmt.orelse, out)
        # a loop that only flushes (``for s in stores: s.flush_all()``)
        # leaves the whole fleet clean even though its loop variable has no
        # stable identity across the must-join with the zero-iteration path
        if body_has_flush and not body_writes:
            out.flush[f"__fleet@{stmt.lineno}"] = _CLEAN
        return out

    def _exec_stmts(self, stmts, state: _State) -> _State:
        for s in stmts:
            state = self._exec_stmt(s, state)
        return state

    def run(self, fn) -> None:
        state = _State(self.spec.initial_states())
        self._exec_stmts(fn.body, state)


# ------------------------------------------------------------------ module API
def _functions(tree: ast.Module):
    """(qualname, node) for every function/method, outermost first."""
    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield f"{prefix}{child.name}", child
                yield from walk(child, f"{prefix}{child.name}.")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
    yield from walk(tree, "")


def check_source(source: str, path: str = "<source>",
                 spec: ProtocolSpec = WAL_SPEC):
    """Check one module's source; returns ``(violations, sites)``."""
    tree = ast.parse(source, filename=path)
    violations: list[Violation] = []
    sites: list[AppendSite] = []
    for qualname, fn in _functions(tree):
        checker = _FunctionChecker(path, qualname, spec)
        checker.run(fn)
        violations.extend(checker.violations.values())
        sites.extend(checker.sites.values())
    violations.sort(key=lambda v: (v.lineno, v.rule))
    sites.sort(key=lambda s: s.lineno)
    return violations, sites


def default_targets() -> list[pathlib.Path]:
    """The protocol's implementation files (the spec's enforcement scope)."""
    src = pathlib.Path(__file__).resolve().parents[3]
    return [
        src / "repro/core/metalog.py",
        src / "repro/core/range_shard.py",
        src / "repro/core/shard.py",
        src / "repro/core/store.py",
        src / "repro/elastic/remap.py",
    ]


def check_paths(paths=None, *, spec: ProtocolSpec = WAL_SPEC,
                require_complete: bool = False) -> list[Violation]:
    """Check files against the spec; with ``require_complete``, also demand
    that every spec kind is appended somewhere in the analyzed tree."""
    paths = default_targets() if paths is None else list(paths)
    violations: list[Violation] = []
    appended: set[str] = set()
    for p in paths:
        p = pathlib.Path(p)
        v, sites = check_source(p.read_text(encoding="utf-8"), str(p),
                                spec=spec)
        violations.extend(v)
        appended |= {s.kind for s in sites if s.kind}
    if require_complete:
        missing = spec.kind_names - appended
        if missing:
            violations.append(Violation(
                str(paths[0]), 0, "unappended-kind",
                f"spec kind(s) {sorted(missing)} are declared in "
                f"{spec.name} but never appended in the analyzed tree — "
                "dead spec entries hide protocol drift"))
    return violations


def append_site_inventory(paths=None, *,
                          spec: ProtocolSpec = WAL_SPEC) -> list[AppendSite]:
    """Every statically resolved append site in ``paths`` (default: the
    protocol implementation files).  The crash-point harness derives its
    required kind coverage from this inventory — see
    ``tests/test_crashpoints.py::test_spec_derived_crash_coverage``."""
    paths = default_targets() if paths is None else list(paths)
    sites: list[AppendSite] = []
    for p in paths:
        p = pathlib.Path(p)
        _v, s = check_source(p.read_text(encoding="utf-8"), str(p), spec=spec)
        sites.extend(s)
    return sites


__all__ = [
    "AppendSite", "PROTOCOL_RULES", "append_site_inventory", "check_paths",
    "check_source", "default_targets",
]
