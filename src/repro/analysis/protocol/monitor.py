"""Runtime WAL-protocol monitor: the automaton replayed over live streams.

Behind ``EngineConfig(debug_checks=True)`` (or ``REPRO_DEBUG_CHECKS``), a
:class:`ProtocolMonitor` is attached to the engine's shard-metadata WAL and
validates every record against :data:`~.spec.WAL_SPEC` as it is appended —
payload schema, the ordering automaton refined with concrete leg tracking
(which rescale leg a ``checkpoint``/``finish`` names, whether
``rescale_finish`` really closes a drained rescale), and the live
flush-before-append fence (the destination store's logs must hold zero
unflushed bytes at the instant a ``checkpoint``/``finish``/``gc_reclaim``/
``snapshot`` record commits).  Recovery replay is validated too: the monitor
wraps ``MetadataLog.replay`` and re-runs the full durable stream through a
fresh automaton, so a corrupted or reordered stream fails at recovery, not
at the next silent divergence.

A violation raises :class:`ProtocolViolation` carrying the offending record
*window* (the last few records plus the offender) so CI logs show the
context, not just the symptom.

Zero-overhead-off discipline (mirrors :mod:`repro.analysis.racecheck`): this
module is imported only from the ``debug_checks`` branch of
``Engine.__init__``; all instrumentation is per-instance method shims, so
with checks off nothing here loads and results/stats are byte-identical —
held by a subprocess-pinned test in ``tests/test_protocol_monitor.py``.
"""
from __future__ import annotations

import collections

from .spec import FLUSH_BEFORE_APPEND, ProtocolSpec, WAL_SPEC

#: per-store value/WAL logs whose unflushed bytes the live fence inspects
_STORE_LOGS = ("small_log", "medium_log", "large_log", "short_log")


class ProtocolViolation(RuntimeError):
    """A WAL record stream diverged from the protocol spec.

    ``window`` holds the trailing records up to and including the offender;
    ``record`` is the offender itself.
    """

    def __init__(self, message: str, window, record):
        self.window = list(window)
        self.record = record
        tail = "".join(f"\n    [{i - len(self.window) + 1:+d}] {r!r}"
                       for i, r in enumerate(self.window))
        super().__init__(f"{message}; offending record window (offender last):"
                         f"{tail}")


def store_is_clean(store) -> bool:
    """Every log of a backing store group-committed (no unflushed bytes)."""
    for name in _STORE_LOGS:
        log = getattr(store, name, None)
        if log is not None and getattr(log, "_unflushed", 0):
            return False
    return True


class ProtocolMonitor:
    """Stream validator for one metadata WAL.

    Call :meth:`observe` per appended record (``live=True`` enables the
    flush-fence, which needs the attached store fleet), or
    :meth:`validate_stream` over a full durable stream.  State is concrete:
    the in-flight legacy leg or rescale leg set is tracked from record
    payloads, exactly mirroring what recovery replay would reconstruct.
    """

    def __init__(self, spec: ProtocolSpec = WAL_SPEC, store_resolver=None,
                 window: int = 6):
        self.spec = spec
        self._resolver = store_resolver
        self._window = collections.deque(maxlen=window)
        self.records_checked = 0
        self.replays_checked = 0
        self.reset()

    def reset(self) -> None:
        self._started = False
        self._legacy = None    # dst ref of the single legacy split/merge leg
        self._rescale = None   # {"scheme": str, "legs": {leg_id: dst_ref}}
        self._window.clear()

    # ----------------------------------------------------------------- errors
    def _fail(self, message: str, record) -> None:
        raise ProtocolViolation(message, self._window, record)

    # ---------------------------------------------------------------- observe
    def observe(self, record, *, live: bool = False) -> None:
        self._window.append(record)
        self.records_checked += 1
        kind_name = record.get("kind") if isinstance(record, dict) else None
        if not isinstance(kind_name, str) or kind_name not in self.spec:
            self._fail(f"record kind {kind_name!r} is not declared in the "
                       f"{self.spec.name} spec", record)
        kind = self.spec[kind_name]
        keys = frozenset(record)
        missing = kind.required - keys
        unknown = keys - kind.payload_keys
        if missing or unknown:
            self._fail(
                f"{kind_name} payload mismatch: missing {sorted(missing)}, "
                f"undeclared {sorted(unknown)}", record)
        if not self._started:
            if not kind.stream_start:
                self._fail(f"{kind_name} cannot open a WAL stream (only "
                           f"{sorted(self.spec.stream_start_kinds())} can)",
                           record)
            self._started = True
        elif kind_name == "init":
            self._fail("init record mid-stream: genesis may only be the "
                       "first record", record)
        dst_ref = getattr(self, f"_on_{kind_name}")(record)
        if live and self._resolver is not None \
                and FLUSH_BEFORE_APPEND in kind.fences:
            for store in self._resolver(kind_name, record, dst_ref):
                if not store_is_clean(store):
                    self._fail(
                        f"flush-before-append fence broken: {kind_name} "
                        "committed while the covered store still holds "
                        "unflushed log bytes — a crash now would lose data "
                        "the durable record already points at", record)

    def validate_stream(self, records) -> int:
        """Run a full stream (e.g. ``metalog.replay()``) from a fresh state;
        returns the number of records validated."""
        self.reset()
        n = 0
        for rec in records:
            self.observe(rec, live=False)
            n += 1
        return n

    # ----------------------------------------------------- per-kind handlers
    def _on_init(self, record):
        return None

    def _on_snapshot(self, record):
        # a snapshot is a full-state reset: adopt its topology authoritatively
        # (this is exactly what recovery replay does with it)
        m = record.get("migration")
        self._legacy = None if m is None else m["dst_id"]
        r = record.get("rescale")
        if r is None:
            self._rescale = None
        else:
            if self._legacy is not None:
                self._fail("snapshot carries both a legacy migration and a "
                           "rescale: the coordinator never runs both", record)
            self._rescale = {
                "scheme": "range",
                "legs": {leg["dst_id"]: leg["dst_id"] for leg in r["legs"]},
            }
        return None

    def _on_cutoff(self, record):
        return None

    def _on_gc_reclaim(self, record):
        return None

    def _start_leg(self, record):
        if self._legacy is not None:
            self._fail(f"{record['kind']} while a legacy migration leg is "
                       "already in flight (the coordinator drains first)",
                       record)
        if self._rescale is not None:
            self._fail(f"{record['kind']} while a rescale is in flight "
                       "(legacy legs and rescales are mutually exclusive)",
                       record)
        self._legacy = record["dst"]
        return None

    _on_split_start = _start_leg
    _on_merge_start = _start_leg

    def _on_rescale_start(self, record):
        if self._legacy is not None or self._rescale is not None:
            self._fail("rescale_start while a migration is already in flight",
                       record)
        scheme, legs = record.get("scheme"), record.get("legs")
        leg_map = {}
        try:
            if scheme == "range":
                # rows: [kind, src, dst, lo, hi, epoch] — legs keyed by dst id
                leg_map = {row[2]: row[2] for row in legs}
            elif scheme == "hash":
                # rows: [src, dst, epoch] — legs keyed by leg index
                leg_map = {i: row[1] for i, row in enumerate(legs)}
            else:
                self._fail(f"rescale_start with unknown scheme {scheme!r}",
                           record)
        except (TypeError, IndexError):
            self._fail(f"rescale_start with malformed legs {legs!r}", record)
        self._rescale = {"scheme": scheme, "legs": leg_map}
        return None

    def _resolve_leg(self, record):
        if "leg" in record:
            if self._rescale is None:
                self._fail(f"{record['kind']} names rescale leg "
                           f"{record['leg']!r} but no rescale is in flight",
                           record)
            legs = self._rescale["legs"]
            if record["leg"] not in legs:
                self._fail(
                    f"{record['kind']} names leg {record['leg']!r} which is "
                    f"not active (active: {sorted(legs)})", record)
            return record["leg"], legs[record["leg"]]
        if self._legacy is None:
            self._fail(f"{record['kind']} with no migration leg in flight",
                       record)
        return None, self._legacy

    def _on_checkpoint(self, record):
        _leg, dst_ref = self._resolve_leg(record)
        return dst_ref

    def _on_finish(self, record):
        leg, dst_ref = self._resolve_leg(record)
        if leg is None:
            self._legacy = None
        else:
            del self._rescale["legs"][leg]
        return dst_ref

    def _on_rescale_finish(self, record):
        if self._rescale is None:
            self._fail("rescale_finish with no rescale in flight", record)
        if self._rescale["legs"]:
            self._fail(
                f"rescale_finish with {len(self._rescale['legs'])} leg(s) "
                f"still active ({sorted(self._rescale['legs'])})", record)
        self._rescale = None
        return None


# -------------------------------------------------------------- instrumentation
def _make_resolver(store):
    """Map a fenced record to the backing store(s) that must be clean."""

    def resolve(kind: str, record, dst_ref):
        if kind == "snapshot":
            return list(store._all_stores())
        by_id = getattr(store, "_by_id", None)
        if kind == "gc_reclaim":
            if by_id is None:
                return []
            s = by_id.get(record.get("shard"))
            return [] if s is None else [s]
        if kind in ("checkpoint", "finish") and dst_ref is not None:
            if by_id is not None:  # range: dst_ref is a registry shard id
                s = by_id.get(dst_ref)
                return [] if s is None else [s]
            shards = getattr(store, "shards", None)  # hash: a slot index
            if (shards is not None and isinstance(dst_ref, int)
                    and 0 <= dst_ref < len(shards)):
                return [shards[dst_ref]]
        return []

    return resolve


def _wrap_metalog(metalog, monitor: ProtocolMonitor) -> None:
    """Per-instance shims (racecheck idiom): validate the already-durable
    stream, then check each future append and each recovery replay."""
    monitor.validate_stream(metalog.replay())
    orig_append = metalog.append
    orig_replay = metalog.replay

    def checked_append(record):
        # the crash-injection / single-writer paths raise *inside* the real
        # append, before the record is durable — only committed records are
        # fed to the automaton (exactly the stream recovery would see)
        idx = orig_append(record)
        monitor.observe(record, live=True)
        return idx

    def checked_replay():
        records = orig_replay()
        # recovery-path validation runs the full durable stream through a
        # fresh automaton so it cannot disturb the live monitor's state
        ProtocolMonitor(monitor.spec).validate_stream(records)
        monitor.replays_checked += 1
        return records

    metalog.append = checked_append
    metalog.replay = checked_replay
    metalog._protocol_monitored = True


def attach_store(store, spec: ProtocolSpec = WAL_SPEC):
    """Attach a monitor to a sharded front-end's metadata WAL.

    The range front-end's metalog exists from construction (its ``init``
    record is validated retroactively); the hash front-end creates its
    metalog lazily at the first rescale, so ``_ensure_metalog`` is shimmed
    to wrap the log the moment it exists.  Returns the monitor, or ``None``
    for stores without a metadata WAL (the bare ``ParallaxStore``).
    """
    monitor = ProtocolMonitor(spec, store_resolver=_make_resolver(store))
    metalog = getattr(store, "metalog", None)
    if metalog is not None:
        _wrap_metalog(metalog, monitor)
        return monitor
    if hasattr(store, "_ensure_metalog"):
        orig_ensure = store._ensure_metalog

        def ensure_and_wrap():
            orig_ensure()
            ml = store.metalog
            if ml is not None and not getattr(ml, "_protocol_monitored", False):
                _wrap_metalog(ml, monitor)

        store._ensure_metalog = ensure_and_wrap
        return monitor
    return None


def attach_engine(engine):
    """Attach to an :class:`repro.api.Engine`'s store; returns ``None``
    when the store has no metadata WAL (the bare serial combo)."""
    return attach_store(engine._store)


__all__ = [
    "ProtocolMonitor", "ProtocolViolation", "attach_engine", "attach_store",
    "store_is_clean",
]
