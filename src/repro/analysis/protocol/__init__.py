"""Spec-driven WAL protocol checking: one spec, three enforcement layers.

The metadata-WAL protocol (``docs/durability.md``) is declared once, in
:mod:`repro.analysis.protocol.spec` (:data:`~repro.analysis.protocol.spec.WAL_SPEC`:
record kinds, payload schemas, the legal ordering automaton, per-kind
fences), and enforced three ways:

* :mod:`~repro.analysis.protocol.static_check` — an ``ast`` CFG/dataflow
  pass proving the *implementation* conforms (every append site resolved,
  ordered, fenced, schema-checked); CLI: ``scripts/check_protocol.py``, a CI
  hard gate with a planted-fixture self-test.
* :mod:`~repro.analysis.protocol.monitor` — a runtime stream validator
  proving each *run* conforms, behind ``EngineConfig(debug_checks=True)``;
  never imported when checks are off.
* the crash harness (``tests/test_crashpoints.py``) derives its required
  record-kind coverage from the spec's append-site inventory, so a new kind
  without crash enumeration is a test failure, not an oversight.

Import discipline: this package (like :mod:`repro.analysis` itself) is never
imported by the engine unless a checker is switched on — keep submodule
imports lazy.
"""

__all__ = ["monitor", "spec", "static_check"]
