"""The metadata-WAL protocol as a declarative, checkable specification.

Every durability argument in ``docs/durability.md`` is phrased over the WAL
record stream: which kinds exist, what payload each carries, what order they
may appear in, and which fence (a flush, a deferred apply, a deferred
truncate) brackets each append.  Until now those rules lived in three
disconnected places — per-function ``# contract:`` annotations, the replay
switch statements, and the hand-written crash scenarios — so a new record
kind could be wired into the code while every checker stayed silent.  This
module is the single source of truth the three enforcement layers derive
from:

* :mod:`repro.analysis.protocol.static_check` proves the *code* conforms —
  every ``metalog.append`` site resolved, ordered, fenced, and schema-checked
  against :data:`WAL_SPEC` (CI hard gate via ``scripts/check_protocol.py``);
* :mod:`repro.analysis.protocol.monitor` proves each *run* conforms — the
  automaton replayed over live appends and recovery replay when
  ``EngineConfig(debug_checks=True)``;
* ``tests/test_crashpoints.py`` proves the *crash sweep* is complete — every
  non-genesis kind in the spec must appear in some scenario's site list.

The automaton is deliberately abstract: four coordinator states
(:data:`START` pre-genesis, :data:`IDLE` quiescent, :data:`LEG` one legacy
split/merge leg in flight, :data:`RESCALE` a multi-leg rescale in flight)
and per-kind transitions between them.  The monitor refines it with concrete
payload tracking (which leg, which destination shard); the static pass runs
it over feasible-state *sets* so intraprocedural paths that cannot know the
caller's state are judged against every state they could legally start in.
"""
from __future__ import annotations

import dataclasses

# ------------------------------------------------------------ abstract states
START = "START"      # no record durable yet (pre-genesis / lazy hash metalog)
IDLE = "IDLE"        # topology stable, no migration leg in flight
LEG = "LEG"          # exactly one legacy split/merge leg draining
RESCALE = "RESCALE"  # a multi-leg elastic rescale draining

STATES = (START, IDLE, LEG, RESCALE)

# -------------------------------------------------------------------- fences
#: the data a record covers must be durable (``flush_all``) before the append
FLUSH_BEFORE_APPEND = "flush-before-append"
#: the topology mutation the record describes must *follow* the append
RECORD_THEN_APPLY = "record-then-apply"
#: WAL truncation may only follow this record's append (rename-before-truncate)
TRUNCATE_AFTER_APPEND = "truncate-after-append"

FENCES = (FLUSH_BEFORE_APPEND, RECORD_THEN_APPLY, TRUNCATE_AFTER_APPEND)


@dataclasses.dataclass(frozen=True)
class RecordKind:
    """One WAL record kind: payload schema, automaton edges, fences.

    ``transitions`` is the kind's edge set over the abstract states — a
    ``(from, to)`` pair per legal occurrence.  ``required`` keys must be
    present in every record of this kind; ``optional`` keys may be; anything
    else (beyond ``"kind"`` itself) is a schema violation.  ``stream_start``
    marks kinds that may legally open a WAL stream: ``init`` at genesis,
    ``snapshot`` after truncation rooted the stream at it, ``rescale_start``
    on the hash front-end's lazily created metalog.  ``genesis`` exempts the
    kind from crash-sweep coverage (a crash at the construction-time record
    precedes all data-path work — there is no window to cover).
    """

    name: str
    required: frozenset
    optional: frozenset
    transitions: tuple
    fences: frozenset = frozenset()
    stream_start: bool = False
    genesis: bool = False
    doc: str = ""

    def step(self, states: frozenset) -> frozenset:
        """Automaton step over a feasible-state set (empty = infeasible)."""
        return frozenset(to for frm, to in self.transitions if frm in states)

    @property
    def payload_keys(self) -> frozenset:
        return self.required | self.optional | {"kind"}


class ProtocolSpec:
    """A named collection of :class:`RecordKind` forming one automaton."""

    def __init__(self, name: str, kinds: tuple):
        self.name = name
        self.kinds = {k.name: k for k in kinds}
        for k in kinds:
            for frm, to in k.transitions:
                if frm not in STATES or to not in STATES:
                    raise ValueError(f"{name}/{k.name}: unknown state in "
                                     f"transition {(frm, to)!r}")
            bad = k.fences - set(FENCES)
            if bad:
                raise ValueError(f"{name}/{k.name}: unknown fence(s) {sorted(bad)}")

    def __contains__(self, kind: str) -> bool:
        return kind in self.kinds

    def __getitem__(self, kind: str) -> RecordKind:
        return self.kinds[kind]

    @property
    def kind_names(self) -> frozenset:
        return frozenset(self.kinds)

    def stream_start_kinds(self) -> frozenset:
        return frozenset(n for n, k in self.kinds.items() if k.stream_start)

    def crash_coverage_kinds(self) -> frozenset:
        """Kinds the crash-point sweep must exercise (non-genesis)."""
        return frozenset(n for n, k in self.kinds.items() if not k.genesis)

    def initial_states(self) -> frozenset:
        """Feasible-state set for code whose entry state is unknown."""
        return frozenset(STATES)

    def step(self, states: frozenset, kind: str) -> frozenset:
        return self.kinds[kind].step(states)


def _k(name, required=(), optional=(), transitions=(), fences=(),
       stream_start=False, genesis=False, doc=""):
    return RecordKind(
        name=name, required=frozenset(required), optional=frozenset(optional),
        transitions=tuple(transitions), fences=frozenset(fences),
        stream_start=stream_start, genesis=genesis, doc=doc)


#: The shard-metadata WAL protocol (see the record table in
#: ``docs/durability.md``, whose rows map 1:1 onto these entries).
WAL_SPEC = ProtocolSpec("shard-metadata-wal", (
    _k("init",
       required=("boundaries", "shards"),
       transitions=((START, IDLE),),
       stream_start=True, genesis=True,
       doc="front-end construction: the genesis topology; only ever the "
           "first record of a stream"),
    _k("snapshot",
       required=("boundaries", "shards", "next_shard_id", "migration",
                 "cutoffs"),
       optional=("rescale",),
       # a full-state reset: legal in any live state, preserving it; also a
       # legal stream root once truncation dropped the prefix it replaces
       transitions=((START, IDLE), (IDLE, IDLE), (LEG, LEG),
                    (RESCALE, RESCALE)),
       fences=(FLUSH_BEFORE_APPEND, TRUNCATE_AFTER_APPEND),
       stream_start=True,
       doc="the whole topology in one self-contained record; every shard "
           "store flushed first, WAL truncation only after it commits"),
    _k("cutoff",
       required=("shard", "t_sm", "t_ml"),
       transitions=((IDLE, IDLE), (LEG, LEG), (RESCALE, RESCALE)),
       fences=(RECORD_THEN_APPLY,),
       doc="adaptive lifetime-cutoff cutover, journaled before the shard "
           "installs the policy; replay applies the last record per shard"),
    _k("gc_reclaim",
       required=("shard", "log", "segment"),
       transitions=((IDLE, IDLE), (LEG, LEG), (RESCALE, RESCALE)),
       fences=(FLUSH_BEFORE_APPEND,),
       doc="GC fence between relocation durability and segment reclaim; a "
           "crash here leaves both copies and newest-LSN replay picks one"),
    _k("split_start",
       required=("src", "dst", "at", "hi", "epoch"),
       transitions=((IDLE, LEG),),
       fences=(RECORD_THEN_APPLY,),
       doc="legacy single-leg split: the record is the boundary flip"),
    _k("merge_start",
       required=("src", "dst", "lo", "hi", "epoch"),
       transitions=((IDLE, LEG),),
       fences=(RECORD_THEN_APPLY,),
       doc="legacy single-leg merge: the record drops the boundary"),
    _k("rescale_start",
       required=("scheme", "from", "to", "legs"),
       optional=("boundaries", "shards", "budget"),
       # START -> RESCALE: the hash front-end creates its metalog lazily at
       # the first rescale, so this kind can legally open a stream
       transitions=((START, RESCALE), (IDLE, RESCALE)),
       fences=(RECORD_THEN_APPLY,),
       stream_start=True,
       doc="elastic N->M rescale: full post-rescale topology plus every "
           "leg in one append, before the routing flip"),
    _k("checkpoint",
       required=("cursor",),
       optional=("leg",),
       transitions=((LEG, LEG), (RESCALE, RESCALE)),
       fences=(FLUSH_BEFORE_APPEND, RECORD_THEN_APPLY),
       doc="per-batch ownership flip: the destination's logs are flushed "
           "before the record; `leg` names one of a rescale's legs"),
    _k("finish",
       required=(),
       optional=("leg",),
       transitions=((LEG, IDLE), (RESCALE, RESCALE)),
       fences=(FLUSH_BEFORE_APPEND, RECORD_THEN_APPLY),
       doc="a migration leg drained (a merge's source retires here); under "
           "a rescale the coordinator stays live until rescale_finish"),
    _k("rescale_finish",
       required=(),
       transitions=((RESCALE, IDLE),),
       fences=(RECORD_THEN_APPLY,),
       doc="the last rescale leg drained; the coordinator retires"),
))


__all__ = [
    "FENCES", "FLUSH_BEFORE_APPEND", "IDLE", "LEG", "RECORD_THEN_APPLY",
    "RESCALE", "START", "STATES", "TRUNCATE_AFTER_APPEND", "ProtocolSpec",
    "RecordKind", "WAL_SPEC",
]
