"""``# contract:`` annotation parsing shared by the lint rules.

Annotations are ordinary comments so they cost nothing at runtime and need no
imports in the annotated modules.  The grammar:

    # contract: <spec>[, <spec>...]

where each ``<spec>`` is a marker name, optionally with a parenthesized
argument.  Two kinds of marker exist:

* **Function-level** markers describe the whole enclosing function and are
  valid on the ``def`` line, a decorator line, the line immediately above the
  ``def``/first decorator, or any line between the ``def`` and the first body
  statement (i.e. alongside the docstring):

  - ``coordinator-only`` — runs only on the coordinator thread (the single
    submitter); may create locks and mutate front-end counters unlocked.
  - ``record-then-apply`` — every topology mutation must follow the
    function's first ``metalog.append`` record call.
  - ``flush-before-record`` — the function's first ``flush``/``flush_all``
    call must precede its first durable-record write.
  - ``rename-before-truncate`` — the function's first ``.truncate(...)``
    call must follow its first replacement write (``metalog.append`` /
    ``os.replace`` / ``os.rename``): history may only be dropped after the
    state it summarized has been durably republished.
  - ``single-threaded`` — a modeled hot path; must stay lock-free.

* **Line-level**: ``exempt(<reason>)`` suppresses every violation reported on
  its own line and on the next line.  An empty reason is itself a violation —
  suppressions must be justified in place.

Unknown marker names are reported (rule ``contract-annotation``) so a typo'd
annotation cannot silently disable a rule.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize

FUNCTION_MARKERS = frozenset(
    ["coordinator-only", "record-then-apply", "flush-before-record",
     "rename-before-truncate", "single-threaded"]
)
LINE_MARKERS = frozenset(["exempt"])
KNOWN_MARKERS = FUNCTION_MARKERS | LINE_MARKERS

_CONTRACT_RE = re.compile(r"#\s*contract:\s*(?P<specs>.+?)\s*$")
_SPEC_RE = re.compile(r"^(?P<name>[a-z][a-z-]*)(?:\((?P<arg>[^()]*)\))?$")


@dataclasses.dataclass(frozen=True)
class Annotation:
    """One parsed ``# contract:`` spec at a source line."""

    name: str
    arg: str | None
    lineno: int
    raw: str


@dataclasses.dataclass(frozen=True)
class Problem:
    """An annotation-hygiene defect (unknown marker, unjustified exempt)."""

    lineno: int
    message: str


def _parse_comments(source: str) -> tuple[list[Annotation], list[Problem]]:
    annotations: list[Annotation] = []
    problems: list[Problem] = []
    tokens = tokenize.generate_tokens(io.StringIO(source).readline)
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _CONTRACT_RE.search(tok.string)
        if m is None:
            continue
        lineno = tok.start[0]
        for raw in m.group("specs").split(","):
            raw = raw.strip()
            if not raw:
                continue
            sm = _SPEC_RE.match(raw)
            if sm is None:
                problems.append(Problem(lineno, f"unparseable contract spec {raw!r}"))
                continue
            name, arg = sm.group("name"), sm.group("arg")
            if name not in KNOWN_MARKERS:
                problems.append(
                    Problem(lineno, f"unknown contract marker {name!r} "
                                    f"(known: {', '.join(sorted(KNOWN_MARKERS))})")
                )
                continue
            if name == "exempt" and not (arg or "").strip():
                problems.append(
                    Problem(lineno, "exempt needs a justification: "
                                    "# contract: exempt(<reason>)")
                )
                continue
            annotations.append(Annotation(name, arg, lineno, raw))
    return annotations, problems


class ModuleContracts:
    """One source file's AST plus its parsed contract annotations.

    Provides the two lookups the rules need: the marker set of a function
    (:meth:`markers_of`, honoring lexical nesting via :meth:`has_marker`) and
    whether a given line is covered by an ``exempt`` (:meth:`exempted`).
    """

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.annotations, self.problems = _parse_comments(source)
        self._by_line: dict[int, list[Annotation]] = {}
        for a in self.annotations:
            self._by_line.setdefault(a.lineno, []).append(a)
        self.exempt_lines: set[int] = set()
        for a in self.annotations:
            if a.name == "exempt":
                self.exempt_lines.update((a.lineno, a.lineno + 1))
        # innermost enclosing function per AST node, and marker set per function
        self.enclosing: dict[ast.AST, ast.AST | None] = {}
        self.functions: list[ast.AST] = []
        self._markers: dict[ast.AST, frozenset[str]] = {}
        self._walk(self.tree, None)

    # ------------------------------------------------------------- structure
    def _walk(self, node: ast.AST, func: ast.AST | None) -> None:
        for child in ast.iter_child_nodes(node):
            self.enclosing[child] = func
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.append(child)
                self._markers[child] = self._collect_markers(child)
                self._walk(child, child)
            else:
                self._walk(child, func)

    def _collect_markers(self, fn: ast.AST) -> frozenset[str]:
        first = min([d.lineno for d in fn.decorator_list] + [fn.lineno])
        last = fn.body[0].lineno - 1 if fn.body else fn.lineno
        lines = set(range(first - 1, last + 1))
        found = set()
        for lineno in lines:
            for a in self._by_line.get(lineno, ()):
                if a.name in FUNCTION_MARKERS:
                    found.add(a.name)
        return frozenset(found)

    # --------------------------------------------------------------- queries
    def markers_of(self, fn: ast.AST) -> frozenset[str]:
        return self._markers.get(fn, frozenset())

    def has_marker(self, node: ast.AST, marker: str) -> bool:
        """True if ``node``'s enclosing function — or any outer function it is
        nested in — carries ``marker``."""
        fn = self.enclosing.get(node)
        while fn is not None:
            if marker in self._markers.get(fn, frozenset()):
                return True
            fn = self.enclosing.get(fn)
        return False

    def exempted(self, lineno: int) -> bool:
        return lineno in self.exempt_lines

    def functions_with(self, marker: str):
        for fn in self.functions:
            if marker in self._markers[fn]:
                yield fn


__all__ = [
    "Annotation",
    "FUNCTION_MARKERS",
    "KNOWN_MARKERS",
    "LINE_MARKERS",
    "ModuleContracts",
    "Problem",
]
