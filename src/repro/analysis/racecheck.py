"""Eraser-style dynamic lockset race detector for the async engine.

The static linter (:mod:`repro.analysis.lint`) proves the *source* honors the
concurrency contracts; this module checks the *execution*: every access to
shared engine state records the set of locks the accessing thread holds, and
the classic Eraser lockset algorithm [Savage et al., SOSP'97] refines a
per-variable candidate set — a write-shared variable whose candidate set goes
empty was reachable by two threads with no common lock, i.e. a data race the
schedule merely happened not to lose.

Adaptation for the executor's barrier discipline: sequence points
(:meth:`ShardExecutor.drain` / ``exclusive``) are happens-before barriers —
the coordinator provably cannot overlap workers across one.  Plain Eraser
would flag the coordinator's unlocked maintenance access after workers
touched the same state (a notorious Eraser false-positive class on
barrier-synchronized code), so :meth:`LocksetChecker.barrier` resets all
variable states when a drain completes; within a barrier window the pure
lockset rule applies.  The single-coordinator submission contract is checked
directly: every executor submission surface records the first submitting
thread and reports any other.

Instrumentation is strictly *per instance* — wrapped locks
(:class:`ChecksafeLock`), bound-method shims on the backing stores, and a
dynamic subclass swap for the front-end counter attributes.  Nothing in this
module is imported, and no wrapper exists on any object, unless
``EngineConfig(debug_checks=True)`` (or ``REPRO_DEBUG_CHECKS=1``) switched it
on — the off path is provably zero-overhead
(``tests/test_analysis_racecheck.py`` counts calls into this file under
``sys.setprofile`` to hold that line).
"""
from __future__ import annotations

import dataclasses
import threading

from repro.core.metalog import MetadataLog
from repro.core.shard import BaseShardedStore
from repro.core.store import ParallaxStore

_tls = threading.local()


def _held() -> set:
    held = getattr(_tls, "held", None)
    if held is None:
        held = _tls.held = set()
    return held


class ChecksafeLock:
    """A ``threading.Lock`` wrapper that tracks itself in the holding thread's
    lockset.  API-compatible with the subset the engine uses (``acquire`` with
    ``blocking``/``timeout``, ``release``, context manager, ``locked``)."""

    __slots__ = ("_lock", "name")

    def __init__(self, name: str, lock: threading.Lock | None = None):
        self._lock = lock if lock is not None else threading.Lock()
        self.name = name

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            _held().add(self)
        return ok

    def release(self) -> None:
        _held().discard(self)
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> "ChecksafeLock":
        self.acquire()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<ChecksafeLock {self.name}>"


class RaceViolation(RuntimeError):
    """Raised on clean close of a ``debug_checks`` engine that saw races."""


@dataclasses.dataclass(frozen=True)
class RaceReport:
    """One detected violation (reported once per variable/surface)."""

    var: str
    write: bool
    thread: str
    lockset: tuple[str, ...]
    note: str = ""

    def __str__(self) -> str:
        kind = "write" if self.write else "read"
        locks = ", ".join(self.lockset) or "<empty>"
        return f"{self.var}: unsynchronized {kind} on thread {self.thread} " \
               f"(candidate lockset: {locks}) {self.note}".rstrip()


# Eraser variable states
_EXCLUSIVE, _SHARED, _SHARED_MOD = range(3)


class _VarState:
    __slots__ = ("state", "owner", "candidates")

    def __init__(self, owner: int):
        self.state = _EXCLUSIVE
        self.owner = owner
        self.candidates: set | None = None


class LocksetChecker:
    """The lockset state machine plus the report log.

    ``access(var, write)`` feeds one shared-state access; ``barrier()`` resets
    all variable states at a sequence point; ``check_coordinator(surface)``
    enforces single-coordinator submission.  ``reports`` accumulates one
    :class:`RaceReport` per offending variable; ``events`` counts every access
    observed (tests assert instrumentation actually fired).
    """

    def __init__(self):
        self._mu = threading.Lock()
        self._vars: dict[str, _VarState] = {}
        self._reported: set[str] = set()
        self._coordinator: int | None = None
        self.reports: list[RaceReport] = []
        self.events = 0
        self.barriers = 0

    # ----------------------------------------------------------- the machine
    def access(self, var: str, write: bool) -> None:
        tid = threading.get_ident()
        held = _held()
        with self._mu:
            self.events += 1
            st = self._vars.get(var)
            if st is None:
                self._vars[var] = _VarState(tid)
                return
            if st.state == _EXCLUSIVE:
                if st.owner == tid:
                    return
                # second thread: start refining from its current lockset
                st.state = _SHARED_MOD if write else _SHARED
                st.candidates = set(held)
            else:
                st.candidates &= held
                if write:
                    st.state = _SHARED_MOD
            if st.state == _SHARED_MOD and not st.candidates:
                self._report(var, write, tid,
                             "no common lock across the sharing threads")

    def barrier(self) -> None:
        """A happens-before barrier (executor drain): everything accessed
        before it is ordered before everything after — restart all variables
        at virgin state so cross-window pairs are not reported."""
        with self._mu:
            self.barriers += 1
            self._vars.clear()

    def check_coordinator(self, surface: str) -> None:
        """Record the first thread to submit through ``surface``'s executor
        and report any submission from a different thread."""
        tid = threading.get_ident()
        with self._mu:
            self.events += 1
            if self._coordinator is None:
                self._coordinator = tid
            elif self._coordinator != tid:
                self._report(f"executor.{surface}", True, tid,
                             "second thread submitted to a single-coordinator "
                             "executor")

    def _report(self, var: str, write: bool, tid: int, note: str) -> None:
        # one report per variable: the first empty-lockset access proves the
        # race; repeats on the same variable add noise, not information
        if var in self._reported:
            return
        self._reported.add(var)
        thread = threading.current_thread().name or str(tid)
        st = self._vars.get(var)
        lockset = tuple(sorted(repr(l) for l in (st.candidates or ()))) if st else ()
        self.reports.append(RaceReport(var, write, thread, lockset, note))

    # -------------------------------------------------------------- plumbing
    def wrap_lock(self, lock, name: str) -> ChecksafeLock:
        if isinstance(lock, ChecksafeLock):
            return lock
        return ChecksafeLock(name, lock)

    def raise_if_violations(self) -> None:
        if self.reports:
            lines = "\n  ".join(str(r) for r in self.reports)
            raise RaceViolation(
                f"lockset race detector found {len(self.reports)} violation(s):"
                f"\n  {lines}"
            )


# ------------------------------------------------------------ instrumentation
# front-end counters shared across coordinator + workers (must match the
# static linter's FRONTEND_COUNTERS; the differential tests cross-check)
MONITORED_COUNTERS = frozenset([
    "gets", "get_probes", "get_fallbacks", "scans", "scan_probes",
    "splits", "merges", "migrated_keys", "migration_ticks",
])

# ParallaxStore surfaces touched by executor tasks: method name -> is-write
_STORE_READS = ("get", "scan", "scan_range", "live_keys_in", "newest_entries",
                "index_entry", "iter_range")
_STORE_WRITES = ("put", "update", "delete", "delete_range", "gc_tick",
                 "flush_all", "flush_l0", "crash", "recover", "_write")

_CLASS_CACHE: dict[type, type] = {}


def _instrumented_class(base: type) -> type:
    """A cached dynamic subclass of a front-end class whose attribute hooks
    report counter reads/writes to the instance's ``_race_checker``."""
    cls = _CLASS_CACHE.get(base)
    if cls is not None:
        return cls

    def __setattr__(self, name, value):
        if name in MONITORED_COUNTERS:
            object.__getattribute__(self, "_race_checker").access(
                f"frontend.{name}", True)
        object.__setattr__(self, name, value)

    def __getattribute__(self, name):
        if name in MONITORED_COUNTERS:
            object.__getattribute__(self, "_race_checker").access(
                f"frontend.{name}", False)
        return object.__getattribute__(self, name)

    cls = type(f"Checked{base.__name__}", (base,),
               {"__setattr__": __setattr__, "__getattribute__": __getattribute__})
    _CLASS_CACHE[base] = cls
    return cls


def _wrap_method(obj, name: str, before) -> None:
    """Shadow ``obj.name`` with an instance attribute calling ``before()``
    first — per-instance, so no other object pays anything."""
    orig = getattr(obj, name, None)
    if orig is None:
        return

    def wrapper(*args, __orig=orig, __before=before, **kwargs):
        __before()
        return __orig(*args, **kwargs)

    wrapper.__name__ = getattr(orig, "__name__", name)
    setattr(obj, name, wrapper)


def attach_parallax(store: ParallaxStore, checker: LocksetChecker, label: str) -> None:
    """Report every op on one backing store as an access to one variable —
    the store is single-threaded by the exclusivity contract, so any
    cross-thread overlap without the store's exclusivity lock is a race."""
    if getattr(store, "_race_wrapped", False):
        return
    store._race_wrapped = True
    var = f"store.{label}"
    for name in _STORE_READS:
        _wrap_method(store, name, lambda v=var: checker.access(v, False))
    for name in _STORE_WRITES:
        _wrap_method(store, name, lambda v=var: checker.access(v, True))


def attach_metalog(metalog: MetadataLog, checker: LocksetChecker) -> None:
    """Metadata-WAL appends must be totally ordered (sequence points only):
    modeled as writes to one variable, with the append lock tracked."""
    metalog._append_lock = checker.wrap_lock(metalog._append_lock,
                                             "metalog._append_lock")
    _wrap_method(metalog, "append", lambda: checker.access("metalog.records", True))
    _wrap_method(metalog, "replay", lambda: checker.access("metalog.records", False))


def attach_frontend(store: BaseShardedStore, checker: LocksetChecker) -> None:
    """Instrument a sharded front-end: tracked ``_stats_lock``, counter hooks
    via a dynamic subclass swap, per-shard store shims (including shards a
    later split creates), and the metadata WAL if present."""
    store._race_checker = checker
    store._stats_lock = checker.wrap_lock(store._stats_lock,
                                          "frontend._stats_lock")
    store.__class__ = _instrumented_class(type(store))
    for i, s in enumerate(store._all_stores()):
        attach_parallax(s, checker, str(i))
    metalog = getattr(store, "metalog", None)
    if metalog is not None:
        attach_metalog(metalog, checker)
    orig_new_shard = store._new_shard
    counter = [len(store._all_stores())]

    def _new_shard():
        s = orig_new_shard()
        counter[0] += 1
        attach_parallax(s, checker, f"new{counter[0]}")
        return s

    store._new_shard = _new_shard


_SUBMISSION_SURFACES = ("put_many", "update_many", "delete_many", "get_many",
                        "scan", "after_batch", "migration_tick", "gc_tick",
                        "exclusive")


def attach_executor(executor, checker: LocksetChecker) -> None:
    """Instrument a :class:`ShardExecutor`: exclusivity locks become tracked
    (workers then carry them in their locksets), a completed ``drain`` is a
    lockset barrier, and every submission surface asserts the
    single-coordinator contract."""
    # all future and existing per-store exclusivity locks become tracked
    executor._new_store_lock = lambda: ChecksafeLock("executor.store_lock")
    for key, lock in list(executor._locks.items()):
        executor._locks[key] = checker.wrap_lock(lock, f"executor.store_lock:{key}")
    orig_drain = executor.drain

    def drain():
        orig_drain()
        checker.barrier()

    executor.drain = drain
    for name in _SUBMISSION_SURFACES:
        _wrap_method(executor, name,
                     lambda n=name: checker.check_coordinator(n))


def attach_engine(engine) -> LocksetChecker:
    """Instrument a :class:`repro.api.Engine` (store + executor); returns the
    checker (also reachable as ``engine.race_checker``)."""
    checker = LocksetChecker()
    store = engine.store
    if isinstance(store, BaseShardedStore):
        attach_frontend(store, checker)
    else:
        attach_parallax(store, checker, "solo")
    if engine._executor is not None:
        attach_executor(engine._executor, checker)
    return checker


__all__ = [
    "ChecksafeLock",
    "LocksetChecker",
    "MONITORED_COUNTERS",
    "RaceReport",
    "RaceViolation",
    "attach_engine",
    "attach_executor",
    "attach_frontend",
    "attach_metalog",
    "attach_parallax",
]
