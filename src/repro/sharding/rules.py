"""Parameter/activation sharding rules for the (pod, data, model) mesh.

Strategy (baseline; §Perf hillclimbs explore alternatives):

* **TP** over ``model``: attention q-heads, FFN hidden, MoE expert-hidden,
  SSM inner dim, vocab.  KV heads and SSM B/C groups are **replicated** when
  they don't divide the axis (GQA kv replication — standard TP practice).
* **FSDP** over ``(pod, data)``: the non-TP dim of every large 2-D+ weight is
  sharded over the data axes (ZeRO-3 style); XLA SPMD inserts the per-layer
  all-gathers.  Optimizer state inherits parameter shardings.
* **Head padding**: archs whose q-head count doesn't divide the model axis
  (yi-34b 56H, phi3 40H) are padded to the next multiple with exact-zero
  padded heads (``pad_config_for_mesh``); vocab is padded to a lane-aligned
  multiple of the model axis.  Both documented in DESIGN.md §2.4.
* **Decode caches**: KV caches shard batch over data and sequence over
  ``model`` (sequence-parallel cache; softmax stats reduce collectively).
  When batch is 1 (long_500k) the sequence axis takes all mesh axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig



# Layouts (the §Perf hillclimb knobs; default is the paper-faithful baseline):
#   baseline            — TP over 'model', FSDP over (pod, data)
#   dp-only             — no TP: 'model' joins the data axes (batch + FSDP
#                         shard over every axis).  Right for small models
#                         whose TP all-reduces dwarf their compute.
#   replicated-weights  — weights sharded over 'model' only (replicated over
#                         data axes).  Right for decode: kills the per-step
#                         FSDP re-gather at the cost of dp x weight memory.
LAYOUTS = ("baseline", "dp-only", "replicated-weights", "pure-dp")
# pure-dp: weights fully replicated, batch over every axis — the classic
# small-model answer (grad all-reduce is the only collective).


def data_axes(mesh: Mesh, layout: str = "baseline") -> tuple[str, ...]:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if layout == "dp-only" and "model" in mesh.axis_names:
        axes = (*axes, "model")
    if layout in ("replicated-weights", "pure-dp"):
        return ()  # weights see no data axes
    return axes


def model_axis_size(mesh: Mesh, layout: str = "baseline") -> int:
    if layout in ("dp-only", "pure-dp"):
        return 1
    return mesh.shape.get("model", 1)


def pad_config_for_mesh(cfg: ArchConfig, mesh: Mesh, layout: str = "baseline") -> ArchConfig:
    """Pad q heads / vocab so TP dims divide the model axis (exact math)."""
    tp = model_axis_size(mesh, layout)
    changes: dict[str, Any] = {"vocab_pad_multiple": 128 * tp}
    if cfg.num_heads and cfg.num_heads % tp:
        padded = -(-cfg.num_heads // tp) * tp
        changes["orig_num_heads"] = cfg.num_heads
        changes["num_heads"] = padded
    return dataclasses.replace(cfg, **changes)


def _spec_for(name: str, shape: tuple[int, ...], cfg: ArchConfig, mesh: Mesh, stacked: bool, layout: str = "baseline") -> P:
    da = data_axes(mesh, layout)
    DA = da if len(da) > 1 else (da[0] if da else None)
    tp = model_axis_size(mesh, layout)
    mdl = "model" if tp > 1 else None

    def div(dim: int, axis) -> Any:
        if axis is None:
            return None
        size = mesh.shape["model"] if axis == "model" else _axes_size(mesh, axis)
        return axis if dim % size == 0 else None

    def _axes_size(mesh, axis):
        if isinstance(axis, tuple):
            out = 1
            for a in axis:
                out *= mesh.shape[a]
            return out
        return mesh.shape[axis]

    d = shape[1:] if stacked else shape
    nd = len(d)
    spec: tuple = ()
    if name in ("embed",):
        spec = (div(d[0], mdl), div(d[1], DA))
    elif name == "unembed":
        spec = (div(d[0], DA), div(d[1], mdl))
    elif name == "dec_pos":
        spec = (div(d[0], mdl), div(d[1], DA))
    elif name == "wq":
        spec = (div(d[0], DA), div(d[1], mdl), None)
    elif name in ("wk", "wv"):
        spec = (div(d[0], DA), div(d[1], mdl), None)
    elif name == "wo":
        spec = (div(d[0], mdl), None, div(d[2], DA))
    elif name in ("bq", "bk", "bv"):
        spec = (div(d[0], mdl), None)
    elif name in ("gate", "up"):  # mlp (D,F) or moe (E,D,F)
        if nd == 2:
            spec = (div(d[0], DA), div(d[1], mdl))
        else:
            spec = (None, div(d[1], DA), div(d[2], mdl))
    elif name == "down":          # mlp (F,D) or moe (E,F,D)
        if nd == 2:
            spec = (div(d[0], mdl), div(d[1], DA))
        else:
            spec = (None, div(d[1], mdl), div(d[2], DA))
    elif name == "router":
        spec = (div(d[0], DA), None)
    elif name in ("wi", "wo_mlp"):
        spec = (div(d[0], DA), div(d[1], mdl))
    elif name in ("wz", "wx"):
        spec = (div(d[0], DA), div(d[1], mdl))
    elif name in ("wb", "wc"):
        spec = (div(d[0], DA), None)
    elif name == "wdt":
        spec = (div(d[0], DA), div(d[1], mdl))
    elif name == "out_proj":
        spec = (div(d[0], mdl), div(d[1], DA))
    elif name == "conv_x":
        spec = (None, div(d[1], mdl))
    elif name in ("conv_b", "conv_c"):
        spec = (None, None)
    elif name in ("a_log", "dt_bias", "d_skip"):
        spec = (div(d[0], mdl),)
    elif name == "bi":            # gelu mlp hidden bias (F,)
        spec = (div(d[0], mdl),)
    else:                          # norms, small biases: replicate
        spec = (None,) * nd
    if stacked:
        spec = (None, *spec)
    return P(*spec)


_GELU_FIX = {"wi": "wi", "wo": None}  # gelu-mlp wo collides with attention wo


def param_specs(cfg: ArchConfig, mesh: Mesh, params_shape, layout: str = "baseline") -> Any:
    """PartitionSpec tree matching a (possibly abstract) param tree."""

    def walk(path: tuple, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        stacked = any(n in ("layers", "enc_layers", "dec_layers") for n in names)
        # disambiguate gelu-mlp 'wo' (D-major 2d) from attention 'wo' (3d)
        if name == "wo" and len(leaf.shape) - (1 if stacked else 0) == 2:
            name = "wo_mlp"
        if name in ("scale", "bias", "bo", "conv_bx", "conv_bb", "conv_bc"):
            nd = len(leaf.shape) - (1 if stacked else 0)
            return P(*((None,) * len(leaf.shape)))
        return _spec_for(name, leaf.shape, cfg, mesh, stacked, layout)

    return jax.tree_util.tree_map_with_path(walk, params_shape)


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_shape, layout: str = "baseline") -> Any:
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_specs(cfg, mesh, params_shape, layout),
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------- activations
def batch_specs(cfg: ArchConfig, mesh: Mesh, batch_shape, layout: str = "baseline") -> Any:
    da = data_axes(mesh, "dp-only" if layout in ("dp-only", "pure-dp") else "baseline")
    DA = da if len(da) > 1 else (da[0] if da else None)

    def spec(path, leaf):
        b = leaf.shape[0]
        dsz = 1
        for a in da:
            dsz *= mesh.shape[a]
        first = DA if b % max(dsz, 1) == 0 and dsz > 1 else None
        return P(first, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def cache_specs(cfg: ArchConfig, mesh: Mesh, cache_shape, layout: str = "baseline") -> Any:
    """KV/SSM cache shardings for decode (see module docstring)."""
    da = data_axes(mesh, "dp-only" if layout in ("dp-only", "pure-dp") else "baseline")
    DA = da if len(da) > 1 else (da[0] if da else None)
    dsz = 1
    for a in da:
        dsz *= mesh.shape[a]
    tp = model_axis_size(mesh)

    def spec(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        shp = leaf.shape
        if name in ("k", "v", "cross_k", "cross_v"):
            # (L_or_sites, B, S, K, hd)
            _, b, s, kh, hd = shp
            bspec = DA if dsz > 1 and b % dsz == 0 else None
            if bspec is None and dsz > 1 and s % (dsz * tp) == 0:
                sspec = (*da, "model") if tp > 1 else DA
            else:
                sspec = "model" if tp > 1 and s % tp == 0 else None
            return P(None, bspec, sspec, None, None)
        if name == "state":  # (L, B, H, P, N)
            _, b, h, p, n = shp
            bspec = DA if dsz > 1 and b % dsz == 0 else None
            hspec = "model" if tp > 1 and h % tp == 0 else None
            return P(None, bspec, hspec, None, None)
        if name == "conv":  # (L, B, W, C) — small, replicate beyond batch
            b = shp[1]
            bspec = DA if dsz > 1 and b % dsz == 0 else None
            return P(None, bspec, None, None)
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def to_shardings(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
