"""Selectable config module for --arch (see registry for provenance)."""
from .registry import YI_34B

CONFIG = YI_34B
REDUCED = CONFIG.reduced()
