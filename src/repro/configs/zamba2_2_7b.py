"""Selectable config module for --arch (see registry for provenance)."""
from .registry import ZAMBA2_27B

CONFIG = ZAMBA2_27B
REDUCED = CONFIG.reduced()
