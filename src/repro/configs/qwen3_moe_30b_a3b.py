"""Selectable config module for --arch (see registry for provenance)."""
from .registry import QWEN3_MOE_30B

CONFIG = QWEN3_MOE_30B
REDUCED = CONFIG.reduced()
