"""Selectable config module for --arch (see registry for provenance)."""
from .registry import QWEN3_8B

CONFIG = QWEN3_8B
REDUCED = CONFIG.reduced()
