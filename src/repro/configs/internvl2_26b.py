"""Selectable config module for --arch (see registry for provenance)."""
from .registry import INTERNVL2_26B

CONFIG = INTERNVL2_26B
REDUCED = CONFIG.reduced()
