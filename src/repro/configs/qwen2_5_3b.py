"""Selectable config module for --arch (see registry for provenance)."""
from .registry import QWEN25_3B

CONFIG = QWEN25_3B
REDUCED = CONFIG.reduced()
