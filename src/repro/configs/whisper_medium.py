"""Selectable config module for --arch (see registry for provenance)."""
from .registry import WHISPER_MEDIUM

CONFIG = WHISPER_MEDIUM
REDUCED = CONFIG.reduced()
