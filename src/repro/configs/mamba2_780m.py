"""Selectable config module for --arch (see registry for provenance)."""
from .registry import MAMBA2_780M

CONFIG = MAMBA2_780M
REDUCED = CONFIG.reduced()
