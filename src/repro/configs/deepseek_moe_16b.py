"""Selectable config module for --arch (see registry for provenance)."""
from .registry import DEEPSEEK_MOE_16B

CONFIG = DEEPSEEK_MOE_16B
REDUCED = CONFIG.reduced()
