"""The 10 assigned architectures (exact public configs) + input shapes.

Sources per the assignment brief:
  mamba2-780m        [arXiv:2405.21060]        yi-34b        [arXiv:2403.04652]
  internvl2-26b      [arXiv:2404.16821]        qwen2.5-3b    [hf:Qwen/Qwen2.5-*]
  phi3-medium-14b    [arXiv:2404.14219]        qwen3-8b      [hf:Qwen/Qwen3-8B]
  whisper-medium     [arXiv:2212.04356]        deepseek-moe-16b [arXiv:2401.06066]
  qwen3-moe-30b-a3b  [hf:Qwen/Qwen3-30B-A3B]   zamba2-2.7b   [arXiv:2411.15242]

Shapes (all archs):
  train_4k     seq 4096,   global batch 256   -> train_step
  prefill_32k  seq 32768,  global batch 32    -> prefill_step
  decode_32k   seq 32768,  global batch 128   -> serve_step (1 token, KV cache)
  long_500k    seq 524288, global batch 1     -> serve_step; SSM/hybrid only
                                                 (full-attention archs skip —
                                                 DESIGN.md §Arch-applicability)
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig

ARCHS: dict[str, ArchConfig] = {}


def _register(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


MAMBA2_780M = _register(ArchConfig(
    name="mamba2-780m", family="ssm", num_layers=48, d_model=1536,
    vocab_size=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    tie_embeddings=True,
))

INTERNVL2_26B = _register(ArchConfig(
    name="internvl2-26b", family="vlm", num_layers=48, d_model=6144,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=16384, vocab_size=92553,
    num_patches=256, rope_theta=1_000_000.0,
))

YI_34B = _register(ArchConfig(
    name="yi-34b", family="dense", num_layers=60, d_model=7168,
    num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480, vocab_size=64000,
    rope_theta=5_000_000.0,
))

QWEN25_3B = _register(ArchConfig(
    name="qwen2.5-3b", family="dense", num_layers=36, d_model=2048,
    num_heads=16, num_kv_heads=2, head_dim=128, d_ff=11008, vocab_size=151936,
    qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=True,
))

PHI3_MEDIUM = _register(ArchConfig(
    name="phi3-medium-14b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=10, head_dim=128, d_ff=17920, vocab_size=100352,
))

QWEN3_8B = _register(ArchConfig(
    name="qwen3-8b", family="dense", num_layers=36, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=12288, vocab_size=151936,
    qk_norm=True, rope_theta=1_000_000.0,
))

WHISPER_MEDIUM = _register(ArchConfig(
    name="whisper-medium", family="encdec", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=51865,
    encoder_layers=24, encoder_frames=1500,
))

DEEPSEEK_MOE_16B = _register(ArchConfig(
    name="deepseek-moe-16b", family="moe", num_layers=28, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, vocab_size=102400,
    num_experts=64, num_shared_experts=2, top_k=6, expert_d_ff=1408,
))

QWEN3_MOE_30B = _register(ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", num_layers=48, d_model=2048,
    num_heads=32, num_kv_heads=4, head_dim=128, vocab_size=151936,
    num_experts=128, num_shared_experts=0, top_k=8, expert_d_ff=768,
    qk_norm=True, rope_theta=1_000_000.0,
))

ZAMBA2_27B = _register(ArchConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, head_dim=80, d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
))


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(arch: str, shape: str) -> bool:
    """long_500k runs only for sub-quadratic (SSM/hybrid) archs."""
    if shape == "long_500k":
        return ARCHS[arch].subquadratic()
    return True


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]


def runnable_cells() -> list[tuple[str, str]]:
    return [(a, s) for a, s in all_cells() if applicable(a, s)]
