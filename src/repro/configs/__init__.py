"""Config registry: 10 assigned architectures x 4 input shapes."""
from .registry import ARCHS, SHAPES, ShapeSpec, all_cells, applicable, runnable_cells

def get_arch(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]

__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "get_arch", "all_cells", "applicable", "runnable_cells"]
