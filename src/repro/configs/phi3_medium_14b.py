"""Selectable config module for --arch (see registry for provenance)."""
from .registry import PHI3_MEDIUM

CONFIG = PHI3_MEDIUM
REDUCED = CONFIG.reduced()
