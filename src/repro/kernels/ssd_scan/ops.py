"""Jit'd dispatcher for the SSD chunked scan.

Chooses the Pallas TPU kernel on TPU backends (or when forced via
``impl='pallas'`` — interpret mode on CPU for validation) and the pure-jnp
reference otherwise.  The models always call this entry point.
"""
from __future__ import annotations

import functools

import jax

from .ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_scan(x, dt, a, bmat, cmat, *, chunk: int = 256, impl: str = "auto"):
    if impl == "pallas" or (impl == "auto" and jax.default_backend() == "tpu"):
        from .kernel import ssd_scan_pallas

        interpret = jax.default_backend() != "tpu"
        return ssd_scan_pallas(x, dt, a, bmat, cmat, chunk=chunk, interpret=interpret)
    return ssd_scan_ref(x, dt, a, bmat, cmat, chunk=chunk)
