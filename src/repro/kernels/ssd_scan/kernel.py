"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: ``(B, H, num_chunks)`` with the chunk dimension innermost.  TPU grids
execute sequentially per core, so the recurrent state (P, N) lives in fp32
VMEM scratch and is carried across chunk iterations of one (batch, head)
pair — no HBM round-trip for the recurrence.  Per chunk the kernel computes:

    intra  = tril(C B^T ∘ exp(cum_l - cum_s)) (dt x)
    y      = intra + exp(cum) * (C . state_in)
    state  = exp(cum_L) * state_in + sum_s exp(cum_L - cum_s) dt_s B_s x_s^T

BlockSpecs keep one chunk of x (L, P), B/C (L, N) and dt (L,) in VMEM; the
(L, L) decay matrix is built in-register.  L defaults to 128/256 (MXU-
aligned); P=64, N=64/128 per the assigned SSM configs.

The GQA-like group mapping for B/C (``h // (H // G)``) happens in the
index_map, mirroring the flash-attention kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_ref, *, L, P, N):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (L,)
    a = a_ref[0].astype(jnp.float32)                 # scalar decay for this head
    b = b_ref[0, :, 0, :].astype(jnp.float32)        # (L, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)        # (L, N)

    da = dt * a                                      # (L,)
    cum = jnp.cumsum(da)                             # inclusive (L,)
    dtx = dt[:, None] * x                            # (L, P)

    # intra-chunk quadratic form with decay mask
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))          # (L, L)
    decay = cum[:, None] - cum[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    # mask before exp (upper triangle is positive -> overflow; see ref.py)
    w = jnp.exp(jnp.where(li >= si, decay, -1e30))
    y = jax.lax.dot(scores * w, dtx)                                      # (L, P)

    # inter-chunk: inject state entering this chunk
    state_in = state_ref[...]                                             # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, state_in, (((1,), (1,)), ((), ()))
    )                                                                     # (L,P)

    # state update: decay + outer-product accumulation
    persist = jnp.exp(cum[-1] - cum)                                      # (L,)
    contrib = jax.lax.dot_general(dtx * persist[:, None], b, (((0,), (0,)), ((), ())))  # (P,N)
    state_ref[...] = state_in * jnp.exp(cum[-1]) + contrib

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        st_ref[0, 0, :, :] = state_ref[...].astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jax.Array,     # (B, S, H, P)
    dt: jax.Array,    # (B, S, H)
    a: jax.Array,     # (H,)
    bmat: jax.Array,  # (B, S, G, N)
    cmat: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b_, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L
    rep = h // g
    grid = (b_, h, nc)
    kernel = functools.partial(_ssd_kernel, L=L, P=p, N=n)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, L, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, L, 1, n), lambda bi, hi, ci: (bi, ci, hi // rep, 0)),
            pl.BlockSpec((1, L, 1, n), lambda bi, hi, ci: (bi, ci, hi // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b_, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt.astype(jnp.float32), a.astype(jnp.float32), bmat, cmat)
    return y, st
