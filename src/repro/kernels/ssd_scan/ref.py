"""Pure-jnp oracle for the Mamba2 SSD chunked scan.

Math (arXiv:2405.21060, SSD): per head h with scalar decay ``a_h < 0``:

    state_t = exp(a_h * dt_t) * state_{t-1} + dt_t * B_t x_t^T
    y_t     = C_t . state_t

computed chunk-parallel: intra-chunk via the (L, L) decay-masked quadratic
form, inter-chunk via a sequential scan over per-chunk states.  This file is
the correctness oracle for the Pallas kernel and the XLA fallback used when
lowering on non-TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H) float32
    a: jax.Array,      # (H,) float32, negative
    bmat: jax.Array,   # (B, S, G, N)
    cmat: jax.Array,   # (B, S, G, N)
    *,
    chunk: int = 256,
    initial_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    L = min(chunk, s)
    if s % L:
        pad = L - s % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    spad = x.shape[1]
    nc = spad // L
    rep = h // g
    bh = jnp.repeat(bmat, rep, axis=2)  # (B,S,H,N)
    ch = jnp.repeat(cmat, rep, axis=2)

    f32 = jnp.float32
    dtf = dt.astype(f32)
    da = dtf * a.astype(f32)[None, None, :]                 # (B,S,H)
    dtx = (dtf[..., None] * x.astype(f32))                  # (B,S,H,P)

    # chunked views
    da_c = da.reshape(b, nc, L, h)
    cum = jnp.cumsum(da_c, axis=2)                          # inclusive
    dtx_c = dtx.reshape(b, nc, L, h, p)
    b_c = bh.reshape(b, nc, L, h, n).astype(f32)
    c_c = ch.reshape(b, nc, L, h, n).astype(f32)

    # ---- intra-chunk quadratic form
    scores = jnp.einsum("bclhn,bcshn->bchls", c_c, b_c)     # (B,nc,H,L,L)
    cum_h = cum.transpose(0, 1, 3, 2)                       # (B,nc,H,L)
    decay = cum_h[:, :, :, :, None] - cum_h[:, :, :, None, :]  # cum_l - cum_s
    mask = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: upper-triangle decay is positive and exp overflows;
    # where(mask, exp(x), 0) would leak NaN into the cotangent (0 * inf)
    decay = jnp.where(mask[None, None, None], decay, -1e30)
    w = jnp.exp(decay)
    y_intra = jnp.einsum("bchls,bcshp->bclhp", scores * w, dtx_c)

    # ---- per-chunk states and sequential carry
    last = cum[:, :, -1:, :]                                # (B,nc,1,H)
    persist = jnp.exp(last - cum)                           # (B,nc,L,H)
    chunk_states = jnp.einsum("bclh,bclhp,bclhn->bchpn", persist, dtx_c, b_c)
    chunk_decay = jnp.exp(last[:, :, 0, :])                 # (B,nc,H)

    s0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), f32)
    )

    def step(carry, inp):
        cs, cd = inp                                        # (B,H,P,N), (B,H)
        new = carry * cd[..., None, None] + cs
        return new, carry                                   # emit state ENTERING the chunk

    final, entering = jax.lax.scan(
        step,
        s0,
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    entering = entering.transpose(1, 0, 2, 3, 4)            # (B,nc,H,P,N)

    # ---- inter-chunk contribution
    y_inter = jnp.einsum("bclh,bclhn,bchpn->bclhp", jnp.exp(cum), c_c, entering)

    y = (y_intra + y_inter).reshape(b, spad, h, p)[:, :s]
    return y, final


def ssd_reference_sequential(x, dt, a, bmat, cmat, initial_state=None):
    """O(S) sequential oracle-of-the-oracle (tests only; tiny shapes)."""
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    bh = jnp.repeat(bmat, rep, axis=2).astype(jnp.float32)
    ch = jnp.repeat(cmat, rep, axis=2).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    state = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    ys = []
    for t in range(s):
        decay = jnp.exp(a.astype(jnp.float32)[None, :] * dtf[:, t])        # (B,H)
        dx = dtf[:, t, :, None] * x[:, t].astype(jnp.float32)              # (B,H,P)
        state = state * decay[..., None, None] + dx[..., None] * bh[:, t, :, None, :]
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, ch[:, t]))
    return jnp.stack(ys, axis=1), state
