"""Pallas TPU bitonic merge of two sorted runs (LSM compaction hot loop).

Hardware adaptation (DESIGN.md §2.3): the paper's compaction merge is a
pointer-walking two-finger merge — branchy, scalar, hostile to TPU vector
units.  The TPU-native equivalent: concatenate run A (ascending) with run B
*reversed* (descending) to form a bitonic sequence of length 2T, then run the
log2(2T)-stage bitonic **merge network**.  Every stage is a reshape +
element-wise min/max — no gathers, no data-dependent control flow, perfectly
mapped to the VPU's (8, 128) lanes.  Payloads co-move via select on the key
comparison.

Grid: one program per row-group of tiles; each program holds its
(BG, 2T) working set in VMEM.  T must be a power of two (the ops.py wrapper
pads); keys int32/uint32/float32, payload any 32-bit dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_stage(keys: jax.Array, vals: jax.Array, stride: int):
    """One bitonic-merge compare-exchange stage at the given stride.

    keys/vals: (BG, N).  Reshape to (BG, N/(2*stride), 2, stride) and
    min/max along the 2-axis — the vectorized form of `compare with partner
    idx XOR stride`.
    """
    bg, n = keys.shape
    k4 = keys.reshape(bg, n // (2 * stride), 2, stride)
    v4 = vals.reshape(bg, n // (2 * stride), 2, stride)
    lo_k, hi_k = k4[:, :, 0], k4[:, :, 1]
    lo_v, hi_v = v4[:, :, 0], v4[:, :, 1]
    swap = lo_k > hi_k
    nlo_k = jnp.where(swap, hi_k, lo_k)
    nhi_k = jnp.where(swap, lo_k, hi_k)
    nlo_v = jnp.where(swap, hi_v, lo_v)
    nhi_v = jnp.where(swap, lo_v, hi_v)
    keys = jnp.stack([nlo_k, nhi_k], axis=2).reshape(bg, n)
    vals = jnp.stack([nlo_v, nhi_v], axis=2).reshape(bg, n)
    return keys, vals


def _merge_kernel(ak_ref, bk_ref, av_ref, bv_ref, ok_ref, ov_ref, *, tile: int):
    ak = ak_ref[...]
    av = av_ref[...]
    # reverse B to form a bitonic sequence [A asc | B desc]
    bk = jax.lax.rev(bk_ref[...], (1,))
    bv = jax.lax.rev(bv_ref[...], (1,))
    keys = jnp.concatenate([ak, bk], axis=1)
    vals = jnp.concatenate([av, bv], axis=1)
    stride = tile
    while stride >= 1:
        keys, vals = _merge_stage(keys, vals, stride)
        stride //= 2
    ok_ref[...] = keys
    ov_ref[...] = vals


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def merge_runs_pallas(
    a_keys: jax.Array,  # (G, T) ascending rows, T a power of two
    b_keys: jax.Array,
    a_vals: jax.Array,
    b_vals: jax.Array,
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    g, t = a_keys.shape
    assert t & (t - 1) == 0, f"tile width must be a power of two, got {t}"
    bg = min(block_rows, g)
    assert g % bg == 0, (g, bg)
    grid = (g // bg,)
    kernel = functools.partial(_merge_kernel, tile=t)
    in_spec = pl.BlockSpec((bg, t), lambda i: (i, 0))
    out_spec = pl.BlockSpec((bg, 2 * t), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, in_spec, in_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((g, 2 * t), a_keys.dtype),
            jax.ShapeDtypeStruct((g, 2 * t), a_vals.dtype),
        ],
        interpret=interpret,
    )(a_keys, b_keys, a_vals, b_vals)
