"""Oracle for the compaction merge kernel: jnp sort of the concatenation.

Stable w.r.t. run order is not required — Parallax merges runs of *unique*
keys per level and resolves collisions by LSN before the byte-level merge, so
the kernel contract is: given two ascending (G, T) key tiles with payloads,
produce the ascending (G, 2T) merged keys + co-sorted payloads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def merge_runs_ref(
    a_keys: jax.Array,   # (G, T) ascending per row
    b_keys: jax.Array,   # (G, T) ascending per row
    a_vals: jax.Array,   # (G, T) payload (e.g. pointer/index)
    b_vals: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    keys = jnp.concatenate([a_keys, b_keys], axis=1)
    vals = jnp.concatenate([a_vals, b_vals], axis=1)
    order = jnp.argsort(keys, axis=1, stable=True)
    return (
        jnp.take_along_axis(keys, order, axis=1),
        jnp.take_along_axis(vals, order, axis=1),
    )
