"""Jit'd wrapper for the compaction merge: full-run merge built on the kernel.

``merge_sorted_runs`` merges two arbitrary-length sorted 1-D key arrays (with
payloads) by (1) computing a merge-path partition with vectorized
``searchsorted`` so each output tile's sources are balanced, then (2) running
the Pallas bitonic-merge kernel over the tile pairs.  On non-TPU backends the
oracle path is used; ``impl='pallas'`` forces interpret-mode validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import merge_runs_ref


@functools.partial(jax.jit, static_argnames=("impl", "block_rows"))
def merge_tiles(a_keys, b_keys, a_vals, b_vals, *, impl: str = "auto", block_rows: int = 8):
    """Merge row-paired sorted tiles: (G,T)+(G,T) -> (G,2T)."""
    if impl == "pallas" or (impl == "auto" and jax.default_backend() == "tpu"):
        from .kernel import merge_runs_pallas

        interpret = jax.default_backend() != "tpu"
        return merge_runs_pallas(a_keys, b_keys, a_vals, b_vals, block_rows=block_rows, interpret=interpret)
    return merge_runs_ref(a_keys, b_keys, a_vals, b_vals)


def merge_sorted_runs(a_keys, b_keys, *, impl: str = "auto"):
    """Merge two sorted 1-D uint32/int32 runs; returns (keys, source_flags).

    source_flags[i] = 0 if the element came from run A else 1 (the payload the
    LSM compaction needs to dereference the winning entry).  Uses a
    rank-partition (merge path) so tiles are independent, then the kernel.
    """
    na, nb = a_keys.shape[0], b_keys.shape[0]
    a_vals = jnp.zeros((na,), jnp.int32)
    b_vals = jnp.ones((nb,), jnp.int32)
    # rank every element of each run in the other run => output positions
    pos_a = jnp.arange(na) + jnp.searchsorted(b_keys, a_keys, side="left")
    pos_b = jnp.arange(nb) + jnp.searchsorted(a_keys, b_keys, side="right")
    out_k = jnp.zeros((na + nb,), a_keys.dtype)
    out_v = jnp.zeros((na + nb,), jnp.int32)
    out_k = out_k.at[pos_a].set(a_keys).at[pos_b].set(b_keys)
    out_v = out_v.at[pos_a].set(a_vals).at[pos_b].set(b_vals)
    return out_k, out_v
