"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, K, D)
    v: jax.Array,  # (B, S, K, D)
    *,
    window: int = 0,
) -> jax.Array:
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    group = h // kh
    qg = q.reshape(b, sq, kh, group, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * d**-0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, d)
