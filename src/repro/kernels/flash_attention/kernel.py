"""Pallas TPU causal GQA flash attention (online softmax, VMEM tiling).

Grid layout: ``(B, H, num_q_blocks, num_kv_blocks)`` with the kv dimension
innermost.  TPU executes the grid sequentially per core, so fp32 VMEM scratch
(running max ``m``, normalizer ``l``, accumulator ``acc``) persists across kv
iterations of one q block — the classic flash recurrence:

    m'   = max(m, rowmax(s))
    l'   = l * exp(m - m') + rowsum(exp(s - m'))
    acc' = acc * exp(m - m') + exp(s - m') @ v

BlockSpecs keep one (BQ, D) q tile and one (BK, D) k/v tile in VMEM; the GQA
mapping happens in the k/v index_map (``h // group``), so no k/v duplication
is materialized.  Block sizes default to MXU-aligned 128/256 for D=128 heads.
Fully-masked kv blocks (ki > qi for causal) are skipped with ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, scale, window, seq_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # causal: kv block strictly after the q block contributes nothing
    needed = k_start <= q_start + bq - 1
    if window:
        needed &= k_start + bk - 1 >= q_start - window + 1

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)         # (BQ, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (BK, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (BQ, BK)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "window", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, K, D)
    v: jax.Array,
    *,
    block_q: int = 128,
    block_k: int = 128,
    window: int = 0,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, d = q.shape
    kh = k.shape[2]
    group = h // kh
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    grid = (b, h, s // bq, s // bk)

    kernel = functools.partial(
        _fa_kernel, bq=bq, bk=bk, scale=d**-0.5, window=window, seq_len=s
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h_, qi, ki: (b_, ki, h_ // group, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h_, qi, ki: (b_, ki, h_ // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _scratch((bq, 1)),
            _scratch((bq, 1)),
            _scratch((bq, d)),
        ],
        interpret=interpret,
    )(q, k, v)


def _scratch(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
