"""Jit'd wrapper: dispatches flash attention to Pallas (TPU) or the oracle."""
from __future__ import annotations

import functools

import jax

from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "impl", "block_q", "block_k"))
def flash_attention(q, k, v, *, window: int = 0, impl: str = "auto", block_q: int = 128, block_k: int = 128):
    if impl == "pallas" or (impl == "auto" and jax.default_backend() == "tpu"):
        from .kernel import flash_attention_pallas

        interpret = jax.default_backend() != "tpu"
        return flash_attention_pallas(
            q, k, v, window=window, block_q=block_q, block_k=block_k, interpret=interpret
        )
    return flash_attention_ref(q, k, v, window=window)
